#!/usr/bin/env python3
"""Append one bench run into the cross-PR trend store.

CI runs `cargo bench -- --quick` (which writes BENCH_gemm.json at the
repo root), restores BENCH_trend.json from the previous run's cache,
then calls this script to append the current run keyed by commit — so
the headline ratios (>=3x decode, >=3x prepared/parallel GEMM, pool >=
scoped) are tracked across PRs instead of living only in each run's
artifact.  Re-running on the same commit replaces that commit's entry
(idempotent on CI retries).

The trend is best-effort: overlapping CI runs both restore the same
parent cache and save separately, so the earlier run's entry can be
dropped from later history.  Each run's own BENCH_gemm.json artifact is
the authoritative record; the trend exists for the at-a-glance ratio
trajectory.

Gate mode: `--check LABEL:MIN` (repeatable) asserts that the HEADLINES
ratio LABEL computed from --bench is >= MIN and exits without touching
the trend — the single source of truth for the CI perf gates (decode,
pool, fabric), replacing per-gate inline scripts in ci.yml.
"""

import argparse
import json
import os

# Headline pairs tracked across PRs: (label, numerator bench, denominator
# bench) — ratio = numerator median_ns / denominator median_ns, so >1 is
# a win for the denominator side.  A denominator of None marks an
# absolute-rate headline instead: the value is the numerator bench's own
# "rate" field (requests/sec etc.), printed and gated without the `x`
# suffix.
HEADLINES = [
    (
        "decode",
        "micro/rrns decode_tile 16x64 clean per-element",
        "micro/rrns decode_tile 16x64 clean batched",
    ),
    (
        "gemm",
        "micro/gemm_mod 8x128x128 x4ch serial unprepared",
        "micro/gemm_mod 8x128x128 x4ch parallel prepared",
    ),
    (
        "pool",
        "micro/pool prepared 4x784x256 x4ch scoped-spawn",
        "micro/pool prepared 4x784x256 x4ch persistent-pool",
    ),
    (
        "fabric",
        "micro/pool prepared 4x784x256 x4ch scoped-spawn",
        "micro/pool prepared 4x784x256 x4ch shared-fabric",
    ),
    # gateway: the same 24-request synthetic-MLP stream in-process vs over
    # loopback TCP.  Ratio < 1 is expected (the wire adds work); the CI
    # gate (gateway >= 0.2) bounds the overhead at 5x, catching a
    # pathological protocol/session regression without flaking on runner
    # jitter.
    (
        "gateway",
        "serve/coordinator 24 reqs synthetic-mlp rns-b6 in-process",
        "serve/gateway loopback 24 reqs synthetic-mlp rns-b6",
    ),
    # sparse: conversion-avoiding capture on a 50%-zero-row workload must
    # beat dense capture (it skips DAC forward + ADC recapture + CRT decode
    # for the zero rows); the CI gate (sparse >= 1.05) catches the skip
    # machinery silently degrading into pure overhead.
    (
        "sparse",
        "micro/sparse rns gemm 16x128x64 50pct-zero dense-capture",
        "micro/sparse rns gemm 16x128x64 50pct-zero sparse-capture",
    ),
    # rps: sustained closed-loop requests/sec through the event-driven
    # gateway session layer, measured by the loadgen harness (4 conns,
    # window 8, 24 requests).  Absolute rate, not a ratio — the CI gate
    # (rps >= 5.0) is a floor far below any healthy runner, catching the
    # readiness loop wedging (stalled wakeups, lost replies) rather than
    # benchmarking the machine.
    (
        "rps",
        "serve/loadgen 24 reqs synthetic-mlp rns-b6 event-loop",
        None,
    ),
]


def load_trend(path):
    empty = {"schema": "rns-analog-bench-trend-v1", "runs": []}
    if not os.path.exists(path):
        return empty
    try:
        with open(path) as f:
            trend = json.load(f)
    except (json.JSONDecodeError, OSError):
        return empty  # corrupt cache: restart the trend, don't fail CI
    if not isinstance(trend, dict) or not isinstance(trend.get("runs"), list):
        return empty
    return trend


def ratio(bench_map, num, den):
    try:
        if den is None:
            return float(bench_map[num]["rate"])
        return bench_map[num]["median_ns"] / bench_map[den]["median_ns"]
    except (KeyError, TypeError, ValueError, ZeroDivisionError):
        return None


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--bench", default="BENCH_gemm.json", help="current run results")
    p.add_argument("--trend", default="BENCH_trend.json", help="trend store to append to")
    p.add_argument("--commit", default=os.environ.get("GITHUB_SHA", "unknown"))
    p.add_argument("--max-runs", type=int, default=200, help="keep at most the newest N runs")
    p.add_argument(
        "--check",
        action="append",
        default=[],
        metavar="LABEL:MIN",
        help="gate mode (repeatable): assert HEADLINES ratio LABEL >= MIN "
        "against --bench, exit nonzero on failure, never touch the trend",
    )
    args = p.parse_args()

    with open(args.bench) as f:
        bench = json.load(f)

    if args.check:
        bench_map = {b.get("name"): b for b in bench.get("benches", [])}
        headlines = {label: (num, den) for label, num, den in HEADLINES}
        failures = []
        for spec in args.check:
            label, _, min_s = spec.partition(":")
            if label not in headlines or not min_s:
                failures.append(f"bad --check spec `{spec}` (labels: {', '.join(headlines)})")
                continue
            num, den = headlines[label]
            v = ratio(bench_map, num, den)
            if v is None:
                what = f"rate missing ({num})" if den is None else f"bench pair missing ({num} / {den})"
                failures.append(f"{label}: {what}")
                continue
            need = float(min_s)
            ok = v >= need
            unit = "" if den is None else "x"
            print(f"gate {label}: {v:.2f}{unit} (need >= {need:.2f}{unit}) {'ok' if ok else 'FAIL'}")
            if not ok:
                failures.append(f"{label}: {v:.2f}{unit} < {need:.2f}{unit}")
        if failures:
            for msg in failures:
                print(f"FAIL: {msg}")
            raise SystemExit(1)
        return

    trend = load_trend(args.trend)
    runs = [r for r in trend["runs"] if r.get("commit") != args.commit]
    runs.append(
        {
            "commit": args.commit,
            "quick": bench.get("quick"),
            "benches": bench.get("benches", []),
        }
    )
    trend["runs"] = runs[-args.max_runs :]
    with open(args.trend, "w") as f:
        json.dump(trend, f, indent=1)
        f.write("\n")

    print(f"{len(trend['runs'])} run(s) in {args.trend}")
    for r in trend["runs"]:
        bench_map = {b.get("name"): b for b in r.get("benches", [])}
        cells = []
        for label, num, den in HEADLINES:
            v = ratio(bench_map, num, den)
            unit = "" if den is None else "x"
            cells.append(f"{label} {v:.2f}{unit}" if v is not None else f"{label} -")
        print(f"  {str(r.get('commit'))[:9]:>9}  " + "  ".join(cells))


if __name__ == "__main__":
    main()
