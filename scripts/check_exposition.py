#!/usr/bin/env python3
"""Validate a Prometheus text-exposition (0.0.4) scrape.

CI scrapes the live gateway at `/metrics?format=prometheus` during the
smoke/chaos runs and pipes the body through this script, which fails on
malformed exposition rather than trusting a 200 status: every sample
line must parse, every sampled family must have been announced by
`# HELP` + `# TYPE` lines first, histogram buckets must be cumulative
and monotone with a terminal `le="+Inf"` bucket equal to `_count`, and
counters must not be negative.

Gate mode: `--require-nonzero FAMILY` (repeatable) additionally asserts
that the named family has at least one sample with value > 0 — the
chaos job uses this to pin `rns_supervision_respawns_total`, proving the
scrape happened *after* the injected faults were survived, not against
an idle server.

Usage:
    python3 scripts/check_exposition.py metrics.txt
    curl -s "$URL" | python3 scripts/check_exposition.py -
    python3 scripts/check_exposition.py metrics.txt \
        --require-nonzero rns_supervision_respawns_total
"""

import argparse
import re
import sys

# sample line: name{labels} value  — labels optional, value is a float
SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{([^}]*)\})?\s+"
    r"([-+]?[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?|[+-]?Inf|NaN)$"
)
LABEL_RE = re.compile(r'^([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"$')
NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")

# sample suffixes that belong to the announced base family
TYPE_SUFFIXES = {
    "histogram": ("_bucket", "_sum", "_count"),
    "summary": ("_sum", "_count"),
    "counter": (),
    "gauge": (),
    "untyped": (),
}


def base_family(name, types):
    """Map a sample name back to its announced family, if any."""
    if name in types:
        return name
    for fam, kind in types.items():
        for suffix in TYPE_SUFFIXES.get(kind, ()):
            if name == fam + suffix:
                return fam
    return None


def parse_labels(raw):
    labels = {}
    if not raw:
        return labels
    for part in split_label_pairs(raw):
        m = LABEL_RE.match(part)
        if m is None:
            raise ValueError(f"bad label pair `{part}`")
        labels[m.group(1)] = m.group(2)
    return labels


def split_label_pairs(raw):
    """Split `a="x",b="y"` on commas outside quoted values."""
    parts, cur, in_quotes, escaped = [], "", False, False
    for ch in raw:
        if escaped:
            cur += ch
            escaped = False
        elif ch == "\\":
            cur += ch
            escaped = True
        elif ch == '"':
            cur += ch
            in_quotes = not in_quotes
        elif ch == "," and not in_quotes:
            parts.append(cur)
            cur = ""
        else:
            cur += ch
    if cur:
        parts.append(cur)
    return parts


def check(text, require_nonzero):
    errors = []
    types = {}  # family -> type
    helped = set()
    # (family, non-le labels) -> [(le, value)], plus _count per series
    buckets = {}
    counts = {}
    family_max = {}  # family -> max sample value seen

    for lineno, line in enumerate(text.splitlines(), 1):
        if not line or line.isspace():
            continue
        if line.startswith("# HELP "):
            rest = line[len("# HELP ") :]
            fam = rest.split(" ", 1)[0]
            if not NAME_RE.match(fam):
                errors.append(f"line {lineno}: bad HELP family name `{fam}`")
            elif fam in helped:
                errors.append(f"line {lineno}: duplicate HELP for `{fam}`")
            helped.add(fam)
            continue
        if line.startswith("# TYPE "):
            rest = line[len("# TYPE ") :].split()
            if len(rest) != 2 or rest[1] not in TYPE_SUFFIXES:
                errors.append(f"line {lineno}: bad TYPE line `{line}`")
                continue
            fam, kind = rest
            if fam in types:
                errors.append(f"line {lineno}: duplicate TYPE for `{fam}`")
            if fam not in helped:
                errors.append(f"line {lineno}: TYPE for `{fam}` before its HELP")
            types[fam] = kind
            continue
        if line.startswith("#"):
            continue  # free-form comment
        m = SAMPLE_RE.match(line)
        if m is None:
            errors.append(f"line {lineno}: unparseable sample `{line}`")
            continue
        name, _, rawlabels, rawvalue = m.groups()
        try:
            labels = parse_labels(rawlabels)
        except ValueError as e:
            errors.append(f"line {lineno}: {e}")
            continue
        value = float(rawvalue.replace("Inf", "inf"))
        fam = base_family(name, types)
        if fam is None:
            errors.append(f"line {lineno}: sample `{name}` has no HELP/TYPE")
            continue
        kind = types[fam]
        if kind == "counter" and value < 0:
            errors.append(f"line {lineno}: counter `{name}` is negative")
        family_max[fam] = max(family_max.get(fam, float("-inf")), value)
        if kind == "histogram":
            rest_labels = tuple(
                sorted((k, v) for k, v in labels.items() if k != "le")
            )
            series = (fam, rest_labels)
            if name == fam + "_bucket":
                if "le" not in labels:
                    errors.append(f"line {lineno}: bucket without `le` label")
                    continue
                le = float(labels["le"].replace("Inf", "inf"))
                buckets.setdefault(series, []).append((le, value, lineno))
            elif name == fam + "_count":
                counts[series] = (value, lineno)

    for series, bs in buckets.items():
        fam, labels = series
        where = f"`{fam}`" + (f" {dict(labels)}" if labels else "")
        prev = -1.0
        for le, value, lineno in bs:
            if value < prev:
                errors.append(
                    f"line {lineno}: {where} bucket le={le} not cumulative "
                    f"({value} < {prev})"
                )
            prev = value
        les = [le for le, _, _ in bs]
        if les != sorted(les):
            errors.append(f"{where}: bucket `le` bounds out of order")
        if not les or les[-1] != float("inf"):
            errors.append(f"{where}: missing terminal le=\"+Inf\" bucket")
        elif series in counts and bs[-1][1] != counts[series][0]:
            errors.append(
                f"{where}: +Inf bucket {bs[-1][1]} != _count {counts[series][0]}"
            )
        if series not in counts:
            errors.append(f"{where}: histogram without a _count sample")

    for fam in require_nonzero:
        if fam not in types:
            errors.append(f"--require-nonzero: family `{fam}` not exposed")
        elif family_max.get(fam, 0) <= 0:
            errors.append(f"--require-nonzero: `{fam}` has no sample > 0")

    n_samples = sum(1 for l in text.splitlines() if l and not l.startswith("#"))
    return errors, len(types), n_samples


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="exposition file, or `-` for stdin")
    ap.add_argument(
        "--require-nonzero",
        action="append",
        default=[],
        metavar="FAMILY",
        help="fail unless FAMILY has a sample with value > 0 (repeatable)",
    )
    args = ap.parse_args()
    if args.path == "-":
        text = sys.stdin.read()
    else:
        with open(args.path) as f:
            text = f.read()
    errors, n_families, n_samples = check(text, args.require_nonzero)
    if errors:
        for e in errors:
            print(f"exposition error: {e}", file=sys.stderr)
        sys.exit(1)
    if n_families == 0:
        print("exposition error: no metric families found", file=sys.stderr)
        sys.exit(1)
    print(f"exposition OK: {n_families} families, {n_samples} samples")


if __name__ == "__main__":
    main()
