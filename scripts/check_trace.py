#!/usr/bin/env python3
"""Validate a Chrome trace-event export from `GET /trace?format=chrome`.

CI fetches the live gateway's span-trace export mid-run and pipes the
body through this script, which fails on malformed output rather than
trusting a 200 status: the body must be a JSON array; every `"ph":"X"`
complete event must carry numeric `ts`/`dur`, an integer `tid`, a
non-empty `name`, and an `args.trace` id to group by; within each trace
the synthesized `session` span must contain every other span, and the
four compute-stage spans must nest inside their worker `batch` span.

Gate mode: at least `--min-traces` traces (default 1) must carry the
full serving pipeline — session, assemble, admission, queue,
batch_form, batch, dac_forward, analog_gemm, adc_capture and decode —
proving a sampled request actually traversed every tier during the
smoke run, not just that the endpoint returned syntactically valid
JSON.  (`delivery` and `write_flush` are deliberately not required:
both are recorded after the reply is in flight and may lose their
benign race with trace completion.)

Usage:
    python3 scripts/check_trace.py trace.json
    curl -s "$URL/trace?format=chrome" | python3 scripts/check_trace.py -
"""

import argparse
import json
import sys

# every trace that counts toward --min-traces must carry all of these
PIPELINE_STAGES = (
    "session",
    "assemble",
    "admission",
    "queue",
    "batch_form",
    "batch",
    "dac_forward",
    "analog_gemm",
    "adc_capture",
    "decode",
)
COMPUTE_STAGES = ("dac_forward", "analog_gemm", "adc_capture", "decode")


def check_event(i, ev, errors):
    """Structural checks on one event; returns True if usable as a span."""
    if not isinstance(ev, dict):
        errors.append(f"event {i}: not an object")
        return False
    ph = ev.get("ph")
    if ph not in ("X", "M"):
        errors.append(f"event {i}: unknown phase `{ph}`")
        return False
    if not isinstance(ev.get("tid"), int):
        errors.append(f"event {i}: missing integer `tid`")
        return False
    if ph == "M":
        return False  # thread-name metadata: valid, but not a span
    name = ev.get("name")
    if not isinstance(name, str) or not name:
        errors.append(f"event {i}: X event without a name")
        return False
    for key in ("ts", "dur"):
        v = ev.get(key)
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
            errors.append(f"event {i} ({name}): bad `{key}` {v!r}")
            return False
    trace = ev.get("args", {}).get("trace") if isinstance(ev.get("args"), dict) else None
    if not isinstance(trace, str) or not trace.startswith("0x"):
        errors.append(f"event {i} ({name}): missing `args.trace` id")
        return False
    return True


def within(inner, outer):
    return (
        inner["ts"] >= outer["ts"]
        and inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    )


def check_nesting(trace_id, spans, errors):
    by_name = {}
    for s in spans:
        by_name.setdefault(s["name"], []).append(s)
    sessions = by_name.get("session", [])
    if len(sessions) != 1:
        errors.append(f"trace {trace_id}: {len(sessions)} session roots (want 1)")
        return
    root = sessions[0]
    for s in spans:
        if not within(s, root):
            errors.append(
                f"trace {trace_id}: `{s['name']}` "
                f"[{s['ts']}..{s['ts'] + s['dur']}] escapes session "
                f"[{root['ts']}..{root['ts'] + root['dur']}]"
            )
    for batch in by_name.get("batch", []):
        for stage in COMPUTE_STAGES:
            for s in by_name.get(stage, []):
                if s["tid"] == batch["tid"] and not within(s, batch):
                    errors.append(
                        f"trace {trace_id}: `{stage}` escapes its "
                        f"worker batch span on tid {s['tid']}"
                    )


def check(text, min_traces):
    errors = []
    try:
        events = json.loads(text)
    except json.JSONDecodeError as e:
        return [f"body is not valid JSON: {e}"], 0, 0
    if not isinstance(events, list):
        return ["top-level JSON value is not an array"], 0, 0

    traces = {}  # trace id -> [span event]
    n_spans = 0
    for i, ev in enumerate(events):
        if not check_event(i, ev, errors):
            continue
        n_spans += 1
        traces.setdefault(ev["args"]["trace"], []).append(ev)

    complete = 0
    for trace_id, spans in sorted(traces.items()):
        check_nesting(trace_id, spans, errors)
        names = {s["name"] for s in spans}
        missing = [st for st in PIPELINE_STAGES if st not in names]
        if not missing:
            complete += 1
    if complete < min_traces:
        errors.append(
            f"only {complete} trace(s) carry the full pipeline "
            f"{PIPELINE_STAGES} (want >= {min_traces}); "
            f"traces seen: {sorted(traces)}"
        )
    return errors, len(traces), n_spans


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="chrome trace JSON file, or `-` for stdin")
    ap.add_argument(
        "--min-traces",
        type=int,
        default=1,
        metavar="N",
        help="fail unless N traces carry every pipeline stage (default 1)",
    )
    args = ap.parse_args()
    if args.path == "-":
        text = sys.stdin.read()
    else:
        with open(args.path) as f:
            text = f.read()
    errors, n_traces, n_spans = check(text, args.min_traces)
    if errors:
        for e in errors:
            print(f"trace error: {e}", file=sys.stderr)
        sys.exit(1)
    print(f"trace OK: {n_traces} traces, {n_spans} spans")


if __name__ == "__main__":
    main()
