//! `cargo bench` entrypoint (harness = false; the image vendors no
//! criterion, so this uses the in-house `bench::Bencher`).
//!
//! Two tiers:
//!   1. hot-path micro benches (modular GEMM serial/prepared/parallel,
//!      Barrett vs `%`, CRT, RRNS decode, quantization) — the §Perf
//!      optimization targets (DESIGN.md §7);
//!   2. one end-to-end bench per paper table/figure regenerator plus the
//!      serving path — the "regenerate the evaluation" deliverable, timed.
//!
//! Every run additionally writes machine-readable results to
//! `BENCH_gemm.json` at the repo root (the perf trajectory across PRs).
//!
//! Filter: cargo bench -- <substring>    Quick mode: cargo bench -- --quick

use rns_analog::analog::{FixedPointCore, NoiseModel, RnsCore, RnsCoreConfig};
use rns_analog::bench::Bencher;
use rns_analog::coordinator::{BackendKind, BatcherConfig, Coordinator, CoordinatorConfig};
use rns_analog::exp;
use rns_analog::nn::dataset::random_gemm_pair;
use rns_analog::nn::models::Batch;
use rns_analog::quant::{quantize_activations, quantize_weights};
use rns_analog::rns::fault_model::estimate_case_probs;
use rns_analog::rns::inject::{FaultInjector, FaultSpec};
use rns_analog::rns::moduli::{extend_moduli, paper_table1};
use rns_analog::rns::rrns::{Decode, RrnsCode};
use rns_analog::rns::{BarrettReducer, RnsContext};
use rns_analog::runtime::{
    default_artifacts_dir, ExecutionFabric, ModularGemmEngine, NativeEngine, PjrtEngine,
    PjrtRuntime, PreparedWeights,
};
use rns_analog::tensor::gemm::{gemm_f32, gemm_i64, gemm_mod};
use rns_analog::tensor::{MatF, MatI};
use rns_analog::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with("--bench")).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let filter: Vec<&String> = args.iter().filter(|a| !a.starts_with("--")).collect();
    let want = |name: &str| filter.is_empty() || filter.iter().any(|f| name.contains(f.as_str()));
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };

    micro_benches(&mut b, &want);
    serve_shaped_benches(&mut b, &want);
    gateway_benches(&mut b, &want);
    figure_benches(&mut b, &want, quick);

    println!("\n{}", b.report());

    // machine-readable perf trajectory at the repo root
    let json_path = format!("{}/../BENCH_gemm.json", env!("CARGO_MANIFEST_DIR"));
    match std::fs::write(&json_path, b.to_json(quick)) {
        Ok(()) => println!("[wrote {json_path}]"),
        Err(e) => eprintln!("[warn] could not write {json_path}: {e}"),
    }
}

fn micro_benches(b: &mut Bencher, want: &dyn Fn(&str) -> bool) {
    let mut rng = Rng::seed_from(1);
    let h = 128usize;
    let m = 63u64;
    let x = MatI::from_vec(8, h, (0..8 * h).map(|_| rng.gen_range(m) as i64).collect());
    let w = MatI::from_vec(h, h, (0..h * h).map(|_| rng.gen_range(m) as i64).collect());
    let macs = (8 * h * h) as f64;

    if want("micro/gemm_mod") {
        b.bench_with_rate("micro/gemm_mod 8x128x128 (1 channel)", macs, "MAC/s", || {
            gemm_mod(&x, &w, m)
        });
    }
    if want("micro/gemm_mod_multi") {
        // the §Perf headline pair: single-threaded unprepared baseline vs
        // the prepared + parallel engine on the same multi-channel tile
        let moduli = paper_table1(6).unwrap().to_vec();
        let xr: Vec<MatI> = moduli
            .iter()
            .map(|&mm| MatI::from_vec(8, h, (0..8 * h).map(|_| rng.gen_range(mm) as i64).collect()))
            .collect();
        let wr: Vec<MatI> = moduli
            .iter()
            .map(|&mm| MatI::from_vec(h, h, (0..h * h).map(|_| rng.gen_range(mm) as i64).collect()))
            .collect();
        let macs_multi = macs * moduli.len() as f64;
        let mut serial = NativeEngine::serial();
        b.bench_with_rate(
            "micro/gemm_mod 8x128x128 x4ch serial unprepared",
            macs_multi,
            "MAC/s",
            || serial.matmul_mod(&xr, &wr, &moduli),
        );
        let prepared = PreparedWeights::new(wr.clone(), &moduli);
        let mut serial_prep = NativeEngine::serial();
        b.bench_with_rate(
            "micro/gemm_mod 8x128x128 x4ch serial prepared",
            macs_multi,
            "MAC/s",
            || serial_prep.matmul_mod_prepared(&xr, &prepared),
        );
        let mut parallel = NativeEngine::default();
        b.bench_with_rate(
            "micro/gemm_mod 8x128x128 x4ch parallel prepared",
            macs_multi,
            "MAC/s",
            || parallel.matmul_mod_prepared(&xr, &prepared),
        );
    }
    if want("micro/pool") {
        // the PR-3 acceptance pair: persistent worker pool vs per-call
        // scoped spawns on a small-batch prepared GEMM (an MLP fc0-shaped
        // tile, where spawn latency is a visible slice of the call).  CI
        // gates pool >= scoped (no regression) next to the decode gate.
        let moduli = paper_table1(6).unwrap().to_vec();
        let (bb, k, n) = (4usize, 784usize, 256usize);
        let xr: Vec<MatI> = moduli
            .iter()
            .map(|&mm| MatI::from_vec(bb, k, (0..bb * k).map(|_| rng.gen_range(mm) as i64).collect()))
            .collect();
        let wr: Vec<MatI> = moduli
            .iter()
            .map(|&mm| MatI::from_vec(k, n, (0..k * n).map(|_| rng.gen_range(mm) as i64).collect()))
            .collect();
        let prepared = PreparedWeights::new(wr, &moduli);
        let macs_pool = (bb * k * n * moduli.len()) as f64;
        let mut scoped = NativeEngine::scoped();
        b.bench_with_rate(
            "micro/pool prepared 4x784x256 x4ch scoped-spawn",
            macs_pool,
            "MAC/s",
            || scoped.matmul_mod_prepared(&xr, &prepared),
        );
        let mut pooled = NativeEngine::default();
        b.bench_with_rate(
            "micro/pool prepared 4x784x256 x4ch persistent-pool",
            macs_pool,
            "MAC/s",
            || pooled.matmul_mod_prepared(&xr, &prepared),
        );
        // the PR-4 pair: the same GEMM through the process-wide shared
        // fabric (one worker => full helper budget, so the comparison
        // isolates the shared-pool dispatch, not a smaller budget).  CI
        // gates fabric >= scoped next to the pool gate.
        let fabric = std::sync::Arc::new(ExecutionFabric::for_workers(1));
        let mut fabbed = NativeEngine::with_fabric(fabric.handle());
        b.bench_with_rate(
            "micro/pool prepared 4x784x256 x4ch shared-fabric",
            macs_pool,
            "MAC/s",
            || fabbed.matmul_mod_prepared(&xr, &prepared),
        );
    }
    if want("micro/gemm_i64") {
        b.bench_with_rate("micro/gemm_i64 8x128x128", macs, "MAC/s", || gemm_i64(&x, &w));
    }
    if want("micro/gemm_f32") {
        let (xf, wf) = random_gemm_pair(&mut rng, 8, h, h, 1.0);
        b.bench_with_rate("micro/gemm_f32 8x128x128", macs, "MAC/s", || gemm_f32(&xf, &wf));
    }
    if want("micro/barrett") {
        let red = BarrettReducer::new(63);
        let vals: Vec<u64> = (0..4096).map(|_| rng.next_u64() >> 1).collect();
        b.bench_with_rate("micro/barrett reduce x4096", 4096.0, "Op/s", || {
            vals.iter().map(|&v| red.reduce(v)).sum::<u64>()
        });
        b.bench_with_rate("micro/native %% x4096", 4096.0, "Op/s", || {
            vals.iter().map(|&v| v % 63).sum::<u64>()
        });
    }
    if want("micro/crt") {
        let ctx = RnsContext::new(paper_table1(6).unwrap()).unwrap();
        let residues: Vec<Vec<u64>> =
            (0..1024).map(|_| ctx.forward(rng.gen_range_i64(-7_000_000, 7_000_000))).collect();
        b.bench_with_rate("micro/crt_signed x1024", 1024.0, "Op/s", || {
            residues.iter().map(|r| ctx.crt_signed(r)).sum::<i128>()
        });
    }
    if want("micro/rrns_decode") {
        let all = extend_moduli(paper_table1(8).unwrap(), 2).unwrap();
        let code = RrnsCode::new(&all, 3).unwrap();
        let words: Vec<Vec<u64>> = (0..256)
            .map(|_| {
                let mut res = code.encode(rng.gen_range_i64(-1_000_000, 1_000_000));
                if rng.bernoulli(0.1) {
                    res[1] = (res[1] + 3) % all[1];
                }
                res
            })
            .collect();
        b.bench_with_rate("micro/rrns decode x256 (10% errors)", 256.0, "Op/s", || {
            words.iter().map(|w| matches!(code.decode(w), rns_analog::rns::Decode::Ok { .. }) as u64).sum::<u64>()
        });
    }
    if want("micro/rrns decode_tile") {
        // the two-tier decode acceptance pair: per-element voting reference
        // vs the batched consistency pre-check, on the same clean tile —
        // plus the two-tier path on a tile with injected faults.  The
        // clean batched/per-element ratio is the >= 3x target tracked in
        // BENCH_gemm.json.
        let all = extend_moduli(paper_table1(8).unwrap(), 2).unwrap();
        let code = RrnsCode::new(&all, 3).unwrap();
        let half = (code.legitimate_range / 2) as i64;
        let (rows, cols) = (16usize, 64usize);
        let elems = (rows * cols) as f64;
        let values = MatI::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.gen_range_i64(-(half - 1), half)).collect(),
        );
        let clean = code.encode_tile(&values);
        let mut faulty = clean.clone();
        FaultInjector::new(FaultSpec::Bernoulli { p: 0.01 }, 42)
            .corrupt_tile(&mut faulty, &all);
        // no allocation in the timed loops beyond the small residue
        // scratch: the reference baseline feeds the CI >=3x ratio gate
        // and must not be padded with harness overhead
        fn vote_one(code: &RrnsCode, channels: &[MatI], res: &mut [u64], e: usize) -> i128 {
            for (r, ch) in res.iter_mut().zip(channels.iter()) {
                *r = ch.data[e] as u64;
            }
            match code.decode(res) {
                Decode::Ok { value, .. } => value,
                Decode::Detected => code.decode_best_effort(res),
            }
        }
        fn vote_tile(code: &RrnsCode, channels: &[MatI], only: Option<&[usize]>, len: usize) -> i128 {
            let mut res = vec![0u64; code.n()];
            let mut acc = 0i128;
            match only {
                Some(f) => {
                    for &e in f {
                        acc += vote_one(code, channels, &mut res, e);
                    }
                }
                None => {
                    for e in 0..len {
                        acc += vote_one(code, channels, &mut res, e);
                    }
                }
            }
            acc
        }
        b.bench_with_rate(
            "micro/rrns decode_tile 16x64 clean per-element",
            elems,
            "elem/s",
            || vote_tile(&code, &clean, None, rows * cols),
        );
        // the batched side pays the full two-tier shape (scratch alloc +
        // fallback walk, empty on a clean tile), not just the pre-check —
        // the CI >=3x gate must certify what decode_tile_batched does
        b.bench_with_rate("micro/rrns decode_tile 16x64 clean batched", elems, "elem/s", || {
            let pre = code.precheck_tile(&clean);
            vote_tile(&code, &clean, Some(&pre.fallback), rows * cols)
        });
        b.bench_with_rate(
            "micro/rrns decode_tile 16x64 1% faults two-tier",
            elems,
            "elem/s",
            || {
                let pre = code.precheck_tile(&faulty);
                vote_tile(&code, &faulty, Some(&pre.fallback), rows * cols)
            },
        );
    }
    if want("micro/quantize") {
        let (xf, wf) = random_gemm_pair(&mut rng, 8, 512, 512, 1.0);
        b.bench_with_rate("micro/quantize acts+weights 8x512,512x512", (8 * 512 + 512 * 512) as f64, "elem/s", || {
            (quantize_activations(&xf, 8), quantize_weights(&wf, 8))
        });
    }
    if want("micro/rns_core_gemm") {
        let (xf, wf) = random_gemm_pair(&mut rng, 8, 256, 64, 1.0);
        let mut core = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
        b.bench_with_rate("micro/rns_core gemm 8x256x64 (4ch)", (8 * 256 * 64 * 4) as f64, "MAC/s", || {
            core.gemm_quantized(&xf, &wf)
        });
        let mut fxp = FixedPointCore::new(6, 128, NoiseModel::None, 0);
        b.bench_with_rate("micro/fxp_core gemm 8x256x64", (8 * 256 * 64) as f64, "MAC/s", || {
            fxp.gemm_quantized(&xf, &wf)
        });
    }
    if want("micro/rrns_core_noisy") {
        let (xf, wf) = random_gemm_pair(&mut rng, 8, 128, 32, 1.0);
        let mut core = RnsCore::new(
            RnsCoreConfig::for_bits(8, 128)
                .with_noise(NoiseModel::ResidueFlip { p: 0.01 })
                .with_rrns(2, 3),
        )
        .unwrap();
        b.bench_with_rate("micro/rrns_core noisy gemm 8x128x32", (8 * 128 * 32 * 5) as f64, "MAC/s", || {
            core.gemm_quantized(&xf, &wf)
        });
    }
    if want("micro/sparse") {
        // 50% zero sample rows: the sparse-capture path should win by
        // skipping DAC forward, ADC capture, and CRT decode for them
        let (mut xf, wf) = random_gemm_pair(&mut rng, 16, 128, 64, 1.0);
        for r in (0..xf.rows).step_by(2) {
            xf.row_mut(r).fill(0.0);
        }
        let cfg = RnsCoreConfig::for_bits(8, 128).with_rrns(2, 2);
        let mut dense = RnsCore::new(cfg.clone()).unwrap();
        dense.prepare_weights(&wf);
        b.bench_with_rate(
            "micro/sparse rns gemm 16x128x64 50pct-zero dense-capture",
            (16 * 128 * 64 * 5) as f64,
            "MAC/s",
            || dense.gemm_quantized(&xf, &wf),
        );
        let mut sparse = RnsCore::new(cfg.with_sparse_capture(true)).unwrap();
        sparse.prepare_weights(&wf);
        b.bench_with_rate(
            "micro/sparse rns gemm 16x128x64 50pct-zero sparse-capture",
            (16 * 128 * 64 * 5) as f64,
            "MAC/s",
            || sparse.gemm_quantized(&xf, &wf),
        );
    }
    if want("micro/pjrt_engine") {
        let artifacts = default_artifacts_dir();
        if let Ok(rt) = PjrtRuntime::cpu() {
            if let Ok(mut engine) = PjrtEngine::load(&rt, &artifacts, 6) {
                let moduli = engine.moduli.clone();
                let xr: Vec<MatI> = moduli
                    .iter()
                    .map(|&mm| MatI::from_vec(8, 128, (0..8 * 128).map(|_| rng.gen_range(mm) as i64).collect()))
                    .collect();
                let wr: Vec<MatI> = moduli
                    .iter()
                    .map(|&mm| MatI::from_vec(128, 128, (0..128 * 128).map(|_| rng.gen_range(mm) as i64).collect()))
                    .collect();
                b.bench_with_rate(
                    "micro/pjrt pallas-kernel tile 8x128x128 (4ch)",
                    (8 * 128 * 128 * 4) as f64,
                    "MAC/s",
                    || engine.matmul_mod(&xr, &wr, &moduli),
                );
                let mut native = NativeEngine::default();
                b.bench_with_rate(
                    "micro/native engine tile 8x128x128 (4ch)",
                    (8 * 128 * 128 * 4) as f64,
                    "MAC/s",
                    || native.matmul_mod(&xr, &wr, &moduli),
                );
            }
        }
    }
}

/// End-to-end serving-shaped benches that need no artifacts: the MLP zoo
/// model's exact GEMM chain (784 -> 256 -> 128 -> 10) through a full
/// `RnsCore`, unprepared-serial (the seed's execution model) vs
/// prepared-parallel (the plan path every worker runs after warm).  This is
/// the e2e number BENCH_gemm.json tracks across PRs.
fn serve_shaped_benches(b: &mut Bencher, want: &dyn Fn(&str) -> bool) {
    if !want("serve/rns_mlp_chain") {
        return;
    }
    let mut rng = Rng::seed_from(7);
    let dims = [(784usize, 256usize), (256, 128), (128, 10)];
    let batch = 8usize;
    let ws: Vec<MatF> = dims
        .iter()
        .map(|&(k, n)| {
            MatF::from_vec(k, n, (0..k * n).map(|_| rng.uniform_f32(-0.5, 0.5)).collect())
        })
        .collect();
    let x0 = MatF::from_vec(
        batch,
        784,
        (0..batch * 784).map(|_| rng.uniform_f32(-1.0, 1.0)).collect(),
    );
    let samples = batch as f64;

    let mut unprep = RnsCore::with_engine(
        RnsCoreConfig::for_bits(6, 128),
        Box::new(NativeEngine::serial()),
    )
    .unwrap();
    b.bench_with_rate("serve/rns_mlp_chain b6 serial unprepared", samples, "img/s", || {
        let mut h = x0.clone();
        for w in &ws {
            h = unprep.gemm_quantized_unprepared(&h, w);
        }
        h
    });

    let mut prep = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
    for w in &ws {
        prep.prepare_weights(w); // model warm, as the coordinator does
    }
    b.bench_with_rate("serve/rns_mlp_chain b6 parallel prepared", samples, "img/s", || {
        let mut h = x0.clone();
        for w in &ws {
            h = prep.gemm_quantized(&h, w);
        }
        h
    });
}

/// The PR-5 acceptance pair: the same 24-request synthetic-MLP stream
/// through the in-process coordinator API vs over loopback TCP through
/// the gateway (4 pipelined client sessions).  Both sides pay full
/// coordinator start/drain per iteration, so the ratio isolates the
/// network tier: framing, per-session threads, response routing.  CI
/// gates gateway >= 0.2x in-process (bench_trend.py `gateway`) — the
/// wire must never cost more than the serving math.
fn gateway_benches(b: &mut Bencher, want: &dyn Fn(&str) -> bool) {
    if !want("serve/gateway") && !want("serve/coordinator 24") && !want("serve/loadgen") {
        return;
    }
    use rns_analog::net::{Client, Gateway, GatewayConfig};
    use rns_analog::nn::models::SYNTHETIC_MLP;
    use rns_analog::tensor::Nhwc;

    const REQS: usize = 24;
    const CLIENTS: usize = 4;
    let backend = BackendKind::Rns { bits: 6, redundant: 0, attempts: 1, noise: NoiseModel::None };
    let mk_cfg = || {
        let mut cfg = CoordinatorConfig::new(backend.clone(), "/nonexistent");
        cfg.workers = 2;
        cfg
    };
    let input = || Batch::Images(Nhwc::zeros(1, 28, 28, 1));

    b.bench_with_rate(
        "serve/coordinator 24 reqs synthetic-mlp rns-b6 in-process",
        REQS as f64,
        "req/s",
        || {
            let coord = Coordinator::start(mk_cfg());
            for _ in 0..REQS {
                coord.submit(SYNTHETIC_MLP, input());
            }
            let r = coord.collect(REQS);
            coord.shutdown();
            r.len()
        },
    );
    b.bench_with_rate(
        "serve/gateway loopback 24 reqs synthetic-mlp rns-b6",
        REQS as f64,
        "req/s",
        || {
            let gw_cfg = GatewayConfig { listen_addr: "127.0.0.1:0".into(), ..Default::default() };
            let gw = Gateway::start(Coordinator::start(mk_cfg()), gw_cfg).expect("gateway");
            let addr = gw.local_addr().to_string();
            let threads: Vec<_> = (0..CLIENTS)
                .map(|_| {
                    let addr = addr.clone();
                    std::thread::spawn(move || {
                        let mut client = Client::connect(&addr).expect("connect");
                        for _ in 0..REQS / CLIENTS {
                            client.submit(SYNTHETIC_MLP, &input()).expect("submit");
                        }
                        for _ in 0..REQS / CLIENTS {
                            client.recv_infer().expect("reply");
                        }
                        client.close();
                    })
                })
                .collect();
            for t in threads {
                t.join().expect("client");
            }
            gw.shutdown()
        },
    );
    // the PR-9 sustained-RPS headline: the same 24-request stream driven
    // by the loadgen harness (closed-loop, bounded window) through the
    // event-driven session layer.  bench_trend.py tracks this bench's
    // absolute rate as the `rps` headline and CI gates it — serving
    // throughput, not just kernel microbenches.
    if want("serve/loadgen") {
        b.bench_with_rate(
            "serve/loadgen 24 reqs synthetic-mlp rns-b6 event-loop",
            REQS as f64,
            "req/s",
            || {
                let gw_cfg = GatewayConfig {
                    listen_addr: "127.0.0.1:0".into(),
                    loop_threads: 2,
                    ..Default::default()
                };
                let gw = Gateway::start(Coordinator::start(mk_cfg()), gw_cfg).expect("gateway");
                let lg = rns_analog::net::LoadgenConfig {
                    addr: gw.local_addr().to_string(),
                    conns: CLIENTS,
                    requests: REQS as u64,
                    window: 8,
                    duration: std::time::Duration::from_secs(60),
                    ..rns_analog::net::LoadgenConfig::default()
                };
                let report = rns_analog::net::loadgen::run(&lg).expect("loadgen");
                assert_eq!(report.failures, 0, "loadgen bench must complete cleanly");
                gw.shutdown();
                report.ok
            },
        );
    }
}

fn figure_benches(b: &mut Bencher, want: &dyn Fn(&str) -> bool, quick: bool) {
    let artifacts = default_artifacts_dir();
    let have_models = std::path::Path::new(&format!("{artifacts}/models/mlp.rt")).exists();
    let samples = if quick { 16 } else { 48 };

    if want("exp/table1") {
        b.bench("exp/table1 regenerate", || exp::table1::run(128));
    }
    if want("exp/fig3") {
        let cfg = exp::fig3::Fig3Config {
            pairs: if quick { 100 } else { 500 },
            bits: vec![4, 6, 8],
            ..Default::default()
        };
        b.bench_with_rate("exp/fig3 error-dist (500 pairs x 3b)", (cfg.pairs * 3) as f64, "pair/s", || {
            exp::fig3::compute(&cfg)
        });
    }
    if want("exp/fig5") {
        let cfg = exp::fig5::Fig5Config {
            trials: if quick { 500 } else { 4000 },
            redundancies: vec![2],
            attempts: vec![1, 3],
            ps: vec![1e-2, 1e-1],
            ..Default::default()
        };
        b.bench("exp/fig5 p_err MC (2 p-points)", || exp::fig5::compute(&cfg));
    }
    if want("exp/fig7") {
        b.bench("exp/fig7 energy model", || exp::fig7::compute(128));
    }
    if have_models {
        if want("exp/fig1") {
            let cfg = exp::fig1::Fig1Config {
                models: vec!["cnn".into()],
                bits: vec![6],
                hs: vec![128],
                samples,
                ..exp::fig1::Fig1Config::new(&artifacts)
            };
            b.bench_with_rate(&format!("exp/fig1 cnn b=6 h=128 ({samples} imgs)"), samples as f64, "img/s", || {
                exp::fig1::compute(&cfg).unwrap()
            });
        }
        if want("exp/fig4") {
            let cfg = exp::fig4::Fig4Config {
                models: vec!["mlp".into()],
                bits: vec![6],
                samples,
                ..exp::fig4::Fig4Config::new(&artifacts)
            };
            b.bench_with_rate(&format!("exp/fig4 mlp b=6 fxp+rns ({samples} imgs)"), (2 * samples) as f64, "img/s", || {
                exp::fig4::compute(&cfg).unwrap()
            });
        }
        if want("exp/fig6") {
            let cfg = exp::fig6::Fig6Config {
                models: vec!["resnet".into()],
                redundancies: vec![2],
                attempts: vec![2],
                ps: vec![1e-2],
                samples: samples.min(24),
                ..exp::fig6::Fig6Config::new(&artifacts)
            };
            b.bench("exp/fig6 resnet rrns 1 cell (24 imgs)", || exp::fig6::compute(&cfg).unwrap());
        }
        if want("serve/") {
            b.bench_with_rate("serve/coordinator 32 reqs fp32 2 workers", 32.0, "req/s", || {
                let mut cfg = CoordinatorConfig::new(BackendKind::Fp32, &artifacts);
                cfg.workers = 2;
                cfg.batcher = BatcherConfig::default();
                let coord = Coordinator::start(cfg);
                for _ in 0..32 {
                    coord.submit(
                        "mlp",
                        Batch::Images(rns_analog::tensor::Nhwc::zeros(1, 28, 28, 1)),
                    );
                }
                let r = coord.collect(32);
                coord.shutdown();
                r.len()
            });
            b.bench_with_rate("serve/coordinator 16 reqs rns-b6 2 workers", 16.0, "req/s", || {
                let mut cfg = CoordinatorConfig::new(
                    BackendKind::Rns { bits: 6, redundant: 0, attempts: 1, noise: NoiseModel::None },
                    &artifacts,
                );
                cfg.workers = 2;
                let coord = Coordinator::start(cfg);
                for _ in 0..16 {
                    coord.submit(
                        "mlp",
                        Batch::Images(rns_analog::tensor::Nhwc::zeros(1, 28, 28, 1)),
                    );
                }
                let r = coord.collect(16);
                coord.shutdown();
                r.len()
            });
        }
    }
    if want("exp/fig5_decode_throughput") {
        // standalone decode-rate datum used in EXPERIMENTS.md §Perf
        let all = extend_moduli(paper_table1(8).unwrap(), 2).unwrap();
        let code = RrnsCode::new(&all, 3).unwrap();
        b.bench("exp/fig5 case-prob MC 2000 trials", || estimate_case_probs(&code, 0.05, 2000, 1));
    }
}
