//! Event-driven session layer at scale (loopback soak): N=1000
//! concurrent sessions served on a bounded process thread count —
//! sessions cost slab entries in the readiness loops, not
//! reader/writer thread pairs — with zero dropped replies and logits
//! bit-identical to the in-process coordinator path.  This is the
//! acceptance gate for the `net/poll.rs` session layer (ROADMAP
//! item 1); the p99 half of the gate lives in the `serve/loadgen`
//! bench + `rps` trend headline.
//!
//! `RNS_SOAK_SESSIONS` overrides N for quick local runs.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use rns_analog::analog::NoiseModel;
use rns_analog::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use rns_analog::net::protocol::{Frame, MAGIC, VERSION};
use rns_analog::net::{Client, Gateway, GatewayConfig};
use rns_analog::nn::models::{Batch, SYNTHETIC_MLP};
use rns_analog::tensor::Nhwc;
use rns_analog::util::rng::Rng;

/// Cheap backend for scale tests: no redundancy, single attempt.
fn rns_cfg(workers: usize) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::new(
        BackendKind::Rns { bits: 6, redundant: 0, attempts: 1, noise: NoiseModel::None },
        "/nonexistent",
    );
    cfg.workers = workers;
    cfg.seed = 7;
    cfg
}

fn gw_cfg(max_sessions: usize, loop_threads: usize) -> GatewayConfig {
    GatewayConfig {
        listen_addr: "127.0.0.1:0".into(),
        max_sessions,
        idle_timeout: Duration::from_secs(60),
        loop_threads,
        ..GatewayConfig::default()
    }
}

/// Deterministic single-sample input #i (16 distinct payloads reused
/// across sessions — enough to catch cross-session reply routing bugs,
/// cheap enough that the in-process reference is instant).
fn input(i: u64) -> Batch {
    let mut rng = Rng::seed_from(0xBEEF ^ (i % 16));
    Batch::Images(Nhwc::from_vec(
        1,
        28,
        28,
        1,
        (0..28 * 28).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
    ))
}

/// Process thread count from /proc (the whole point of the event loop
/// is that this stays bounded while sessions grow).
#[cfg(target_os = "linux")]
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|n| n.parse().ok())
}

#[cfg(not(target_os = "linux"))]
fn process_threads() -> Option<usize> {
    None
}

/// Soft RLIMIT_NOFILE, from /proc (std exposes no getrlimit).
#[cfg(target_os = "linux")]
fn fd_soft_limit() -> Option<usize> {
    let limits = std::fs::read_to_string("/proc/self/limits").ok()?;
    let line = limits.lines().find(|l| l.starts_with("Max open files"))?;
    line.split_whitespace().nth(3)?.parse().ok()
}

#[cfg(not(target_os = "linux"))]
fn fd_soft_limit() -> Option<usize> {
    None
}

fn soak_sessions() -> usize {
    let asked =
        std::env::var("RNS_SOAK_SESSIONS").ok().and_then(|v| v.parse().ok()).unwrap_or(1000);
    // every loopback session holds 2 fds in this one process (client end
    // + server end); clamp to the soft limit so a stock 1024-fd shell
    // still passes — CI raises the limit and runs the full 1000
    let budget = fd_soft_limit().map_or(usize::MAX, |l| l.saturating_sub(128) / 2);
    asked.min(budget)
}

/// The scale gate: 1000 concurrent loopback sessions, all open at once,
/// one pipelined inference each.  Asserts (a) every reply arrives —
/// zero drops under the readiness loops' backpressure/wakeup machinery,
/// (b) replies are bit-identical to the in-process path, (c) the
/// process thread count at peak stays bounded (≪ N — sessions are slab
/// entries, not thread pairs), and (d) the gateway's own live report
/// sees all N sessions active at once.
#[test]
fn soak_1000_sessions_bounded_threads_bit_identical() {
    let n_sessions = soak_sessions();
    const DRIVERS: usize = 8;
    let per_driver = n_sessions / DRIVERS;
    let n_sessions = per_driver * DRIVERS; // round to a driver multiple

    // in-process reference for the 16 distinct payloads
    let coord = Coordinator::start(rns_cfg(1));
    let mut ids = Vec::new();
    for i in 0..16u64 {
        ids.push(coord.submit(SYNTHETIC_MLP, input(i)));
    }
    let resps = coord.collect(16);
    let mut want: Vec<Vec<u32>> = vec![Vec::new(); 16];
    for r in &resps {
        let idx = ids.iter().position(|&id| id == r.id).expect("known id");
        let logits = r.result.as_ref().expect("in-process ok");
        want[idx] = logits.data.iter().map(|v| v.to_bits()).collect();
    }
    let want = Arc::new(want);
    coord.shutdown();

    let gw =
        Gateway::start(Coordinator::start(rns_cfg(2)), gw_cfg(n_sessions + 16, 2)).expect("gateway");
    let addr = gw.local_addr().to_string();
    // two rendezvous: all sessions open + answered, then main has
    // finished its peak-state checks and sessions may close
    let peak = Arc::new(Barrier::new(DRIVERS + 1));
    let done = Arc::new(Barrier::new(DRIVERS + 1));

    let mut threads = Vec::new();
    for d in 0..DRIVERS {
        let addr = addr.clone();
        let want = Arc::clone(&want);
        let peak = Arc::clone(&peak);
        let done = Arc::clone(&done);
        threads.push(std::thread::spawn(move || -> usize {
            // open every session first (peak concurrency), then pipeline
            // one inference through each
            let mut clients = Vec::with_capacity(per_driver);
            for _ in 0..per_driver {
                clients.push(Client::connect(&addr).expect("connect"));
            }
            let mut pending = Vec::with_capacity(per_driver);
            for (k, client) in clients.iter_mut().enumerate() {
                let i = (d * per_driver + k) as u64;
                pending.push(client.submit(SYNTHETIC_MLP, &input(i)).expect("submit"));
            }
            let mut got = 0usize;
            for (k, client) in clients.iter_mut().enumerate() {
                let i = (d * per_driver + k) as u64;
                let reply = client.recv_infer().expect("reply owed");
                assert_eq!(reply.id, pending[k], "session gets its own reply back");
                let bits: Vec<u32> = reply.logits.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(bits, want[(i % 16) as usize], "session {i}: bit-identical logits");
                got += 1;
            }
            peak.wait(); // all N sessions still open with replies in hand
            done.wait(); // main finished checking peak state
            for client in clients {
                client.close();
            }
            got
        }));
    }

    peak.wait();
    // (c) bounded thread count at peak: drivers + loops + coordinator +
    // fabric helpers land well under 256 on any sane core count, vs
    // 2*N+ for the old thread-per-session layer
    if let Some(threads_now) = process_threads() {
        assert!(
            threads_now < 256,
            "thread count must not scale with sessions: {threads_now} threads at {n_sessions} sessions"
        );
    }
    // (d) the gateway itself sees all N sessions active right now
    let report = http_get(&addr, "/metrics");
    let gw_line = report
        .lines()
        .find(|l| l.starts_with("gateway: "))
        .unwrap_or_else(|| panic!("no gateway line in:\n{report}"));
    let active: usize = gw_line
        .split_whitespace()
        .find_map(|t| t.strip_prefix("active=").and_then(|v| v.parse().ok()))
        .expect("active counter");
    assert_eq!(active, n_sessions, "all sessions concurrently active: {gw_line}");
    done.wait();

    let answered: usize = threads.into_iter().map(|t| t.join().expect("driver")).sum();
    assert_eq!(answered, n_sessions, "zero dropped replies");
    let report = gw.shutdown();
    assert!(report.contains(&format!("requests={n_sessions}")), "{report}");
    assert!(report.contains("failures=0"), "{report}");
}

fn http_get(addr: &str, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).expect("response");
    out
}

/// Raw handshake (no Client) so the tests below control exactly how
/// bytes hit the wire.
fn raw_handshake(addr: &str) -> TcpStream {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut hello = Vec::new();
    hello.extend_from_slice(&MAGIC);
    hello.extend_from_slice(&VERSION.to_le_bytes());
    s.write_all(&hello).unwrap();
    let mut reply = [0u8; 7];
    s.read_exact(&mut reply).unwrap();
    assert_eq!(&reply[..4], &MAGIC);
    assert_eq!(reply[6], 0, "hello status ok");
    s
}

/// The incremental reassembly path under adversarial framing: 64 pings
/// coalesced into one giant write (the loop must peel frame after frame
/// from one read), then one ping dripped a byte at a time (the
/// assembler must hold partial state across sweeps).
#[test]
fn coalesced_and_dripped_frames_reassemble() {
    let gw = Gateway::start(Coordinator::start(rns_cfg(1)), gw_cfg(4, 1)).expect("gateway");
    let addr = gw.local_addr().to_string();
    let mut s = raw_handshake(&addr);

    let mut blob = Vec::new();
    for id in 1..=64u64 {
        blob.extend_from_slice(&Frame::Ping { id }.encode());
    }
    s.write_all(&blob).unwrap();
    for id in 1..=64u64 {
        match Frame::read_from(&mut s).expect("pong") {
            Frame::Pong { id: got } => assert_eq!(got, id, "pipelined replies in order"),
            other => panic!("expected pong, got {other:?}"),
        }
    }

    let bytes = Frame::Ping { id: 65 }.encode();
    for &b in &bytes {
        s.write_all(&[b]).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    match Frame::read_from(&mut s).expect("pong") {
        Frame::Pong { id } => assert_eq!(id, 65),
        other => panic!("expected pong, got {other:?}"),
    }

    drop(s);
    let report = gw.shutdown();
    assert!(report.contains("failures=0"), "{report}");
}

/// The timer wheel closes idle sessions: a session that goes quiet past
/// `idle_timeout` is reaped (read returns EOF / reset), while an active
/// one keeps its deadline fresh.
#[test]
fn idle_sessions_are_reaped_by_the_timer_wheel() {
    let cfg = GatewayConfig {
        listen_addr: "127.0.0.1:0".into(),
        max_sessions: 4,
        idle_timeout: Duration::from_millis(250),
        loop_threads: 1,
        ..GatewayConfig::default()
    };
    let gw = Gateway::start(Coordinator::start(rns_cfg(1)), cfg).expect("gateway");
    let addr = gw.local_addr().to_string();

    // active session: pings every 100ms stay under the 250ms deadline
    let mut active = raw_handshake(&addr);
    let mut idle = raw_handshake(&addr);
    for id in 1..=12u64 {
        active.write_all(&Frame::Ping { id }.encode()).unwrap();
        match Frame::read_from(&mut active).expect("active session survives") {
            Frame::Pong { id: got } => assert_eq!(got, id),
            other => panic!("{other:?}"),
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    // the idle one has been quiet for ~1.2s: the server must have
    // closed it — the read sees EOF or a reset, never a hang
    let mut buf = [0u8; 1];
    match idle.read(&mut buf) {
        Ok(0) => {}
        Ok(n) => panic!("unexpected {n} bytes on an idle-reaped session"),
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            panic!("idle session still open after 4x the idle timeout")
        }
        Err(_) => {} // connection reset is also a valid reap signal
    }
    drop(active);
    gw.shutdown();
}
