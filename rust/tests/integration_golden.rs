//! Cross-language golden tests: artifacts/golden.rt is written by
//! python/compile/export_golden.py from the *python* implementations of
//! forward conversion, CRT, quantization, and RRNS decoding; these tests
//! assert the rust implementations produce identical results, pinning the
//! two languages to each other.
//!
//! Skips silently when the golden file has not been exported.

use rns_analog::nn::store;
use rns_analog::quant::quantize_activations;
use rns_analog::rns::rrns::{Decode, RrnsCode};
use rns_analog::rns::RnsContext;
use rns_analog::tensor::MatF;

const DETECTED_SENTINEL: i64 = -(1 << 62);

fn golden_path() -> String {
    format!("{}/artifacts/golden.rt", env!("CARGO_MANIFEST_DIR"))
}

fn load_golden() -> Option<store::TensorStore> {
    store::load(&golden_path()).ok()
}

#[test]
fn forward_and_crt_match_python() {
    let Some(t) = load_golden() else {
        eprintln!("skipping: golden.rt not exported");
        return;
    };
    for bits in 4..=8u32 {
        let moduli: Vec<u64> = t[&format!("b{bits}.moduli")]
            .as_i64()
            .unwrap()
            .iter()
            .map(|&m| m as u64)
            .collect();
        let ctx = RnsContext::new(&moduli).unwrap();
        let values = t[&format!("b{bits}.values")].as_i64().unwrap();
        let residues = t[&format!("b{bits}.residues")].as_i64().unwrap();
        let crt = t[&format!("b{bits}.crt")].as_i64().unwrap();
        let n = moduli.len();
        for (i, &v) in values.iter().enumerate() {
            let expect: Vec<u64> = residues[i * n..(i + 1) * n].iter().map(|&r| r as u64).collect();
            assert_eq!(ctx.forward(v), expect, "b={bits} v={v}");
            assert_eq!(ctx.crt_signed(&expect), crt[i] as i128, "b={bits} v={v}");
        }
    }
}

#[test]
fn quantization_matches_python() {
    let Some(t) = load_golden() else {
        return;
    };
    let x = t["quant.x"].as_f32().unwrap();
    let dims = t["quant.x"].dims().to_vec();
    let xq = t["quant.xq"].as_i64().unwrap();
    let scales = t["quant.scales"].as_f32().unwrap();
    let mat = MatF::from_vec(dims[0], dims[1], x.to_vec());
    let qa = quantize_activations(&mat, 8);
    assert_eq!(qa.q.data, xq, "quantized integers must match python");
    for (a, b) in qa.scales.iter().zip(scales) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }
}

#[test]
fn rrns_decode_matches_python() {
    let Some(t) = load_golden() else {
        return;
    };
    let moduli: Vec<u64> =
        t["rrns.moduli"].as_i64().unwrap().iter().map(|&m| m as u64).collect();
    let k = t["rrns.k"].as_i64().unwrap()[0] as usize;
    let code = RrnsCode::new(&moduli, k).unwrap();
    let words = t["rrns.words"].as_i64().unwrap();
    let expected = t["rrns.expected"].as_i64().unwrap();
    let n = moduli.len();
    let mut corrected = 0;
    for (i, &want) in expected.iter().enumerate() {
        let word: Vec<u64> = words[i * n..(i + 1) * n].iter().map(|&r| r as u64).collect();
        match code.decode(&word) {
            Decode::Ok { value, .. } => {
                assert_ne!(want, DETECTED_SENTINEL, "case {i}: python detected, rust decoded");
                assert_eq!(value, want as i128, "case {i}");
                corrected += 1;
            }
            Decode::Detected => {
                assert_eq!(want, DETECTED_SENTINEL, "case {i}: rust detected, python decoded");
            }
        }
    }
    assert!(corrected > 0, "golden set should contain decodable words");
}
