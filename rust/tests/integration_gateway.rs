//! TCP gateway integration tests (loopback): concurrent clients are
//! bit-identical to the in-process coordinator path (outputs, fault
//! counters, converter counts), admission control rejects overload with
//! a typed frame, malformed/truncated/oversized frames earn a typed
//! protocol error without hurting the server, graceful shutdown drains
//! every accepted request, and `GET /metrics` serves the live
//! `ServingMetrics` report with the new `gateway:` lines on top of the
//! unchanged PR-2 global lines.
//!
//! Every test serves `synthetic-mlp` (seeded in-process weights), so no
//! `make artifacts` step is needed anywhere.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use rns_analog::analog::NoiseModel;
use rns_analog::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use rns_analog::net::protocol::{checksum, ErrorCode, Frame, WireBatch, MAGIC, MAX_FRAME_LEN, VERSION};
use rns_analog::net::{Client, Gateway, GatewayConfig};
use rns_analog::nn::models::{Batch, SYNTHETIC_MLP};
use rns_analog::tensor::Nhwc;
use rns_analog::util::rng::Rng;

fn rns_cfg(workers: usize) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::new(
        BackendKind::Rns { bits: 8, redundant: 2, attempts: 2, noise: NoiseModel::None },
        "/nonexistent",
    );
    cfg.workers = workers;
    cfg.seed = 7;
    cfg
}

fn gw_cfg(max_sessions: usize) -> GatewayConfig {
    GatewayConfig {
        listen_addr: "127.0.0.1:0".into(),
        max_sessions,
        idle_timeout: Duration::from_secs(10),
        ..GatewayConfig::default()
    }
}

/// Deterministic single-sample input #i.
fn input(i: u64) -> Batch {
    let mut rng = Rng::seed_from(0xBEEF ^ i);
    Batch::Images(Nhwc::from_vec(
        1,
        28,
        28,
        1,
        (0..28 * 28).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
    ))
}

fn line_with<'a>(report: &'a str, prefix: &str) -> &'a str {
    report
        .lines()
        .find(|l| l.starts_with(prefix))
        .unwrap_or_else(|| panic!("no `{prefix}` line in report:\n{report}"))
}

/// The headline acceptance test: 8 concurrent loopback clients receive
/// results bit-identical to the in-process `Coordinator` path — same
/// logits, same decode/fault counters, same data-converter counts, same
/// plan adoptions — on an RRNS backend.
#[test]
fn concurrent_clients_are_bit_identical_to_in_process() {
    const N_CLIENTS: u64 = 8;
    const PER_CLIENT: u64 = 2;
    const TOTAL: u64 = N_CLIENTS * PER_CLIENT;

    // in-process reference (1 worker: adoption/energy totals are exact)
    let coord = Coordinator::start(rns_cfg(1));
    let mut ids = Vec::new();
    for i in 0..TOTAL {
        ids.push(coord.submit(SYNTHETIC_MLP, input(i)));
    }
    let resps = coord.collect(TOTAL as usize);
    let mut want: Vec<Vec<u32>> = vec![Vec::new(); TOTAL as usize];
    for r in &resps {
        let idx = ids.iter().position(|&id| id == r.id).expect("known id");
        let logits = r.result.as_ref().expect("in-process ok");
        assert_eq!((logits.rows, logits.cols), (1, 10));
        want[idx] = logits.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(r.faults_detected, 0, "clean RRNS run");
    }
    let want = Arc::new(want);
    let inproc_report = coord.shutdown();

    // gateway path: same backend config, N concurrent TCP clients
    let gw = Gateway::start(Coordinator::start(rns_cfg(1)), gw_cfg(16)).expect("gateway");
    let addr = gw.local_addr().to_string();
    let mut threads = Vec::new();
    for c in 0..N_CLIENTS {
        let addr = addr.clone();
        let want = Arc::clone(&want);
        threads.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for k in 0..PER_CLIENT {
                let i = c * PER_CLIENT + k;
                let reply = client.infer(SYNTHETIC_MLP, &input(i)).expect("infer");
                let got: Vec<u32> = reply.logits.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, want[i as usize], "request {i}: gateway == in-process, bit-exact");
                assert_eq!(reply.faults_detected, 0);
            }
            client.close();
        }));
    }
    for t in threads {
        t.join().expect("client thread");
    }
    let gw_report = gw.shutdown();

    // the serving counters agree line for line: decode split, fault
    // totals, converter counts (energy), plan adoptions
    for prefix in ["decode: ", "faults: ", "energy: ", "layer plans built="] {
        assert_eq!(
            line_with(&inproc_report, prefix),
            line_with(&gw_report, prefix),
            "`{prefix}` line must match between paths\n--- in-process:\n{inproc_report}\n\
             --- gateway:\n{gw_report}"
        );
    }
    assert!(gw_report.contains(&format!("requests={TOTAL}")), "{gw_report}");
    assert!(gw_report.contains("failures=0"), "{gw_report}");
    assert!(line_with(&gw_report, "gateway: ").contains("sessions=8"), "{gw_report}");
}

#[test]
fn overload_beyond_max_sessions_is_rejected_with_typed_frame() {
    let gw = Gateway::start(Coordinator::start(rns_cfg(1)), gw_cfg(2)).expect("gateway");
    let addr = gw.local_addr().to_string();

    let mut c1 = Client::connect(&addr).expect("first session");
    let c2 = Client::connect(&addr).expect("second session");
    let refused = Client::connect(&addr);
    let err = refused.err().expect("third session must be refused");
    assert!(err.contains("Overloaded"), "typed overload status in: {err}");
    assert!(err.contains("capacity (2 sessions)"), "server's reason in: {err}");

    // admitted sessions still work at the cap
    c1.ping().expect("admitted session alive");
    // freeing a slot re-admits: close one, retry until the session
    // thread's guard releases the slot
    c2.close();
    let mut readmitted = None;
    for _ in 0..100 {
        match Client::connect(&addr) {
            Ok(c) => {
                readmitted = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let mut c4 = readmitted.expect("slot frees after a session closes");
    c4.ping().expect("readmitted session alive");
    c1.close();
    c4.close();

    let report = gw.shutdown();
    let gw_line = line_with(&report, "gateway: ");
    let rejects: u64 = gw_line
        .split_whitespace()
        .find_map(|t| t.strip_prefix("rejects=").and_then(|v| v.parse().ok()))
        .expect("rejects counter");
    assert!(rejects >= 1, "{report}");
}

/// Raw-socket handshake helper for the fuzz cases.
fn raw_handshake(addr: &str) -> TcpStream {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut hello = Vec::new();
    hello.extend_from_slice(&MAGIC);
    hello.extend_from_slice(&VERSION.to_le_bytes());
    s.write_all(&hello).unwrap();
    let mut reply = [0u8; 7];
    s.read_exact(&mut reply).unwrap();
    assert_eq!(&reply[..4], &MAGIC);
    assert_eq!(reply[6], 0, "hello status ok");
    s
}

fn expect_protocol_error(s: &mut TcpStream) {
    match Frame::read_from(s).expect("typed reply before close") {
        Frame::Error { code, .. } => assert_eq!(code, ErrorCode::Protocol),
        other => panic!("expected protocol error, got {other:?}"),
    }
}

#[test]
fn malformed_frames_get_typed_errors_and_server_stays_healthy() {
    let gw = Gateway::start(Coordinator::start(rns_cfg(1)), gw_cfg(8)).expect("gateway");
    let addr = gw.local_addr().to_string();

    // oversized declared length: typed error, session closes (nothing
    // beyond the length is written — the server closes with no unread
    // bytes, so the error frame is not raced by a TCP reset)
    {
        let mut s = raw_handshake(&addr);
        s.write_all(&((MAX_FRAME_LEN as u32) + 1).to_le_bytes()).unwrap();
        expect_protocol_error(&mut s);
        let mut rest = Vec::new();
        assert_eq!(s.read_to_end(&mut rest).unwrap_or(0), 0, "session closed after the error");
    }
    // corrupted checksum
    {
        let mut s = raw_handshake(&addr);
        let mut bytes = Frame::Ping { id: 1 }.encode();
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF;
        s.write_all(&bytes).unwrap();
        expect_protocol_error(&mut s);
    }
    // unknown frame kind (valid length + checksum)
    {
        let mut s = raw_handshake(&addr);
        let mut body = vec![99u8];
        body.extend_from_slice(&1u64.to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&(body.len() as u32).to_le_bytes());
        let sum = checksum(&body);
        bytes.extend_from_slice(&body);
        bytes.extend_from_slice(&sum.to_le_bytes());
        s.write_all(&bytes).unwrap();
        expect_protocol_error(&mut s);
    }
    // a reply kind sent to the server
    {
        let mut s = raw_handshake(&addr);
        s.write_all(&Frame::Pong { id: 4 }.encode()).unwrap();
        expect_protocol_error(&mut s);
    }
    // truncated frame then hard close: no reply owed, server survives
    {
        let mut s = raw_handshake(&addr);
        let bytes = Frame::Ping { id: 5 }.encode();
        s.write_all(&bytes[..bytes.len() - 3]).unwrap();
        drop(s);
    }
    // declared batch shape contradicting the payload: typed error but
    // the framing is intact, so the *same session* keeps working
    {
        let mut s = raw_handshake(&addr);
        let frame = Frame::Infer {
            id: 6,
            model: SYNTHETIC_MLP.into(),
            deadline_ms: 0,
            input: WireBatch::Images { n: 2, h: 28, w: 28, c: 1, data: vec![0.0; 13] },
            trace_id: 0,
        };
        s.write_all(&frame.encode()).unwrap();
        expect_protocol_error(&mut s);
        s.write_all(&Frame::Ping { id: 7 }.encode()).unwrap();
        match Frame::read_from(&mut s).expect("session survived the shape error") {
            Frame::Pong { id } => assert_eq!(id, 7),
            other => panic!("{other:?}"),
        }
    }

    // after all that abuse a normal client still gets served
    let mut client = Client::connect(&addr).expect("healthy server");
    client.ping().expect("ping");
    let reply = client.infer(SYNTHETIC_MLP, &input(0)).expect("infer");
    assert_eq!((reply.logits.rows, reply.logits.cols), (1, 10));
    client.close();

    let report = gw.shutdown();
    let gw_line = line_with(&report, "gateway: ");
    let errors: u64 = gw_line
        .split_whitespace()
        .find_map(|t| t.strip_prefix("protocol-errors=").and_then(|v| v.parse().ok()))
        .expect("protocol-errors counter");
    assert!(errors >= 5, "every fuzz case counted: {report}");
    assert!(report.contains("failures=0"), "{report}");
}

/// Graceful shutdown loses zero accepted requests: clients pipeline a
/// burst, prove the server has read every frame (a reply to the last
/// submitted id — the session reader is sequential), then shutdown races
/// the remaining in-flight replies.  Every accepted request must still
/// be answered.
#[test]
fn graceful_shutdown_drains_every_accepted_request() {
    const N_CLIENTS: usize = 4;
    const PER_CLIENT: usize = 8;

    let gw = Gateway::start(Coordinator::start(rns_cfg(2)), gw_cfg(8)).expect("gateway");
    let addr = gw.local_addr().to_string();
    let barrier = Arc::new(Barrier::new(N_CLIENTS + 1));

    let mut threads = Vec::new();
    for c in 0..N_CLIENTS {
        let addr = addr.clone();
        let barrier = Arc::clone(&barrier);
        threads.push(std::thread::spawn(move || -> usize {
            let mut client = Client::connect(&addr).expect("connect");
            let mut ids = Vec::new();
            for k in 0..PER_CLIENT {
                ids.push(client.submit(SYNTHETIC_MLP, &input((c * PER_CLIENT + k) as u64)).unwrap());
            }
            let last = *ids.last().unwrap();
            let mut got = Vec::new();
            // any reply to `last` proves the reader consumed all frames
            while !got.contains(&last) {
                let r = client.recv_infer().expect("reply before shutdown");
                assert_eq!(r.logits.cols, 10);
                got.push(r.id);
            }
            barrier.wait(); // main now starts the shutdown race
            while got.len() < PER_CLIENT {
                let r = client.recv_infer().expect("reply owed by the drain");
                got.push(r.id);
            }
            got.sort_unstable();
            let mut want = ids;
            want.sort_unstable();
            assert_eq!(got, want, "every accepted request answered exactly once");
            got.len()
        }));
    }

    barrier.wait();
    let report = gw.shutdown();
    let mut answered = 0usize;
    for t in threads {
        answered += t.join().expect("client thread");
    }
    assert_eq!(answered, N_CLIENTS * PER_CLIENT, "zero lost replies");
    assert!(report.contains(&format!("requests={}", N_CLIENTS * PER_CLIENT)), "{report}");
    assert!(report.contains("failures=0"), "{report}");
}

#[test]
fn http_metrics_scrape_serves_live_report() {
    let gw = Gateway::start(Coordinator::start(rns_cfg(1)), gw_cfg(4)).expect("gateway");
    let addr = gw.local_addr().to_string();

    // some traffic first so the report is non-trivial
    let mut client = Client::connect(&addr).expect("connect");
    client.infer(SYNTHETIC_MLP, &input(1)).expect("infer");

    let scrape = |method: &str, path: &str| -> String {
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(s, "{method} {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
            .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).expect("response");
        out
    };

    let ok = scrape("GET", "/metrics");
    assert!(ok.starts_with("HTTP/1.1 200 OK"), "{ok}");
    assert!(ok.contains("Content-Type: text/plain"), "{ok}");
    // PR-2 global lines unchanged for old parsers...
    assert!(ok.contains("requests=1"), "{ok}");
    assert!(ok.contains("decode: fast-path="), "{ok}");
    assert!(ok.contains("faults: detected=0 corrected=0"), "{ok}");
    // ...plus the new gateway block
    assert!(ok.contains("gateway: sessions=1 active=1"), "{ok}");
    assert!(ok.contains("gateway latency: p50="), "{ok}");

    // query-string routing: same path, Prometheus exposition body
    let prom = scrape("GET", "/metrics?format=prometheus");
    assert!(prom.starts_with("HTTP/1.1 200 OK"), "{prom}");
    assert!(prom.contains("Content-Type: text/plain; version=0.0.4"), "{prom}");
    assert!(prom.contains("# TYPE rns_requests_total counter"), "{prom}");

    // HEAD: status + headers only, no body after the blank line
    let head = scrape("HEAD", "/metrics");
    assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
    let (headers, body) = head.split_once("\r\n\r\n").expect("header terminator");
    assert!(headers.contains("Content-Length: "), "{head}");
    assert!(body.is_empty(), "HEAD must carry no body: {head}");

    let missing = scrape("GET", "/nope");
    assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

    // scrapes are exempt from admission and counted separately —
    // every HTTP request counts, hit or miss, GET or HEAD
    client.close();
    let report = gw.shutdown();
    assert!(line_with(&report, "gateway: ").contains("scrapes=4"), "{report}");
}

#[test]
fn admin_frames_stats_load_unload_shutdown_roundtrip() {
    let gw = Gateway::start(Coordinator::start(rns_cfg(1)), gw_cfg(4)).expect("gateway");
    let addr = gw.local_addr().to_string();

    let mut client = Client::connect(&addr).expect("connect");
    client.ping().expect("ping");
    // load before traffic, serve, then proactively unload
    let info = client.load_model(SYNTHETIC_MLP).expect("load");
    assert!(info.contains("loaded"), "{info}");
    assert!(client.load_model("no-such-model").is_err(), "unknown model load must fail typed");
    client.infer(SYNTHETIC_MLP, &input(3)).expect("infer");
    let info = client.unload_model(SYNTHETIC_MLP).expect("unload");
    assert!(info.contains("unloaded"), "{info}");
    // a request after the unload reloads transparently
    client.infer(SYNTHETIC_MLP, &input(4)).expect("infer after unload");
    let stats = client.stats().expect("stats");
    assert!(stats.contains("requests=2"), "{stats}");
    assert!(stats.contains("unloads: proactive=1"), "{stats}");
    assert!(stats.contains("gateway: sessions=1"), "{stats}");

    // remote shutdown request: acked, then the server-side wait fires
    let info = client.shutdown_server().expect("shutdown frame");
    assert!(info.contains("draining"), "{info}");
    assert!(gw.wait_shutdown(Some(Duration::from_secs(10))), "shutdown signal received");
    client.close();
    let report = gw.shutdown();
    assert!(report.contains("failures=0"), "{report}");
}

/// With `admin_token` configured, admin frames need the token even from
/// loopback; inference never does.  Wrong/missing tokens earn a typed
/// `Unauthorized` and the session stays usable.
#[test]
fn admin_frames_require_the_configured_token() {
    let mut cfg = gw_cfg(4);
    cfg.admin_token = Some("hunter2".into());
    let gw = Gateway::start(Coordinator::start(rns_cfg(1)), cfg).expect("gateway");
    let addr = gw.local_addr().to_string();

    let mut client = Client::connect(&addr).expect("connect");
    // no token: typed reject, even from loopback
    let err = client.load_model(SYNTHETIC_MLP).expect_err("load without token");
    assert!(err.contains("Unauthorized"), "typed code in: {err}");
    let err = client.shutdown_server().expect_err("shutdown without token");
    assert!(err.contains("Unauthorized"), "{err}");
    // wrong token: same reject
    client.set_admin_token("wrong");
    let err = client.unload_model(SYNTHETIC_MLP).expect_err("unload with wrong token");
    assert!(err.contains("Unauthorized"), "{err}");
    // inference needs no token, and the session survived the rejects
    client.infer(SYNTHETIC_MLP, &input(9)).expect("infer without token");
    // right token: admin frames work
    client.set_admin_token("hunter2");
    let info = client.load_model(SYNTHETIC_MLP).expect("load with token");
    assert!(info.contains("loaded"), "{info}");
    let info = client.shutdown_server().expect("shutdown with token");
    assert!(info.contains("draining"), "{info}");
    assert!(gw.wait_shutdown(Some(Duration::from_secs(10))));
    client.close();
    let report = gw.shutdown();
    assert!(report.contains("failures=0"), "{report}");
}
