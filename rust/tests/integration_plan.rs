//! Integration tests for the prepared-execution subsystem (runtime/plan.rs
//! + the parallel native engine): the prepared path must be bit-identical
//! to the unprepared reference across every configuration axis, and the
//! parallel engine must reproduce the serial engine's noisy outputs
//! exactly under a fixed seed — parallelism only touches the exact modular
//! arithmetic, never the rng stream.

use rns_analog::analog::{NoiseModel, RnsCore, RnsCoreConfig};
use rns_analog::rns::paper_table1;
use rns_analog::runtime::{ModularGemmEngine, NativeEngine, PreparedWeights, RnsPlan};
use rns_analog::tensor::MatF;
use rns_analog::util::prop::{prop_assert_eq, run_prop};
use rns_analog::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> MatF {
    MatF::from_vec(rows, cols, (0..rows * cols).map(|_| rng.uniform_f32(-scale, scale)).collect())
}

/// Prepared vs unprepared outputs are bit-identical across
/// (bits, moduli set, RRNS on/off, noise on/off, tiling).
#[test]
fn prop_prepared_bit_identical_to_unprepared() {
    run_prop("prepared == unprepared", 24, |rng| {
        let bits = [4u32, 5, 6, 7, 8][rng.gen_range(5) as usize];
        // b=4's Table-I set {15,14,13,11} has no coprime headroom left for
        // redundant moduli, so RRNS only applies from b=5 up
        let rrns = bits >= 5 && rng.bernoulli(0.4);
        let noisy = rng.bernoulli(0.5);
        let b = 1 + rng.gen_range(4) as usize;
        let k = 1 + rng.gen_range(300) as usize; // 1..=300: 1-3 tiles at h=128
        let n = 1 + rng.gen_range(10) as usize;
        let seed = rng.next_u64();
        let x = rand_mat(rng, b, k, 1.0);
        let w = rand_mat(rng, k, n, 0.5);
        let mk_cfg = || {
            let mut cfg = RnsCoreConfig::for_bits(bits, 128).with_seed(seed);
            if noisy {
                cfg = cfg.with_noise(NoiseModel::ResidueFlip { p: 0.02 });
            }
            if rrns {
                cfg = cfg.with_rrns(2, 3);
            }
            cfg
        };
        // two cores with the same seed: same rng stream on both paths
        let mut prepared = RnsCore::new(mk_cfg()).unwrap();
        let mut unprepared = RnsCore::new(mk_cfg()).unwrap();
        let ya = prepared.gemm_quantized(&x, &w);
        let yb = unprepared.gemm_quantized_unprepared(&x, &w);
        prop_assert_eq(
            ya.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            yb.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            &format!("bits={bits} rrns={rrns} noisy={noisy} k={k}"),
        )
    });
}

/// The parallel engine reproduces the serial engine's noisy outputs
/// exactly under a fixed seed (determinism is independent of scheduling).
#[test]
fn parallel_engine_deterministic_vs_serial_under_noise() {
    let mut rng = Rng::seed_from(1);
    // large enough that every tile clears the engine's parallel threshold
    // (16 rows x 128 tile-K x 64 cols x >=3 channels > 2^18 MACs)
    let x = rand_mat(&mut rng, 16, 256, 1.0);
    let w = rand_mat(&mut rng, 256, 64, 0.5);
    for (redundant, attempts) in [(0usize, 1u32), (2, 3)] {
        let mk_cfg = || {
            RnsCoreConfig::for_bits(8, 128)
                .with_noise(NoiseModel::ResidueFlip { p: 0.03 })
                .with_rrns(redundant, attempts)
                .with_seed(99)
        };
        let mut serial =
            RnsCore::with_engine(mk_cfg(), Box::new(NativeEngine::serial())).unwrap();
        let mut parallel =
            RnsCore::with_engine(mk_cfg(), Box::new(NativeEngine::with_threads(4))).unwrap();
        let ys = serial.gemm_quantized(&x, &w);
        let yp = parallel.gemm_quantized(&x, &w);
        assert_eq!(
            ys.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            yp.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "rrns={redundant}: parallel engine must be bit-identical to serial"
        );
        // and a re-run with the same seed reproduces itself
        let mut again =
            RnsCore::with_engine(mk_cfg(), Box::new(NativeEngine::with_threads(4))).unwrap();
        assert_eq!(again.gemm_quantized(&x, &w).data, yp.data);
    }
}

/// A plan built explicitly and executed via `gemm_with_plan` matches the
/// implicit per-weight cache — the coordinator's warm path is the same
/// computation.
#[test]
fn explicit_plan_matches_cached_path() {
    let mut rng = Rng::seed_from(2);
    let x = rand_mat(&mut rng, 5, 200, 1.0);
    let w = rand_mat(&mut rng, 200, 7, 0.5);
    let mut a = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
    let mut b = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
    let plan = RnsPlan::build(&w, 6, 128, paper_table1(6).unwrap());
    let ya = a.gemm_with_plan(&x, &plan);
    let yb = b.gemm_quantized(&x, &w);
    assert_eq!(ya.data, yb.data);
}

/// The default-fallback `matmul_mod_prepared` (what a non-native engine
/// inherits) agrees with the native staged override.
#[test]
fn prepared_default_fallback_matches_native_override() {
    struct FallbackOnly(NativeEngine);
    impl ModularGemmEngine for FallbackOnly {
        fn matmul_mod(
            &mut self,
            x: &[rns_analog::tensor::MatI],
            w: &[rns_analog::tensor::MatI],
            m: &[u64],
        ) -> Vec<rns_analog::tensor::MatI> {
            self.0.matmul_mod(x, w, m)
        }
        // no matmul_mod_prepared override: exercises the trait default
        fn name(&self) -> &'static str {
            "fallback"
        }
    }

    let moduli = paper_table1(6).unwrap();
    let mut rng = Rng::seed_from(3);
    let mk = |rng: &mut Rng, rows: usize, cols: usize, m: u64| {
        rns_analog::tensor::MatI::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.gen_range(m) as i64).collect(),
        )
    };
    let xr: Vec<_> = moduli.iter().map(|&m| mk(&mut rng, 6, 64, m)).collect();
    let wr: Vec<_> = moduli.iter().map(|&m| mk(&mut rng, 64, 9, m)).collect();
    let prepared = PreparedWeights::new(wr.clone(), moduli);
    let want = NativeEngine::default().matmul_mod_prepared(&xr, &prepared);
    let got = FallbackOnly(NativeEngine::default()).matmul_mod_prepared(&xr, &prepared);
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.data, w.data);
    }
}
