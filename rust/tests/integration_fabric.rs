//! Integration tests for the process-wide execution fabric and the
//! worker control plane (PR 4): W coordinator workers must share ONE
//! pool of fan-out threads bounded by cores − 1 (no per-worker pools
//! oversubscribing many-core hosts), concurrent engines must interleave
//! on the shared claim queue without deadlock, and
//! `Coordinator::unload_model` must proactively release worker-held
//! model Arcs through the control channel — without the model ever
//! being requested again.
//!
//! Artifact-dependent tests skip silently when `make artifacts` has not
//! run (same convention as the coordinator tests).

use std::sync::Arc;
use std::time::Duration;

use rns_analog::analog::NoiseModel;
use rns_analog::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use rns_analog::nn::models::Batch;
use rns_analog::runtime::{ExecutionFabric, ModularGemmEngine, NativeEngine, PreparedWeights};
use rns_analog::tensor::{MatI, Nhwc};
use rns_analog::util::rng::Rng;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(&format!("{}/models/mlp.rt", artifacts_dir())).exists()
}

fn rns_cfg(workers: usize) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::new(
        BackendKind::Rns { bits: 6, redundant: 0, attempts: 1, noise: NoiseModel::None },
        &artifacts_dir(),
    );
    cfg.workers = workers;
    cfg
}

/// The PR-3 follow-up that motivated the fabric: W=4 workers previously
/// parked 4 × (cores − 1) helpers; on the fabric the process-wide helper
/// count is bounded by cores − 1 regardless of W (the strict equality
/// below is that bound plus "sized to the machine, not per worker").
/// No artifacts needed — the fabric (and its threads) exist from
/// coordinator startup.
#[test]
fn four_workers_share_one_bounded_helper_pool() {
    let coord = Coordinator::start(rns_cfg(4));
    let fabric = coord.fabric().expect("native RNS backend builds a fabric");
    let stats = fabric.stats();
    let total = rns_analog::runtime::fabric::default_total_threads();
    assert_eq!(
        stats.helper_threads,
        total - 1,
        "one shared pool at machine width (cores-1 helpers), not one pool per worker"
    );
    assert_eq!(stats.workers, 4);
    // budget math: each of the W workers may claim at most
    // ceil(helpers / W) helpers per job, and at least one when any exist
    let want_budget =
        if stats.helper_threads == 0 { 0 } else { stats.helper_threads.div_ceil(4) };
    assert_eq!(stats.budget, want_budget);
    coord.shutdown();
}

/// Fp32 / fixed-point backends never touch the native parallel engine:
/// no fabric, no fan-out threads.
#[test]
fn non_native_backends_build_no_fabric() {
    let coord = Coordinator::start(CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent"));
    assert!(coord.fabric().is_none());
    coord.shutdown();
}

/// Four engines on four threads, one fabric: concurrent prepared GEMMs
/// interleave on the shared claim queue (per-worker budgets), nobody
/// deadlocks (the submitter always participates in its own job), and
/// every result is bit-identical to a serial engine.
#[test]
fn concurrent_engines_interleave_on_one_fabric() {
    let fabric = Arc::new(ExecutionFabric::with_threads(4, 4)); // budget 1 per worker
    let moduli = [255u64, 254, 253, 251];
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let handle = fabric.handle();
            let moduli = moduli;
            s.spawn(move || {
                let mut rng = Rng::seed_from(100 + t);
                let xr: Vec<MatI> = moduli
                    .iter()
                    .map(|&m| {
                        MatI::from_vec(
                            16,
                            128,
                            (0..16 * 128).map(|_| rng.gen_range(m) as i64).collect(),
                        )
                    })
                    .collect();
                let wr: Vec<MatI> = moduli
                    .iter()
                    .map(|&m| {
                        MatI::from_vec(
                            128,
                            64,
                            (0..128 * 64).map(|_| rng.gen_range(m) as i64).collect(),
                        )
                    })
                    .collect();
                let prepared = PreparedWeights::new(wr.clone(), &moduli);
                let want = NativeEngine::serial().matmul_mod_prepared(&xr, &prepared);
                let mut eng = NativeEngine::with_fabric(handle);
                for round in 0..8 {
                    let got = eng.matmul_mod_prepared(&xr, &prepared);
                    for (g, w) in got.iter().zip(&want) {
                        assert_eq!(g.data, w.data, "worker {t} round {round}");
                    }
                }
            });
        }
    });
    let stats = fabric.stats();
    assert!(stats.jobs > 0, "fan-outs must have routed through the fabric");
    assert_eq!(stats.helper_threads, 3, "3 helpers total for 4 workers — no per-worker pools");
}

/// The control plane releases worker-held model instances without the
/// model being requested again: after `unload_model` returns (all
/// workers acked), the only strong count left on the instance is the
/// test's own clone, the plans are gone, and the draining state has been
/// ended by the acks — a later request reloads and serves normally.
#[test]
fn proactive_unload_releases_worker_arcs_without_another_request() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(rns_cfg(2));
    for _ in 0..6 {
        coord.submit("mlp", Batch::Images(Nhwc::zeros(1, 28, 28, 1)));
    }
    let resps = coord.collect(6);
    assert!(resps.iter().all(|r| r.result.is_ok()));

    let instance = coord.model_registry().peek("mlp").expect("mlp loaded");
    assert!(
        Arc::strong_count(&instance) >= 3,
        "registry + serving worker(s) must hold the instance, got {}",
        Arc::strong_count(&instance)
    );
    assert_eq!(coord.plan_store().stats().resident_plans, 3);

    let evicted = coord.unload_model("mlp");
    assert_eq!(evicted, 3, "all three layer plans evicted");
    // the acceptance property: every worker dropped its Arc on the
    // control ack — no request for `mlp` happened since the unload
    assert_eq!(
        Arc::strong_count(&instance),
        1,
        "only the test clone survives a proactive unload"
    );
    assert_eq!(coord.plan_store().stats().resident_plans, 0);
    assert!(
        !coord.plan_store().is_draining("mlp"),
        "full ack set ends the draining state without a re-warm"
    );

    // the name still serves: a later request reloads fresh weights and
    // re-warms fresh plans
    coord.submit("mlp", Batch::Images(Nhwc::zeros(1, 28, 28, 1)));
    let r = coord.recv_timeout(Duration::from_secs(60)).expect("response after reload");
    assert!(r.result.is_ok());
    let reloaded = coord.model_registry().peek("mlp").expect("reloaded");
    assert!(!Arc::ptr_eq(&instance, &reloaded), "reload is a fresh instance");

    let report = coord.shutdown();
    assert!(report.contains("unloads: proactive=1 worker-releases="), "{report}");
    assert!(report.contains("fabric: threads="), "{report}");
}

/// Serving through the fabric records utilization, and batched traffic
/// is served correctly end to end with W=4 workers on one shared pool.
#[test]
fn fabric_serves_batched_traffic_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(rns_cfg(4));
    // 8-sample requests form full batches deterministically, and an
    // 8x784x256 first layer clears the engine's parallel threshold
    for _ in 0..8 {
        coord.submit("mlp", Batch::Images(Nhwc::zeros(8, 28, 28, 1)));
    }
    let resps = coord.collect(8);
    assert!(resps.iter().all(|r| r.result.is_ok()));
    let fabric = coord.fabric().expect("fabric");
    if fabric.stats().budget >= 1 {
        assert!(
            fabric.stats().jobs > 0,
            "parallel-eligible batches must fan out through the fabric"
        );
    }
    let report = coord.shutdown();
    assert!(report.contains("requests=8"), "{report}");
}
