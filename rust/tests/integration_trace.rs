//! End-to-end span-trace integration tests (loopback gateway): a
//! sampled request yields a complete span tree whose per-stage
//! durations are *exactly* the values the `rns_stage_latency_us`
//! histograms observed (one measurement, two projections — the views
//! cannot disagree), spans nest (admission inside the session root,
//! compute stages inside the worker batch span), the `/trace` endpoint
//! serves both the text summary and Chrome trace-event JSON, the
//! health endpoints flip correctly across a drain (`/readyz` → 503
//! while `/healthz` stays 200), and the default trace-off path records
//! nothing at all.
//!
//! Every test serves `synthetic-mlp` (seeded in-process weights), so no
//! `make artifacts` step is needed anywhere.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use rns_analog::analog::NoiseModel;
use rns_analog::coordinator::metrics::stage_histogram;
use rns_analog::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use rns_analog::net::{Client, Gateway, GatewayConfig};
use rns_analog::nn::models::{Batch, SYNTHETIC_MLP};
use rns_analog::tensor::Nhwc;
use rns_analog::util::rng::Rng;
use rns_analog::util::trace::{self, parse_summary_line, Span, TraceTree};

fn rns_cfg(workers: usize) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::new(
        BackendKind::Rns { bits: 8, redundant: 2, attempts: 2, noise: NoiseModel::None },
        "/nonexistent",
    );
    cfg.workers = workers;
    cfg.seed = 7;
    cfg
}

fn gw_cfg(max_sessions: usize) -> GatewayConfig {
    GatewayConfig {
        listen_addr: "127.0.0.1:0".into(),
        max_sessions,
        idle_timeout: Duration::from_secs(10),
        ..GatewayConfig::default()
    }
}

/// Deterministic single-sample input #i.
fn input(i: u64) -> Batch {
    let mut rng = Rng::seed_from(0xBEEF ^ i);
    Batch::Images(Nhwc::from_vec(
        1,
        28,
        28,
        1,
        (0..28 * 28).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
    ))
}

fn http_get(addr: &str, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).expect("response");
    let (headers, body) = out.split_once("\r\n\r\n").expect("header terminator");
    (headers.to_string(), body.to_string())
}

fn span<'a>(tree: &'a TraceTree, name: &str) -> &'a Span {
    tree.spans.iter().find(|s| s.name == name).unwrap_or_else(|| {
        let names: Vec<&str> = tree.spans.iter().map(|s| s.name).collect();
        panic!("no `{name}` span; tree has {names:?}")
    })
}

/// The headline acceptance test: a client-sampled loopback request comes
/// back with its trace id echoed, the collector keeps a span tree whose
/// stage durations equal the histogram observations *exactly* (single
/// request ⇒ histogram sum == the one sample), the spans nest, and both
/// the admin frame and the HTTP endpoint serve the same trace.
#[test]
fn sampled_request_yields_span_tree_consistent_with_stage_histograms() {
    const TRACE_ID: u64 = 0xABC;

    let coord = Coordinator::start(rns_cfg(1));
    let handle = coord.handle();
    let collector = handle.trace_collector();
    let registry = handle.metric_registry();
    let gw = Gateway::start(coord, gw_cfg(4)).expect("gateway");
    let addr = gw.local_addr().to_string();

    let mut client = Client::connect(&addr).expect("connect");
    let id = client.submit_traced(SYNTHETIC_MLP, &input(0), TRACE_ID).expect("submit");
    let reply = client.recv_infer().expect("reply");
    assert_eq!(reply.id, id);
    assert_eq!(reply.trace_id, TRACE_ID, "InferOk echoes the wire trace id");

    // completion lands in the gateway sweep that flushed the reply; by
    // the time the client has read it the tree is kept or microseconds
    // away — poll briefly rather than assume the race is won
    let mut waited = Duration::ZERO;
    while collector.stats().kept == 0 && waited < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(10));
        waited += Duration::from_millis(10);
    }
    let trees = collector.trees();
    let tree = trees.iter().find(|t| t.id == TRACE_ID).expect("sampled tree kept");
    assert!(!tree.forced, "clean request must not be marked forced");
    assert_eq!(tree.model, SYNTHETIC_MLP);

    // every tier contributed its spans (delivery is recorded after the
    // fan-out and may lose the benign race with the reply flush, so it
    // is deliberately not asserted here)
    for name in [
        trace::SPAN_SESSION,
        trace::SPAN_ASSEMBLE,
        trace::SPAN_ADMISSION,
        trace::SPAN_QUEUE,
        trace::SPAN_BATCH_FORM,
        trace::SPAN_BATCH,
        trace::SPAN_DAC_FORWARD,
        trace::SPAN_ANALOG_GEMM,
        trace::SPAN_ADC_CAPTURE,
        trace::SPAN_DECODE,
        trace::SPAN_WRITE_FLUSH,
    ] {
        span(tree, name);
    }

    // span durations ARE the histogram observations: one request means
    // each stage histogram holds exactly one sample, and the span was
    // built from the same u64 that sample observed
    for (span_name, stage) in [
        (trace::SPAN_ADMISSION, "admission"),
        (trace::SPAN_QUEUE, "queue"),
        (trace::SPAN_BATCH_FORM, "batch_form"),
        (trace::SPAN_DAC_FORWARD, "dac_forward"),
        (trace::SPAN_ANALOG_GEMM, "analog_gemm"),
        (trace::SPAN_ADC_CAPTURE, "adc_capture"),
        (trace::SPAN_DECODE, "decode"),
    ] {
        let h = stage_histogram(&registry, stage);
        assert_eq!(h.count(), 1, "exactly one `{stage}` observation");
        assert_eq!(
            span(tree, span_name).dur_us,
            h.sum(),
            "`{span_name}` span duration == `{stage}` histogram sum"
        );
    }

    // nesting: the synthesized session root contains every span, and
    // each compute stage lies inside its worker's batch span
    let root = &tree.spans[0];
    assert_eq!(root.name, trace::SPAN_SESSION);
    assert_eq!(root.start_us, tree.start_us);
    assert_eq!(root.dur_us, tree.total_us);
    for s in &tree.spans {
        assert!(
            s.start_us >= root.start_us && s.end_us() <= root.end_us(),
            "`{}` [{}..{}] escapes session [{}..{}]",
            s.name,
            s.start_us,
            s.end_us(),
            root.start_us,
            root.end_us()
        );
    }
    let batch = span(tree, trace::SPAN_BATCH).clone();
    for name in [
        trace::SPAN_DAC_FORWARD,
        trace::SPAN_ANALOG_GEMM,
        trace::SPAN_ADC_CAPTURE,
        trace::SPAN_DECODE,
    ] {
        let s = span(tree, name);
        assert!(
            s.start_us >= batch.start_us && s.end_us() <= batch.end_us(),
            "`{}` [{}..{}] escapes batch [{}..{}]",
            s.name,
            s.start_us,
            s.end_us(),
            batch.start_us,
            batch.end_us()
        );
    }

    // the admin wire frame serves a summary line the loadgen join parses
    let text = client.trace_spans().expect("trace spans report");
    let line = text
        .lines()
        .find(|l| l.starts_with("span-trace: "))
        .unwrap_or_else(|| panic!("no span-trace line in:\n{text}"));
    let entry = parse_summary_line(line).expect("parseable summary line");
    assert_eq!(entry.id, TRACE_ID);
    assert_eq!(entry.total_us, tree.total_us);
    assert!(!entry.forced);
    assert!(entry.dominant.is_some(), "a completed tree names its dominant stage");

    // ... and the HTTP endpoint serves the same trace, both renderings
    let (headers, body) = http_get(&addr, "/trace");
    assert!(headers.contains("200"), "{headers}");
    assert!(body.contains("span-trace: id=0x0000000000000abc"), "{body}");
    let (headers, body) = http_get(&addr, "/trace?format=chrome");
    assert!(headers.contains("200"), "{headers}");
    assert!(headers.contains("application/json"), "{headers}");
    let trimmed = body.trim();
    assert!(trimmed.starts_with('[') && trimmed.ends_with(']'), "JSON array:\n{body}");
    assert!(body.contains("\"name\":\"session\""), "{body}");
    assert!(body.contains("\"ph\":\"X\""), "{body}");
    assert!(body.contains("\"name\":\"analog_gemm\""), "{body}");

    client.close();
    let report = gw.shutdown();
    assert!(report.contains("failures=0"), "{report}");
}

/// Liveness vs readiness across a drain: `/healthz` answers 200 for as
/// long as the process serves HTTP at all, while `/readyz` flips to 503
/// the moment the gateway starts draining.
#[test]
fn readyz_flips_to_503_during_drain_while_healthz_stays_200() {
    let gw = Gateway::start(Coordinator::start(rns_cfg(1)), gw_cfg(4)).expect("gateway");
    let addr = gw.local_addr().to_string();

    let (headers, body) = http_get(&addr, "/healthz");
    assert!(headers.contains("200"), "{headers}");
    assert_eq!(body, "ok\n");
    let (headers, body) = http_get(&addr, "/readyz");
    assert!(headers.contains("200"), "{headers}");
    assert_eq!(body, "ready\n");
    // the hint on unknown paths advertises the health endpoints
    let (headers, body) = http_get(&addr, "/nope");
    assert!(headers.contains("404"), "{headers}");
    assert!(body.contains("/healthz"), "{body}");

    // remote drain: the Shutdown frame sets draining before its Ack is
    // written, so readiness is already false when the reply lands
    let mut client = Client::connect(&addr).expect("connect");
    let info = client.shutdown_server().expect("shutdown frame");
    assert!(info.contains("draining"), "{info}");

    let (headers, body) = http_get(&addr, "/readyz");
    assert!(headers.contains("503"), "not ready while draining: {headers}");
    assert_eq!(body, "draining\n");
    let (headers, body) = http_get(&addr, "/healthz");
    assert!(headers.contains("200"), "alive while draining: {headers}");
    assert_eq!(body, "ok\n");

    assert!(gw.wait_shutdown(Some(Duration::from_secs(10))), "shutdown signal received");
    client.close();
    let report = gw.shutdown();
    assert!(report.contains("failures=0"), "{report}");
}

/// The default path samples nothing: an untraced request leaves the
/// collector empty and the reply carries trace id 0 — the trace-off
/// wire bytes and behavior match the pre-tracing protocol.
#[test]
fn trace_off_default_records_nothing() {
    let coord = Coordinator::start(rns_cfg(1));
    let handle = coord.handle();
    let collector = handle.trace_collector();
    let gw = Gateway::start(coord, gw_cfg(4)).expect("gateway");
    let addr = gw.local_addr().to_string();

    let mut client = Client::connect(&addr).expect("connect");
    let reply = client.infer(SYNTHETIC_MLP, &input(1)).expect("infer");
    assert_eq!(reply.trace_id, 0, "sampling defaults off");

    let stats = collector.stats();
    assert_eq!(stats.sampled, 0, "no server-side sampling at trace_sample=0");
    assert_eq!(stats.kept, 0, "no trees kept");
    assert_eq!(stats.pending, 0, "no trees pending");
    let text = client.trace_spans().expect("trace spans report");
    assert!(!text.contains("span-trace: "), "{text}");

    client.close();
    let report = gw.shutdown();
    assert!(report.contains("failures=0"), "{report}");
}
