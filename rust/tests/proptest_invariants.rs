//! Property-based invariants over the whole stack, driven by the in-house
//! `util::prop` harness (see DESIGN.md — no proptest crate offline).
//!
//! These are the "coordinator invariants" class of properties: routing /
//! batching / state invariants plus the numeric laws the cores rely on.

use rns_analog::analog::{NoiseModel, RnsCore, RnsCoreConfig};
use rns_analog::coordinator::batcher::{BatcherConfig, DynamicBatcher};
use rns_analog::coordinator::request::InferenceRequest;
use rns_analog::nn::models::Batch;
use rns_analog::quant::{dequantize, quantize_activations, quantize_weights, qmax};
use rns_analog::rns::inject::FaultSpec;
use rns_analog::rns::moduli::{extend_moduli, paper_table1};
use rns_analog::rns::rrns::{combinations, Decode, RrnsCode};
use rns_analog::rns::RnsContext;
use rns_analog::tensor::gemm::{gemm_f32, gemm_i64, gemm_mod};
use rns_analog::tensor::{MatF, MatI, Nhwc};
use rns_analog::util::prop::{prop_assert, prop_assert_eq, run_prop};
use rns_analog::util::rng::Rng;
use std::time::{Duration, Instant};

fn rand_mat_f(rng: &mut Rng, rows: usize, cols: usize) -> MatF {
    MatF::from_vec(rows, cols, (0..rows * cols).map(|_| rng.uniform_f32(-2.0, 2.0)).collect())
}

#[test]
fn prop_crt_is_ring_isomorphism() {
    // (a ± b) and (a * b) commute with forward/CRT on every Table-I set
    run_prop("crt ring isomorphism", 400, |rng| {
        let bits = [4u32, 5, 6, 7, 8][rng.gen_range(5) as usize];
        let ctx = RnsContext::new(paper_table1(bits).unwrap()).unwrap();
        let bound = ((ctx.big_m as f64).sqrt() as i64) / 2;
        let a = rng.gen_range_i64(-bound, bound);
        let b = rng.gen_range_i64(-bound, bound);
        let ra = ctx.forward(a);
        let rb = ctx.forward(b);
        let prod: Vec<u64> = ra
            .iter()
            .zip(&rb)
            .zip(&ctx.moduli)
            .map(|((&x, &y), &m)| (x * y) % m)
            .collect();
        let sum: Vec<u64> = ra
            .iter()
            .zip(&rb)
            .zip(&ctx.moduli)
            .map(|((&x, &y), &m)| (x + y) % m)
            .collect();
        prop_assert_eq(ctx.crt_signed(&prod), (a as i128) * (b as i128), "mul")?;
        prop_assert_eq(ctx.crt_signed(&sum), (a + b) as i128, "add")
    });
}

#[test]
fn prop_modular_gemm_equals_exact_mod() {
    run_prop("gemm_mod == (gemm_i64 mod m)", 60, |rng| {
        let m = [11u64, 13, 59, 61, 127, 251][rng.gen_range(6) as usize];
        let b = 1 + rng.gen_range(3) as usize;
        let k = 1 + rng.gen_range(300) as usize;
        let n = 1 + rng.gen_range(12) as usize;
        let x = MatI::from_vec(b, k, (0..b * k).map(|_| rng.gen_range(m) as i64).collect());
        let w = MatI::from_vec(k, n, (0..k * n).map(|_| rng.gen_range(m) as i64).collect());
        let want: Vec<i64> =
            gemm_i64(&x, &w).data.iter().map(|&v| v.rem_euclid(m as i64)).collect();
        prop_assert_eq(gemm_mod(&x, &w, m).data, want, &format!("m={m} k={k}"))
    });
}

#[test]
fn prop_quantize_dequantize_error_bound() {
    // |dequant(quant(x) @ quant(w)) - x@w| <= K * (s_x/2qm * max|w| + s_w/2qm * max|x| + cross)
    run_prop("quantized gemm error bound", 40, |rng| {
        let bits = [6u32, 8][rng.gen_range(2) as usize];
        let b = 1 + rng.gen_range(3) as usize;
        let k = 1 + rng.gen_range(128) as usize;
        let n = 1 + rng.gen_range(8) as usize;
        let x = rand_mat_f(rng, b, k);
        let w = rand_mat_f(rng, k, n);
        let qa = quantize_activations(&x, bits);
        let qw = quantize_weights(&w, bits);
        let got = dequantize(&gemm_i64(&qa.q, &qw.q), &qa, &qw);
        let want = gemm_f32(&x, &w);
        let qm = qmax(bits) as f32;
        for r in 0..b {
            let sx = qa.scales[r];
            for c in 0..n {
                let sw = qw.scales[c];
                // per-term rounding error: 0.5/qm each side, plus the cross term
                let tol = k as f32 * (sx * sw) * (1.0 / qm + 0.25 / (qm * qm)) + 1e-4;
                let err = (got.at(r, c) - want.at(r, c)).abs();
                prop_assert(err <= tol, &format!("err {err} > tol {tol} (b={bits} k={k})"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rrns_corrects_any_single_error_position_and_magnitude() {
    let base = paper_table1(8).unwrap();
    let all = extend_moduli(base, 2).unwrap();
    let code = RrnsCode::new(&all, base.len()).unwrap();
    let half = (code.legitimate_range / 2) as i64;
    run_prop("rrns single-error correction", 500, |rng| {
        let a = rng.gen_range_i64(-(half - 1), half);
        let mut res = code.encode(a);
        let i = rng.gen_range(code.n() as u64) as usize;
        let delta = 1 + rng.gen_range(all[i] - 1);
        res[i] = (res[i] + delta) % all[i];
        match code.decode(&res) {
            Decode::Ok { value, suspects } => {
                prop_assert_eq(value, a as i128, "value")?;
                prop_assert_eq(suspects, vec![i], "suspect set")
            }
            Decode::Detected => Err(format!("single error at {i} (delta {delta}) not corrected")),
        }
    });
}

#[test]
fn prop_batched_decode_equals_voting_under_correctable_faults() {
    // For random values and ANY fault pattern with <= correctable()
    // corrupted channels, the two-tier batched decode (consistency
    // pre-check + voting fallback) == the per-element voting decode ==
    // the original value — across several (n, k) code configurations.
    let configs: Vec<RrnsCode> = vec![
        // (5, 3), t = 1
        RrnsCode::new(&extend_moduli(paper_table1(8).unwrap(), 2).unwrap(), 3).unwrap(),
        // (7, 3), t = 2
        RrnsCode::new(&extend_moduli(paper_table1(8).unwrap(), 4).unwrap(), 3).unwrap(),
        // (6, 4), t = 1
        RrnsCode::new(&extend_moduli(paper_table1(6).unwrap(), 2).unwrap(), 4).unwrap(),
        // (8, 4), t = 2
        RrnsCode::new(&extend_moduli(paper_table1(6).unwrap(), 4).unwrap(), 4).unwrap(),
    ];
    run_prop("batched == voting under <=t faults", 120, |rng| {
        for code in &configs {
            let t = code.correctable();
            let half = (code.legitimate_range / 2) as i64;
            let rows = 1 + rng.gen_range(4) as usize;
            let cols = 1 + rng.gen_range(5) as usize;
            let values = MatI::from_vec(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.gen_range_i64(-(half - 1), half)).collect(),
            );
            let mut channels = code.encode_tile(&values);
            // every element gets an independent fault pattern of weight
            // 0..=t via the shared injector
            let count = rng.gen_range(t as u64 + 1) as usize;
            let spec = FaultSpec::Channels { count };
            spec.apply_tile(&mut channels, &code.full.moduli, rng);
            let pre = code.precheck_tile(&channels);
            let mut res = vec![0u64; code.n()];
            for e in 0..rows * cols {
                for (r, ch) in res.iter_mut().zip(&channels) {
                    *r = ch.data[e] as u64;
                }
                let voted = match code.decode(&res) {
                    Decode::Ok { value, .. } => value,
                    Decode::Detected => {
                        return Err(format!(
                            "{count} <= t={t} faults must be correctable (n={}, k={})",
                            code.n(),
                            code.k
                        ))
                    }
                };
                prop_assert_eq(voted, values.data[e] as i128, "voting == original")?;
                let batched = if pre.fallback.contains(&e) {
                    voted
                } else {
                    pre.values.data[e] as i128
                };
                prop_assert_eq(batched, voted, "batched == voting")?;
            }
            // every fault-free element must have taken the fast path
            if count == 0 {
                prop_assert(pre.fallback.is_empty(), "clean tile must fully fast-path")?;
            } else {
                prop_assert_eq(
                    pre.fallback.len(),
                    rows * cols,
                    "corrupted elements must all fall back",
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_combinations_counts_and_uniqueness() {
    run_prop("C(n,k) combinations", 50, |rng| {
        let n = 1 + rng.gen_range(7) as usize;
        let k = 1 + rng.gen_range(n as u64) as usize;
        let combos = combinations(n, k);
        let expect = (0..k).fold(1usize, |acc, i| acc * (n - i) / (i + 1));
        prop_assert_eq(combos.len(), expect, "count")?;
        let mut seen = std::collections::BTreeSet::new();
        for c in &combos {
            prop_assert(c.len() == k, "size")?;
            prop_assert(c.windows(2).all(|w| w[0] < w[1]), "sorted")?;
            prop_assert(seen.insert(c.clone()), "unique")?;
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_conserves_requests_and_order_within_model() {
    // whatever the arrival pattern: no request lost, no request duplicated,
    // batches never exceed max_batch (except single oversize requests),
    // and per-model FIFO order is preserved.
    run_prop("batcher conservation", 60, |rng| {
        let max_batch = 1 + rng.gen_range(8) as usize;
        let mut batcher = DynamicBatcher::new(BatcherConfig {
            max_batch,
            max_wait: Duration::from_secs(3600),
            ..Default::default()
        });
        let n_req = 1 + rng.gen_range(30) as usize;
        let mut submitted: Vec<(u64, String)> = Vec::new();
        for id in 0..n_req as u64 {
            let model = if rng.bernoulli(0.5) { "a" } else { "b" };
            let samples = 1 + rng.gen_range(3) as usize;
            batcher.push(InferenceRequest::new(
                id,
                model,
                Batch::Images(Nhwc::zeros(samples, 1, 1, 1)),
            ));
            submitted.push((id, model.to_string()));
        }
        let mut drained: Vec<(u64, String)> = Vec::new();
        while let Some(fb) = batcher.pop_ready(Instant::now(), true) {
            let total: usize = fb.members.iter().map(|(r, _)| r.num_samples()).sum();
            prop_assert(
                total <= max_batch || fb.members.len() == 1,
                &format!("batch of {total} exceeds {max_batch}"),
            )?;
            prop_assert_eq(total, fb.input.len(), "concat size")?;
            for (req, _) in fb.members {
                drained.push((req.id, req.model.clone()));
            }
        }
        prop_assert_eq(batcher.pending(), 0, "fully drained")?;
        prop_assert_eq(drained.len(), submitted.len(), "conservation")?;
        for model in ["a", "b"] {
            let sub: Vec<u64> =
                submitted.iter().filter(|(_, m)| m == model).map(|(i, _)| *i).collect();
            let dra: Vec<u64> =
                drained.iter().filter(|(_, m)| m == model).map(|(i, _)| *i).collect();
            prop_assert_eq(dra, sub, &format!("fifo order for {model}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_clean_rns_core_is_deterministic_and_tiling_invariant() {
    run_prop("rns core tiling invariance", 15, |rng| {
        let k = 128 + rng.gen_range(256) as usize;
        let x = rand_mat_f(rng, 2, k);
        let w = rand_mat_f(rng, k, 4);
        // same moduli set (chosen for the larger h) used at two tile sizes:
        // clean RNS accumulation must be bit-identical across tilings
        let moduli = rns_analog::rns::select_moduli(6, 512).unwrap();
        let mk_core = |h: usize| {
            let mut cfg = RnsCoreConfig::for_bits(6, h);
            cfg.moduli = moduli.clone();
            RnsCore::new(cfg).unwrap()
        };
        let a = mk_core(128).gemm_quantized(&x, &w);
        let b = mk_core(512).gemm_quantized(&x, &w);
        prop_assert_eq(a.data, b.data, "tiling invariance")
    });
}

#[test]
fn prop_noise_rate_scales_with_p() {
    // measured corruption rate of the RNS core tracks the configured p
    run_prop("noise rate tracks p", 8, |rng| {
        let p = [0.01f64, 0.05, 0.2][rng.gen_range(3) as usize];
        let x = rand_mat_f(rng, 8, 128);
        let w = rand_mat_f(rng, 128, 16);
        let mut clean = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
        let mut noisy = RnsCore::new(
            RnsCoreConfig::for_bits(6, 128)
                .with_noise(NoiseModel::ResidueFlip { p })
                .with_seed(rng.next_u64()),
        )
        .unwrap();
        let a = clean.gemm_quantized(&x, &w);
        let b = noisy.gemm_quantized(&x, &w);
        let outputs = a.data.len() as f64;
        let differing = a.data.iter().zip(&b.data).filter(|(x, y)| x != y).count() as f64;
        // each output = n residues; P(any flipped) = 1-(1-p)^n
        let n = clean.n_channels() as f64;
        let expect = 1.0 - (1.0 - p).powf(n);
        let rate = differing / outputs;
        prop_assert(
            (rate - expect).abs() < 0.15 + expect * 0.5,
            &format!("rate {rate:.3} vs expected {expect:.3} at p={p}"),
        )
    });
}
