//! Drift-campaign integration tests (ROADMAP PR-3 open item): drive
//! `FaultSpec::TemporalBurst` through a *full model forward* and assert
//! the RRNS retry loop's behavior — seeded determinism first (a campaign
//! replays bit-for-bit from `(spec, seed)`), then the code-property
//! guarantees: a burst within the correction radius is absorbed exactly
//! (logits bit-equal the clean run), and a wider burst is detected and
//! recovered by the recompute loop when attempts allow.
//!
//! Uses `Mlp::synthetic` so no `make artifacts` step is needed.

use rns_analog::analog::{FaultStats, InjectionSite, RnsCore, RnsCoreConfig};
use rns_analog::nn::models::{Batch, Mlp, Model};
use rns_analog::rns::inject::FaultSpec;
use rns_analog::tensor::{MatF, Nhwc};
use rns_analog::util::rng::Rng;

fn synth_mlp() -> Mlp {
    Mlp::synthetic(42)
}

fn eval_batch(n: usize) -> Batch {
    let mut rng = Rng::seed_from(7);
    let data = (0..n * 28 * 28).map(|_| rng.uniform_f32(0.0, 1.0)).collect();
    Batch::Images(Nhwc::from_vec(n, 28, 28, 1, data))
}

fn forward_with(
    model: &Mlp,
    input: &Batch,
    spec: Option<(FaultSpec, u64)>,
    attempts: u32,
) -> (MatF, FaultStats) {
    forward_at(model, input, spec, attempts, InjectionSite::Capture)
}

fn forward_at(
    model: &Mlp,
    input: &Batch,
    spec: Option<(FaultSpec, u64)>,
    attempts: u32,
    site: InjectionSite,
) -> (MatF, FaultStats) {
    let mut cfg = RnsCoreConfig::for_bits(8, 128).with_rrns(2, attempts).with_fault_site(site);
    if let Some((s, seed)) = spec {
        cfg = cfg.with_fault_injection(s, seed);
    }
    let mut core = RnsCore::new(cfg).unwrap();
    let logits = model.forward(input, &mut core);
    (logits, core.stats)
}

fn bits_of(m: &MatF) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

/// The satellite requirement: a TemporalBurst campaign through a full
/// forward pass replays bit-for-bit from `(spec, seed)` — logits and
/// every fault counter — and a different seed lands the drift rectangle
/// elsewhere.
#[test]
fn temporal_burst_campaign_is_seed_deterministic() {
    let model = synth_mlp();
    let input = eval_batch(4);
    let spec = FaultSpec::TemporalBurst { tiles: 3, elems: 6, width: 2 };
    let (la, sa) = forward_with(&model, &input, Some((spec, 11)), 1);
    let (lb, sb) = forward_with(&model, &input, Some((spec, 11)), 1);
    assert_eq!(bits_of(&la), bits_of(&lb), "same (spec, seed): bit-identical logits");
    assert_eq!(sa, sb, "same (spec, seed): identical fault counters");
    assert!(sa.detections + sa.corrected > 0, "the burst must actually corrupt decodes");

    let (lc, sc) = forward_with(&model, &input, Some((spec, 12)), 1);
    assert!(
        bits_of(&la) != bits_of(&lc) || sa != sc,
        "a different drift seed must corrupt differently"
    );
}

/// Burst width within the correction radius (width = 1 ≤ t for an
/// RRNS(6,4) code): every corrupted element is corrected exactly, so the
/// campaign's logits are bit-equal to a clean core's — the paper's
/// fault-tolerance claim end to end through a model.
#[test]
fn correctable_burst_is_absorbed_bit_exactly() {
    let model = synth_mlp();
    let input = eval_batch(4);
    let (clean, clean_stats) = forward_with(&model, &input, None, 1);
    assert_eq!(clean_stats.corrected, 0);
    let spec = FaultSpec::TemporalBurst { tiles: 4, elems: 8, width: 1 };
    let (drifted, stats) = forward_with(&model, &input, Some((spec, 5)), 1);
    assert!(stats.corrected > 0, "drift within radius must exercise correction");
    assert_eq!(stats.exhausted, 0, "single-channel faults never exhaust");
    assert_eq!(
        bits_of(&clean),
        bits_of(&drifted),
        "corrected campaign must be bit-identical to the clean forward"
    );
    // fast path still carries the untouched bulk of the tiles
    assert!(stats.fast_path_elems > stats.voted_elems);
}

/// Burst width beyond the correction radius (width = 2 = n − k):
/// detections fire, and because the injected faults hit the *capture*
/// (the retry recomputes from clean channel outputs), the paper's
/// detect → recompute loop recovers every element when attempts allow —
/// while attempts = 1 must exhaust instead.
#[test]
fn retry_loop_recovers_detected_bursts() {
    let model = synth_mlp();
    let input = eval_batch(4);
    let spec = FaultSpec::TemporalBurst { tiles: 2, elems: 6, width: 2 };

    let (_, retry) = forward_with(&model, &input, Some((spec, 9)), 3);
    assert!(retry.detections > 0, "width 2 > t must trigger detections");
    assert_eq!(retry.exhausted, 0, "clean recompute resolves every detection");

    let (_, no_retry) = forward_with(&model, &input, Some((spec, 9)), 1);
    assert!(no_retry.detections > 0);
    assert_eq!(
        no_retry.exhausted, no_retry.detections,
        "attempts=1: every detection exhausts into best-effort decode"
    );
}

/// Array-side drift replays bit-for-bit from `(spec, seed)` exactly like
/// the capture-side campaigns, and a different seed lands elsewhere.
#[test]
fn array_side_campaign_is_seed_deterministic() {
    let model = synth_mlp();
    let input = eval_batch(4);
    let spec = FaultSpec::TemporalBurst { tiles: 3, elems: 6, width: 2 };
    let (la, sa) = forward_at(&model, &input, Some((spec, 11)), 3, InjectionSite::Array);
    let (lb, sb) = forward_at(&model, &input, Some((spec, 11)), 3, InjectionSite::Array);
    assert_eq!(bits_of(&la), bits_of(&lb), "same (spec, seed): bit-identical logits");
    assert_eq!(sa, sb, "same (spec, seed): identical fault counters");
    assert!(sa.detections > 0, "the array burst must actually corrupt decodes");
    let (lc, sc) = forward_at(&model, &input, Some((spec, 12)), 3, InjectionSite::Array);
    assert!(bits_of(&la) != bits_of(&lc) || sa != sc, "a different seed must differ");
}

/// Array-side drift within the correction radius is still absorbed bit
/// exactly — the code corrects a width ≤ t burst wherever it lands.
#[test]
fn array_side_correctable_burst_is_absorbed() {
    let model = synth_mlp();
    let input = eval_batch(4);
    let (clean, _) = forward_with(&model, &input, None, 1);
    let spec = FaultSpec::TemporalBurst { tiles: 4, elems: 8, width: 1 };
    let (drifted, stats) = forward_at(&model, &input, Some((spec, 5)), 1, InjectionSite::Array);
    assert!(stats.corrected > 0);
    assert_eq!(stats.exhausted, 0, "width 1 <= t never exhausts, array-side or not");
    assert_eq!(bits_of(&clean), bits_of(&drifted), "corrected campaign bit-equals clean");
}

/// The array-side satellite claim: a burst wider than t corrupts the
/// *recomputed* dot product too, so retries re-detect the same fault and
/// `max_attempts` exhausts — the capture-side path recovers the very
/// same `(spec, seed)` campaign with one retry.
#[test]
fn array_side_bursts_exhaust_where_capture_side_recovers() {
    let model = synth_mlp();
    let input = eval_batch(4);
    let spec = FaultSpec::TemporalBurst { tiles: 2, elems: 6, width: 2 };

    let (_, capture) = forward_at(&model, &input, Some((spec, 9)), 3, InjectionSite::Capture);
    assert!(capture.detections > 0);
    assert_eq!(capture.exhausted, 0, "capture-side: clean recompute recovers everything");

    let (arr_logits, array) = forward_at(&model, &input, Some((spec, 9)), 3, InjectionSite::Array);
    assert!(array.exhausted > 0, "array-side: retries re-read the corruption and exhaust");
    // every element that started voting re-detects on every one of its
    // 3 attempts (noise is None, so the recompute is identical), so
    // detections = attempts x exhausted
    assert_eq!(array.detections, 3 * array.exhausted);
    // and raising attempts cannot help while the event persists
    let (_, array1) = forward_at(&model, &input, Some((spec, 9)), 1, InjectionSite::Array);
    assert_eq!(
        array1.exhausted, array.exhausted,
        "attempts budget does not change how many elements stay corrupt"
    );
    // exhausted elements decode best-effort: the forward must still
    // complete with finite logits
    assert!(arr_logits.data.iter().all(|v| v.is_finite()));
}
