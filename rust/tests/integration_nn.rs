//! Integration tests for the nn substrate against the real trained
//! artifacts: rust FP32 inference must reproduce the accuracy recorded at
//! jax training time, and the analog backends must slot in transparently.
//!
//! Tests skip silently when `make artifacts` has not run.

use rns_analog::analog::{FixedPointCore, Fp32Backend, NoiseModel, RnsCore, RnsCoreConfig};
use rns_analog::nn::dataset::{dataset_for_model, load_eval_set};
use rns_analog::nn::models::{accuracy, load_model, ZOO};

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(&format!("{}/models/mlp.rt", artifacts_dir())).exists()
}

#[test]
fn rust_fp32_matches_jax_training_accuracy() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // the full 512-sample eval set must reproduce the accuracy the jax
    // training loop recorded, within a small tolerance (conv/layernorm
    // numerics differ at the 1e-6 level; argmax flips are rare)
    for name in ZOO {
        let model = load_model(&artifacts_dir(), name).unwrap();
        let eval = load_eval_set(&artifacts_dir(), dataset_for_model(name)).unwrap();
        let acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut Fp32Backend);
        let trained = model.trained_fp32_accuracy() as f64;
        assert!(
            (acc - trained).abs() < 0.02,
            "{name}: rust fp32 {acc:.4} vs jax {trained:.4}"
        );
    }
}

#[test]
fn rns_b8_matches_fp32_predictions_closely() {
    if !have_artifacts() {
        return;
    }
    for name in ["mlp", "resnet"] {
        let model = load_model(&artifacts_dir(), name).unwrap();
        let eval = load_eval_set(&artifacts_dir(), dataset_for_model(name)).unwrap().take(128);
        let fp32 = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut Fp32Backend);
        let mut rns = RnsCore::new(RnsCoreConfig::for_bits(8, 128)).unwrap();
        let rns_acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut rns);
        assert!(
            rns_acc >= fp32 - 0.02,
            "{name}: rns b=8 {rns_acc:.4} should track fp32 {fp32:.4}"
        );
    }
}

#[test]
fn fixed_point_collapses_at_low_bits_on_deep_model() {
    if !have_artifacts() {
        return;
    }
    let model = load_model(&artifacts_dir(), "resnet").unwrap();
    let eval = load_eval_set(&artifacts_dir(), "shapes").unwrap().take(96);
    let fp32 = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut Fp32Backend);
    let mut fxp = FixedPointCore::new(4, 128, NoiseModel::None, 0);
    let fxp_acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut fxp);
    assert!(
        fxp_acc < 0.6 * fp32,
        "4-bit fixed point should collapse on resnet: {fxp_acc:.4} vs fp32 {fp32:.4}"
    );
}

#[test]
fn headline_99pct_at_6_bits_all_models() {
    if !have_artifacts() {
        return;
    }
    // THE paper claim, on the full model zoo at 128 samples each.
    for name in ZOO {
        let model = load_model(&artifacts_dir(), name).unwrap();
        let eval = load_eval_set(&artifacts_dir(), dataset_for_model(name)).unwrap().take(128);
        let fp32 = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut Fp32Backend);
        let mut rns = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
        let rns_acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut rns);
        assert!(
            rns_acc / fp32.max(1e-9) >= 0.99,
            "{name}: rns b=6 normalized accuracy {:.4} below the 99% headline",
            rns_acc / fp32
        );
    }
}

#[test]
fn eval_sets_are_complete_and_labelled() {
    if !have_artifacts() {
        return;
    }
    for ds in ["digits", "shapes", "tokens"] {
        let eval = load_eval_set(&artifacts_dir(), ds).unwrap();
        assert_eq!(eval.len(), 512, "{ds}");
        assert!(eval.labels.iter().all(|&l| (0..10).contains(&l)));
    }
}

#[test]
fn wrong_artifacts_dir_is_clean_error() {
    let err = match load_model("/definitely/not/here", "mlp") {
        Err(e) => e,
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("No such file") || err.contains("not found"), "{err}");
    assert!(load_eval_set("/definitely/not/here", "digits").is_err());
}
