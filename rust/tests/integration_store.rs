//! Integration tests for the shared-plan-store + persistent-worker-pool
//! runtime (PR 3): concurrent warms must build each plan exactly once
//! store-wide with pointer-equal `Arc`s across workers, model unload must
//! evict, and pool-executed GEMM must be bit-identical to the serial and
//! scoped-spawn paths under fixed seeds.

use std::sync::Arc;

use rns_analog::analog::{GemmBackend, NoiseModel, RnsCore, RnsCoreConfig};
use rns_analog::runtime::{ExecutionFabric, NativeEngine, RnsPlan, SpawnMode};
use rns_analog::store::{PlanKey, PlanStore};
use rns_analog::tensor::MatF;
use rns_analog::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> MatF {
    MatF::from_vec(rows, cols, (0..rows * cols).map(|_| rng.uniform_f32(-scale, scale)).collect())
}

/// N worker threads warm the same 3-layer "model" against one shared
/// store: every plan is built exactly once, every worker ends up holding
/// the same `Arc` per layer, and each worker still adopts (and charges)
/// all 3 plans locally.
#[test]
fn concurrent_warm_builds_each_plan_exactly_once() {
    let store = Arc::new(PlanStore::default());
    let mut rng = Rng::seed_from(1);
    // shared weight allocations, as the coordinator's ModelRegistry
    // provides: plan keys include the data pointer, so cross-worker
    // dedup requires workers to share the weights themselves
    let layers = Arc::new(vec![
        rand_mat(&mut rng, 300, 7, 1.0),
        rand_mat(&mut rng, 128, 64, 1.0),
        rand_mat(&mut rng, 64, 10, 1.0),
    ]);
    let cfg = RnsCoreConfig::for_bits(6, 128);
    let moduli = cfg.moduli.clone();
    let workers = 8usize;
    let per_worker: Vec<Vec<Arc<RnsPlan>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|wid| {
                let store = Arc::clone(&store);
                let layers = Arc::clone(&layers);
                let cfg = cfg.clone();
                let moduli = moduli.clone();
                s.spawn(move || {
                    let mut core =
                        RnsCore::with_store(cfg.with_seed(wid as u64), Arc::clone(&store)).unwrap();
                    core.set_model_tag("shared-mlp");
                    for w in layers.iter() {
                        core.prepare_weights(w);
                    }
                    assert_eq!(GemmBackend::plans_built(&core), 3, "worker {wid} adopts 3 plans");
                    layers
                        .iter()
                        .map(|w| {
                            store
                                .get(&PlanKey::for_weights(w, 6, 128, &moduli))
                                .expect("plan resident after warm")
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    let stats = store.stats();
    assert_eq!(stats.builds, 3, "each layer built exactly once across {workers} workers");
    assert_eq!(stats.resident_plans, 3);
    assert_eq!(stats.evicted, 0, "tagged plans are never LRU-evicted");
    // every warm after the 3 reservations was a store hit
    assert_eq!(stats.hits, (workers as u64) * 3 - 3);
    // the acceptance property: one plan instance per layer, pointer-equal
    // Arc across all workers
    for layer in 0..3 {
        for wid in 1..workers {
            assert!(
                Arc::ptr_eq(&per_worker[0][layer], &per_worker[wid][layer]),
                "layer {layer}: worker {wid} must share worker 0's plan"
            );
        }
    }
    // per-model attribution landed under the tag
    let ms = store.model_stats();
    assert_eq!(ms.len(), 1);
    assert_eq!(ms[0].model, "shared-mlp");
    assert_eq!(ms[0].misses, 3);
    assert_eq!(ms[0].hits, (workers as u64) * 3 - 3);
    assert_eq!(ms[0].plans, 3);

    // model unload evicts all three; the Arcs handed out above stay valid
    assert_eq!(store.unload_model("shared-mlp"), 3);
    assert_eq!(store.stats().resident_plans, 0);
    assert_eq!(per_worker[0][0].k, 300, "in-flight Arc outlives eviction");
}

/// Pool-executed GEMM is bit-identical to the serial engine, to the
/// per-call scoped-spawn engine, and to a shared-fabric engine,
/// including under RRNS + noise with fixed seeds (the pool schedules
/// exact arithmetic only; the rng stays serial inside the core).
#[test]
fn pool_gemm_bit_identical_to_serial_scoped_and_fabric() {
    let mut rng = Rng::seed_from(2);
    // large enough that every tile clears the engine's parallel threshold
    let x = rand_mat(&mut rng, 16, 256, 1.0);
    let w = rand_mat(&mut rng, 256, 64, 0.5);
    let fabric = Arc::new(ExecutionFabric::with_threads(4, 2));
    for (redundant, attempts) in [(0usize, 1u32), (2, 3)] {
        let mk_cfg = || {
            RnsCoreConfig::for_bits(8, 128)
                .with_noise(NoiseModel::ResidueFlip { p: 0.03 })
                .with_rrns(redundant, attempts)
                .with_seed(1234)
        };
        let mut serial = RnsCore::with_engine(mk_cfg(), Box::new(NativeEngine::serial())).unwrap();
        let mut pooled = RnsCore::with_engine(
            mk_cfg(),
            Box::new(NativeEngine::with_spawn_mode(4, SpawnMode::Pool)),
        )
        .unwrap();
        let mut scoped = RnsCore::with_engine(
            mk_cfg(),
            Box::new(NativeEngine::with_spawn_mode(4, SpawnMode::Scoped)),
        )
        .unwrap();
        let mut fabbed = RnsCore::with_engine(
            mk_cfg(),
            Box::new(NativeEngine::with_fabric(fabric.handle())),
        )
        .unwrap();
        let ys = serial.gemm_quantized(&x, &w);
        // two passes through the pooled core: the second reuses parked
        // threads (the persistent-pool steady state)
        let yp1 = pooled.gemm_quantized(&x, &w);
        let yc = scoped.gemm_quantized(&x, &w);
        let yf = fabbed.gemm_quantized(&x, &w);
        assert_eq!(
            ys.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            yp1.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "rrns={redundant}: pool must be bit-identical to serial"
        );
        assert_eq!(
            yp1.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            yc.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "rrns={redundant}: pool must be bit-identical to scoped"
        );
        assert_eq!(
            yc.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            yf.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "rrns={redundant}: shared fabric must be bit-identical to scoped"
        );
        let ys2 = serial.gemm_quantized(&x, &w);
        let yp2 = pooled.gemm_quantized(&x, &w);
        let yf2 = fabbed.gemm_quantized(&x, &w);
        assert_eq!(
            ys2.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            yp2.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "rrns={redundant}: second pass (pool reuse) must stay bit-identical"
        );
        assert_eq!(
            ys2.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            yf2.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "rrns={redundant}: second pass (fabric reuse) must stay bit-identical"
        );
        // identical rng consumption => identical counters and energy
        assert_eq!(serial.stats, pooled.stats);
        assert_eq!(serial.stats, fabbed.stats);
        assert_eq!(serial.meter.adc_conversions, pooled.meter.adc_conversions);
        assert_eq!(serial.meter.adc_conversions, fabbed.meter.adc_conversions);
        assert_eq!(serial.meter.dac_conversions, fabbed.meter.dac_conversions);
        assert_eq!(
            serial.meter.total_joules().to_bits(),
            fabbed.meter.total_joules().to_bits(),
            "rrns={redundant}: energy ledgers must match to the bit"
        );
    }
    assert!(fabric.stats().jobs > 0, "fabric cores must route fan-outs through the fabric");
}

/// Sparse capture must not disturb the engine-equivalence contract: the
/// serial, persistent-pool, and shared-fabric engines run the identical
/// skip logic (the mask is computed from clean channel outputs, which
/// all engines produce bit-identically), so outputs, energy meters —
/// including `skipped_dac` / `skipped_adc` — and fault stats — including
/// `skipped_rows` — must agree exactly on a seeded 50%-zero-row workload.
#[test]
fn sparse_skip_counters_identical_across_engines_and_decode_paths() {
    let mut rng = Rng::seed_from(4);
    let mut x = rand_mat(&mut rng, 16, 256, 1.0);
    let w = rand_mat(&mut rng, 256, 64, 0.5);
    // zero half the sample rows so whole-row ADC skips actually fire
    for r in (0..x.rows).step_by(2) {
        x.row_mut(r).fill(0.0);
    }
    let fabric = Arc::new(ExecutionFabric::with_threads(4, 2));
    let mk_cfg = || {
        RnsCoreConfig::for_bits(8, 128)
            .with_noise(NoiseModel::ResidueFlip { p: 0.03 })
            .with_rrns(2, 3)
            .with_seed(77)
            .with_sparse_capture(true)
    };
    let mut serial = RnsCore::with_engine(mk_cfg(), Box::new(NativeEngine::serial())).unwrap();
    let mut pooled =
        RnsCore::with_engine(mk_cfg(), Box::new(NativeEngine::with_spawn_mode(4, SpawnMode::Pool)))
            .unwrap();
    let mut fabbed =
        RnsCore::with_engine(mk_cfg(), Box::new(NativeEngine::with_fabric(fabric.handle())))
            .unwrap();
    let ys = serial.gemm_quantized(&x, &w);
    let yp = pooled.gemm_quantized(&x, &w);
    let yf = fabbed.gemm_quantized(&x, &w);
    assert_eq!(
        ys.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        yp.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "sparse capture: pool must be bit-identical to serial"
    );
    assert_eq!(
        yp.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        yf.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "sparse capture: fabric must be bit-identical to pool"
    );
    assert_eq!(serial.stats, pooled.stats);
    assert_eq!(serial.stats, fabbed.stats);
    assert!(serial.stats.skipped_rows > 0, "the zero rows must actually be skipped");
    assert!(serial.meter.skipped_adc > 0);
    assert!(serial.meter.skipped_dac > 0);
    for other in [&pooled.meter, &fabbed.meter] {
        assert_eq!(serial.meter.dac_conversions, other.dac_conversions);
        assert_eq!(serial.meter.adc_conversions, other.adc_conversions);
        assert_eq!(serial.meter.skipped_dac, other.skipped_dac);
        assert_eq!(serial.meter.skipped_adc, other.skipped_adc);
        assert_eq!(serial.meter.total_joules().to_bits(), other.total_joules().to_bits());
    }

    // decode-path identity: the batched two-tier RRNS decode and the
    // per-element reference decoder must perform (and skip) the same
    // conversions on the same sparse workload — conversion counts are a
    // capture-time property, decided before decode runs
    let clean = || RnsCoreConfig::for_bits(8, 128).with_rrns(2, 2).with_sparse_capture(true);
    let mut batched = RnsCore::new(clean()).unwrap();
    let mut reference = RnsCore::new(clean().with_reference_decode(true)).unwrap();
    let yb = batched.gemm_quantized(&x, &w);
    let yr = reference.gemm_quantized(&x, &w);
    assert_eq!(yb.data, yr.data, "decode paths must agree on sparse input");
    assert_eq!(batched.meter.dac_conversions, reference.meter.dac_conversions);
    assert_eq!(batched.meter.adc_conversions, reference.meter.adc_conversions);
    assert_eq!(batched.meter.skipped_dac, reference.meter.skipped_dac);
    assert_eq!(batched.meter.skipped_adc, reference.meter.skipped_adc);
    assert_eq!(batched.stats.skipped_rows, reference.stats.skipped_rows);
}

/// Cores with different moduli configurations can share one store
/// without collisions, and gemm through a store-shared plan matches a
/// private-store core exactly.
#[test]
fn mixed_configs_share_one_store_safely() {
    let mut rng = Rng::seed_from(3);
    let x = rand_mat(&mut rng, 3, 200, 1.0);
    let w = rand_mat(&mut rng, 200, 5, 0.5);
    let store = Arc::new(PlanStore::default());
    let mut b6 = RnsCore::with_store(RnsCoreConfig::for_bits(6, 128), Arc::clone(&store)).unwrap();
    let mut b8 = RnsCore::with_store(RnsCoreConfig::for_bits(8, 128), Arc::clone(&store)).unwrap();
    let mut b8_rrns = RnsCore::with_store(
        RnsCoreConfig::for_bits(8, 128).with_rrns(2, 2),
        Arc::clone(&store),
    )
    .unwrap();
    let y6 = b6.gemm_quantized(&x, &w);
    let y8 = b8.gemm_quantized(&x, &w);
    let y8r = b8_rrns.gemm_quantized(&x, &w);
    // same weights, three distinct (bits, moduli) configs => three plans
    assert_eq!(store.stats().builds, 3);
    // each matches a core with a private store bit-for-bit
    let mut p6 = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
    let mut p8 = RnsCore::new(RnsCoreConfig::for_bits(8, 128)).unwrap();
    let mut p8r = RnsCore::new(RnsCoreConfig::for_bits(8, 128).with_rrns(2, 2)).unwrap();
    assert_eq!(y6.data, p6.gemm_quantized(&x, &w).data);
    assert_eq!(y8.data, p8.gemm_quantized(&x, &w).data);
    assert_eq!(y8r.data, p8r.gemm_quantized(&x, &w).data);
}
