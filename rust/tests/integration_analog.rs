//! Integration tests for the analog simulator: RNS vs fixed-point cores,
//! energy accounting, and noise + RRNS interplay at GEMM level.

use rns_analog::analog::energy::{adc_energy, dac_energy};
use rns_analog::analog::{FixedPointCore, NoiseModel, RnsCore, RnsCoreConfig};
use rns_analog::nn::dataset::random_gemm_pair;
use rns_analog::quant::qmax;
use rns_analog::tensor::gemm::gemm_f32;
use rns_analog::tensor::MatF;
use rns_analog::util::rng::Rng;

fn mean_err(got: &MatF, want: &MatF) -> f64 {
    got.data.iter().zip(&want.data).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
        / want.data.len() as f64
}

#[test]
fn rns_error_is_quantization_bounded_all_bits() {
    let mut rng = Rng::seed_from(0);
    let (x, w) = random_gemm_pair(&mut rng, 6, 256, 12, 1.0);
    let want = gemm_f32(&x, &w);
    for bits in 4..=8u32 {
        let mut core = RnsCore::new(RnsCoreConfig::for_bits(bits, 128)).unwrap();
        let got = core.gemm_quantized(&x, &w);
        // per-element bound: K * (x_step*|w| + w_step*|x|) ~ K * 1.5/qm
        let tol = 256.0 * 1.5 / qmax(bits) as f64;
        let err = mean_err(&got, &want);
        assert!(err < tol, "bits={bits}: err {err} > tol {tol}");
    }
}

#[test]
fn fixed_point_loses_rns_does_not_across_tilings() {
    // same GEMM split across different array heights: RNS output is
    // invariant; fixed-point error grows with h (more dropped bits)
    let mut rng = Rng::seed_from(1);
    let (x, w) = random_gemm_pair(&mut rng, 4, 512, 8, 1.0);
    let want = gemm_f32(&x, &w);
    let mut rns_errs = Vec::new();
    let mut fxp_errs = Vec::new();
    for h in [128usize, 256, 512] {
        let mut cfg = RnsCoreConfig::for_bits(6, h);
        cfg.h = h;
        cfg.moduli = rns_analog::rns::select_moduli(6, h).unwrap();
        let mut rns = RnsCore::new(cfg).unwrap();
        let mut fxp = FixedPointCore::new(6, h, NoiseModel::None, 0);
        rns_errs.push(mean_err(&rns.gemm_quantized(&x, &w), &want));
        fxp_errs.push(mean_err(&fxp.gemm_quantized(&x, &w), &want));
    }
    // RNS: error stays at the quantization floor regardless of h
    let rns_spread = rns_errs.iter().fold(0.0f64, |a, &b| a.max(b))
        / rns_errs.iter().fold(f64::MAX, |a, &b| a.min(b));
    assert!(rns_spread < 3.0, "rns errors too spread: {rns_errs:?}");
    // fixed point at h=512 must be strictly worse than at h=128
    assert!(
        fxp_errs[2] > fxp_errs[0],
        "fxp err should grow with h: {fxp_errs:?}"
    );
    // and fixed point is always worse than RNS
    for (f, r) in fxp_errs.iter().zip(&rns_errs) {
        assert!(f > r);
    }
}

#[test]
fn energy_meters_match_analytic_model() {
    let mut rng = Rng::seed_from(2);
    let (x, w) = random_gemm_pair(&mut rng, 2, 128, 4, 1.0);
    let bits = 6u32;
    let mut core = RnsCore::new(RnsCoreConfig::for_bits(bits, 128)).unwrap();
    core.gemm_quantized(&x, &w);
    let n = core.n_channels() as f64;
    // DAC conversions: n * (2*128 inputs + 128*4 weights)
    let expect_dac = n * (2.0 * 128.0 + 128.0 * 4.0);
    assert_eq!(core.meter.dac_conversions as f64, expect_dac);
    assert!((core.meter.dac_joules - expect_dac * dac_energy(bits)).abs() < 1e-18);
    // ADC conversions: n * 2*4 outputs
    assert_eq!(core.meter.adc_conversions as f64, n * 8.0);
    assert!((core.meter.adc_joules - n * 8.0 * adc_energy(bits)).abs() < 1e-18);
}

#[test]
fn gaussian_noise_maps_to_residue_errors() {
    // a Gaussian channel with sigma 0.4 LSB should corrupt residues at
    // roughly erfc(0.5/(0.4*sqrt(2))) and RRNS should still hold accuracy
    let mut rng = Rng::seed_from(3);
    let (x, w) = random_gemm_pair(&mut rng, 6, 128, 8, 1.0);
    let want = gemm_f32(&x, &w);
    let noise = NoiseModel::Gaussian { sigma_lsb: 0.4 };
    let p_eff = noise.effective_p();
    assert!(p_eff > 0.1 && p_eff < 0.3, "effective p {p_eff}");
    let mut protected = RnsCore::new(
        RnsCoreConfig::for_bits(8, 128).with_noise(noise).with_rrns(2, 3).with_seed(7),
    )
    .unwrap();
    let mut unprotected =
        RnsCore::new(RnsCoreConfig::for_bits(8, 128).with_noise(noise).with_seed(7)).unwrap();
    let e_prot = mean_err(&protected.gemm_quantized(&x, &w), &want);
    let e_unprot = mean_err(&unprotected.gemm_quantized(&x, &w), &want);
    assert!(
        e_prot < e_unprot / 3.0,
        "rrns {e_prot} should beat unprotected {e_unprot} under gaussian noise"
    );
}

#[test]
fn rrns_attempts_reduce_exhaustion() {
    let mut rng = Rng::seed_from(4);
    let (x, w) = random_gemm_pair(&mut rng, 8, 128, 16, 1.0);
    let noise = NoiseModel::ResidueFlip { p: 0.08 };
    let mut one = RnsCore::new(
        RnsCoreConfig::for_bits(8, 128).with_noise(noise).with_rrns(2, 1).with_seed(5),
    )
    .unwrap();
    let mut many = RnsCore::new(
        RnsCoreConfig::for_bits(8, 128).with_noise(noise).with_rrns(2, 5).with_seed(5),
    )
    .unwrap();
    one.gemm_quantized(&x, &w);
    many.gemm_quantized(&x, &w);
    assert!(one.stats.detections > 0, "p=0.08 must trigger detections");
    assert!(
        many.stats.exhausted < one.stats.exhausted.max(1),
        "5 attempts ({}) should exhaust less than 1 attempt ({})",
        many.stats.exhausted,
        one.stats.exhausted
    );
}

#[test]
fn deterministic_under_seed() {
    let mut rng = Rng::seed_from(6);
    let (x, w) = random_gemm_pair(&mut rng, 4, 128, 8, 1.0);
    let noise = NoiseModel::ResidueFlip { p: 0.05 };
    let run = |seed: u64, rrns: bool| {
        let mut cfg = RnsCoreConfig::for_bits(6, 128).with_noise(noise).with_seed(seed);
        if rrns {
            cfg = cfg.with_rrns(2, 2);
        }
        let mut core = RnsCore::new(cfg).unwrap();
        core.gemm_quantized(&x, &w).data
    };
    assert_eq!(run(42, true), run(42, true), "same seed, same output");
    // unprotected core: noise shows through, so seeds diverge.  (With RRNS
    // both seeds may legitimately agree — everything gets corrected.)
    assert_ne!(run(42, false), run(43, false), "different seed, different noise");
}
