//! Integration tests for the PJRT runtime: the AOT-compiled pallas kernel
//! must be bit-identical to the native rust engine, including the padded /
//! tiled execution paths, and the full-pipeline artifact must match the
//! rust RnsCore.
//!
//! Tests skip silently when `make artifacts` has not run.

use rns_analog::analog::{RnsCore, RnsCoreConfig};
use rns_analog::nn::dataset::random_gemm_pair;
use rns_analog::runtime::{F32Input, Manifest, ModularGemmEngine, NativeEngine, PjrtEngine, PjrtRuntime};
use rns_analog::tensor::MatI;
use rns_analog::util::rng::Rng;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(&format!("{}/manifest.txt", artifacts_dir())).exists()
}

/// The PJRT client only exists when the crate is built with the `pjrt`
/// feature (default builds get the always-failing stub) — skip rather
/// than panic so `cargo test` stays green with artifacts present.
fn pjrt_runtime() -> Option<PjrtRuntime> {
    match PjrtRuntime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: PJRT unavailable ({e})");
            None
        }
    }
}

fn rand_residues(rng: &mut Rng, moduli: &[u64], rows: usize, cols: usize) -> Vec<MatI> {
    moduli
        .iter()
        .map(|&m| {
            MatI::from_vec(rows, cols, (0..rows * cols).map(|_| rng.gen_range(m) as i64).collect())
        })
        .collect()
}

#[test]
fn pjrt_engine_bit_identical_exact_shape() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let Some(rt) = pjrt_runtime() else {
        return;
    };
    for bits in [4u32, 6, 8] {
        let mut engine = PjrtEngine::load(&rt, &artifacts_dir(), bits).unwrap();
        let moduli = engine.moduli.clone();
        let mut rng = Rng::seed_from(bits as u64);
        let xr = rand_residues(&mut rng, &moduli, engine.batch, engine.h);
        let wr = rand_residues(&mut rng, &moduli, engine.h, engine.h);
        let got = engine.matmul_mod(&xr, &wr, &moduli);
        let want = NativeEngine::default().matmul_mod(&xr, &wr, &moduli);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.data, w.data, "bits={bits}");
        }
    }
}

#[test]
fn pjrt_engine_bit_identical_padded_and_tiled() {
    if !have_artifacts() {
        return;
    }
    let Some(rt) = pjrt_runtime() else {
        return;
    };
    let mut engine = PjrtEngine::load(&rt, &artifacts_dir(), 6).unwrap();
    let moduli = engine.moduli.clone();
    let mut rng = Rng::seed_from(77);
    // (rows, K, N) exercising padding (< artifact shape) and tiling (>)
    for (b, k, n) in [(1usize, 7usize, 3usize), (3, 128, 128), (11, 200, 140), (8, 300, 40)] {
        let xr = rand_residues(&mut rng, &moduli, b, k);
        let wr = rand_residues(&mut rng, &moduli, k, n);
        let got = engine.matmul_mod(&xr, &wr, &moduli);
        let want = NativeEngine::default().matmul_mod(&xr, &wr, &moduli);
        for (ch, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.data, w.data, "shape ({b},{k},{n}) channel {ch}");
        }
    }
}

#[test]
fn rns_core_identical_on_native_and_pjrt_engines() {
    if !have_artifacts() {
        return;
    }
    let mut rng = Rng::seed_from(5);
    let (x, w) = random_gemm_pair(&mut rng, 6, 192, 10, 1.0);
    let mut native = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
    let Some(rt) = pjrt_runtime() else {
        return;
    };
    let engine = PjrtEngine::load(&rt, &artifacts_dir(), 6).unwrap();
    let mut pjrt =
        RnsCore::with_engine(RnsCoreConfig::for_bits(6, 128), Box::new(engine)).unwrap();
    let a = native.gemm_quantized(&x, &w);
    let b = pjrt.gemm_quantized(&x, &w);
    assert_eq!(a.data, b.data, "cores must agree bit-for-bit (both exact)");
    assert_eq!(pjrt.engine_name(), "pjrt");
}

#[test]
fn full_pipeline_artifact_matches_rust_core() {
    if !have_artifacts() {
        return;
    }
    let Some(rt) = pjrt_runtime() else {
        return;
    };
    let exe = rt.load(&format!("{}/rns_gemm_b6.hlo.txt", artifacts_dir())).unwrap();
    let mut rng = Rng::seed_from(9);
    let (x, w) = random_gemm_pair(&mut rng, 8, 128, 128, 1.0);
    let got = exe
        .run_f32(&[
            F32Input { data: &x.data, dims: vec![8, 128] },
            F32Input { data: &w.data, dims: vec![128, 128] },
        ])
        .unwrap();
    let mut core = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
    let want = core.gemm_quantized(&x, &w);
    // both are the identical exact pipeline; f32 rescale rounding may differ
    // in the last ulp
    for (g, wv) in got.iter().zip(&want.data) {
        assert!((g - wv).abs() <= wv.abs() * 1e-5 + 1e-6, "{g} vs {wv}");
    }
}

#[test]
fn manifest_validation_and_mismatch_rejection() {
    if !have_artifacts() {
        return;
    }
    let manifest = Manifest::load(&artifacts_dir()).unwrap();
    assert_eq!(manifest.h, 128);
    assert_eq!(manifest.batch, 8);
    let Some(rt) = pjrt_runtime() else {
        return;
    };
    let mut engine = PjrtEngine::load(&rt, &artifacts_dir(), 6).unwrap();
    // asking the engine for different moduli than were baked must fail loudly
    let wrong = vec![255u64, 254, 253];
    let xr = rand_residues(&mut Rng::seed_from(1), &wrong, 2, 8);
    let wr = rand_residues(&mut Rng::seed_from(2), &wrong, 8, 2);
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        engine.matmul_mod(&xr, &wr, &wrong)
    }));
    assert!(res.is_err(), "moduli mismatch must be rejected");
}

#[test]
fn missing_bits_artifact_is_clean_error() {
    if !have_artifacts() {
        return;
    }
    let Some(rt) = pjrt_runtime() else {
        return;
    };
    assert!(PjrtEngine::load(&rt, &artifacts_dir(), 12).is_err());
}
