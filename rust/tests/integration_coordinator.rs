//! Integration tests for the L3 coordinator: batching, multi-worker
//! dispatch, RNS backends under serving load, and fault surfacing.
//!
//! Model-dependent tests skip silently when `make artifacts` has not run.

use std::time::Duration;

use rns_analog::analog::NoiseModel;
use rns_analog::coordinator::{BackendKind, BatcherConfig, Coordinator, CoordinatorConfig};
use rns_analog::nn::dataset::load_eval_set;
use rns_analog::nn::models::Batch;
use rns_analog::tensor::Nhwc;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(&format!("{}/models/mlp.rt", artifacts_dir())).exists()
}

fn img(n: usize) -> Batch {
    Batch::Images(Nhwc::zeros(n, 28, 28, 1))
}

#[test]
fn serves_through_rns_core() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = CoordinatorConfig::new(
        BackendKind::Rns { bits: 6, redundant: 0, attempts: 1, noise: NoiseModel::None },
        &artifacts_dir(),
    );
    cfg.workers = 2;
    let coord = Coordinator::start(cfg);
    for _ in 0..12 {
        coord.submit("mlp", img(1));
    }
    let resps = coord.collect(12);
    assert!(resps.iter().all(|r| r.result.is_ok()));
    // both workers should have participated under round-robin dispatch
    let workers: std::collections::BTreeSet<usize> = resps.iter().map(|r| r.worker).collect();
    assert!(!workers.is_empty());
    let report = coord.shutdown();
    assert!(report.contains("requests=12"));
}

#[test]
fn rns_predictions_match_direct_inference() {
    if !have_artifacts() {
        return;
    }
    // serving through the coordinator must yield the same logits as direct
    // single-threaded inference with an identical core (clean, no noise)
    use rns_analog::analog::{RnsCore, RnsCoreConfig};
    use rns_analog::nn::models::load_model;

    let eval = load_eval_set(&artifacts_dir(), "digits").unwrap().take(4);
    let imgs = match &eval.input {
        Batch::Images(t) => t.clone(),
        _ => unreachable!(),
    };
    let model = load_model(&artifacts_dir(), "mlp").unwrap();
    let mut core = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
    let direct = model.forward(&Batch::Images(imgs.clone()), &mut core);

    let cfg = CoordinatorConfig::new(
        BackendKind::Rns { bits: 6, redundant: 0, attempts: 1, noise: NoiseModel::None },
        &artifacts_dir(),
    );
    let coord = Coordinator::start(cfg);
    let id = coord.submit("mlp", Batch::Images(imgs));
    let resp = coord.recv_timeout(Duration::from_secs(60)).expect("response");
    assert_eq!(resp.id, id);
    let served = resp.result.unwrap();
    assert_eq!(served.data, direct.data, "served logits must equal direct inference");
    coord.shutdown();
}

#[test]
fn batcher_aggregates_under_load() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = CoordinatorConfig::new(BackendKind::Fp32, &artifacts_dir());
    cfg.workers = 1;
    cfg.batcher = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(50), ..Default::default() };
    let coord = Coordinator::start(cfg);
    for _ in 0..32 {
        coord.submit("mlp", img(1));
    }
    let resps = coord.collect(32);
    assert_eq!(resps.len(), 32);
    let report = coord.shutdown();
    // 32 single-sample requests at max_batch 8 -> roughly 4-8 batches, far
    // fewer than 32 (dynamic batching actually happened)
    assert!(report.contains("batches="));
    let batches: u64 = report
        .split("batches=")
        .nth(1)
        .unwrap()
        .split_whitespace()
        .next()
        .unwrap()
        .parse()
        .unwrap();
    assert!(batches <= 16, "expected aggregation, got {batches} batches");
}

#[test]
fn mixed_models_served_concurrently() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = CoordinatorConfig::new(BackendKind::Fp32, &artifacts_dir());
    cfg.workers = 2;
    let coord = Coordinator::start(cfg);
    let tokens = Batch::Tokens { tokens: vec![1; 32], batch: 1, seq: 32 };
    let mut expected = Vec::new();
    for i in 0..10 {
        if i % 2 == 0 {
            expected.push((coord.submit("mlp", img(1)), 10usize));
        } else {
            expected.push((coord.submit("bert", tokens_clone(&tokens)), 4usize));
        }
    }
    let resps = coord.collect(10);
    for r in &resps {
        let (_, classes) = expected.iter().find(|(id, _)| *id == r.id).unwrap();
        assert_eq!(r.result.as_ref().unwrap().cols, *classes);
    }
    coord.shutdown();
}

fn tokens_clone(b: &Batch) -> Batch {
    match b {
        Batch::Tokens { tokens, batch, seq } => {
            Batch::Tokens { tokens: tokens.clone(), batch: *batch, seq: *seq }
        }
        _ => unreachable!(),
    }
}

#[test]
fn noisy_rrns_backend_serves_and_reports_faults() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = CoordinatorConfig::new(
        BackendKind::Rns {
            bits: 8,
            redundant: 2,
            attempts: 2,
            noise: NoiseModel::ResidueFlip { p: 0.02 },
        },
        &artifacts_dir(),
    );
    cfg.workers = 1;
    let coord = Coordinator::start(cfg);
    for _ in 0..4 {
        coord.submit("mlp", img(1));
    }
    let resps = coord.collect(4);
    assert!(resps.iter().all(|r| r.result.is_ok()));
    let report = coord.shutdown();
    let field = |key: &str| -> u64 {
        report
            .split(key)
            .nth(1)
            .unwrap_or_else(|| panic!("missing `{key}` in report: {report}"))
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap()
    };
    // with p=0.02 over thousands of decodes, corrections must appear
    let corrected = field("corrected=");
    assert!(corrected > 0, "expected RRNS corrections in report: {report}");
    // and the two-tier decode must have fast-pathed the bulk of them
    let fast = field("fast-path=");
    let voted = field("voted=");
    assert!(fast > 0, "expected fast-path decodes in report: {report}");
    assert!(fast > voted, "p=0.02 should leave most elements clean: {report}");
}

#[test]
fn shutdown_with_no_requests_is_clean() {
    let cfg = CoordinatorConfig::new(BackendKind::Fp32, "/nonexistent");
    let coord = Coordinator::start(cfg);
    let report = coord.shutdown();
    assert!(report.contains("requests=0"));
}
