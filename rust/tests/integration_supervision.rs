//! Supervised-serving integration tests over the TCP gateway: seeded
//! chaos kills workers mid-stream and every accepted request is still
//! answered with logits bit-identical to a crash-free run (inference is
//! pure under `NoiseModel::None`); poison batches earn a typed
//! `Poisoned` reject that the retry client does NOT retry; injected
//! connection drops are survived by the retry client's
//! reconnect-and-retry path; and per-request wire deadlines come back as
//! typed `DeadlineExceeded`.
//!
//! Every test serves `synthetic-mlp` (seeded in-process weights), so no
//! `make artifacts` step is needed anywhere.

use std::time::Duration;

use rns_analog::analog::NoiseModel;
use rns_analog::coordinator::{BackendKind, ChaosSpec, Coordinator, CoordinatorConfig};
use rns_analog::net::{Client, ClientError, Gateway, GatewayConfig, RetryClient, RetryPolicy};
use rns_analog::nn::models::{Batch, SYNTHETIC_MLP};
use rns_analog::tensor::Nhwc;
use rns_analog::util::rng::Rng;

fn rns_cfg(workers: usize, chaos: &str) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::new(
        BackendKind::Rns { bits: 8, redundant: 2, attempts: 2, noise: NoiseModel::None },
        "/nonexistent",
    );
    cfg.workers = workers;
    cfg.seed = 7;
    cfg.chaos = ChaosSpec::parse(chaos).expect("valid chaos spec");
    cfg
}

fn gw_cfg() -> GatewayConfig {
    GatewayConfig {
        listen_addr: "127.0.0.1:0".into(),
        max_sessions: 16,
        idle_timeout: Duration::from_secs(10),
        ..GatewayConfig::default()
    }
}

/// Deterministic single-sample input #i.
fn input(i: u64) -> Batch {
    let mut rng = Rng::seed_from(0xBEEF ^ i);
    Batch::Images(Nhwc::from_vec(
        1,
        28,
        28,
        1,
        (0..28 * 28).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
    ))
}

fn line_with<'a>(report: &'a str, prefix: &str) -> &'a str {
    report
        .lines()
        .find(|l| l.starts_with(prefix))
        .unwrap_or_else(|| panic!("no `{prefix}` line in report:\n{report}"))
}

/// Serve `n` sequential round trips over the gateway, returning the
/// logits bit patterns per request plus the final report.
fn run_gateway(workers: usize, chaos: &str, n: u64) -> (Vec<Vec<u32>>, String) {
    let mut gcfg = gw_cfg();
    gcfg.chaos = ChaosSpec::parse(chaos).expect("valid chaos spec");
    let gw = Gateway::start(Coordinator::start(rns_cfg(workers, chaos)), gcfg).expect("gateway");
    let addr = gw.local_addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");
    let mut out = Vec::new();
    for i in 0..n {
        let reply = client.infer(SYNTHETIC_MLP, &input(i)).expect("infer");
        assert_eq!((reply.logits.rows, reply.logits.cols), (1, 10));
        out.push(reply.logits.data.iter().map(|v| v.to_bits()).collect());
    }
    client.close();
    (out, gw.shutdown())
}

/// The headline chaos test: with W=4 workers and an injected panic on
/// worker 0's first batch, the supervisor respawns the worker and
/// redispatches the dead worker's batch — every request is answered,
/// zero failures, and the logits (plus the RRNS decode/fault counters)
/// are bit-identical to the crash-free run.
#[test]
fn crashed_worker_chaos_run_is_bit_identical_to_clean_run() {
    const N: u64 = 8; // two round-robin laps over 4 workers
    let (want, clean_report) = run_gateway(4, "", N);
    let (got, chaos_report) = run_gateway(4, "panic@w0:b1", N);
    for i in 0..N as usize {
        assert_eq!(got[i], want[i], "request {i}: chaos run == clean run, bit-exact");
    }
    // crash-free path: nothing supervised
    assert!(
        clean_report.contains("respawns=0 stalls=0 redispatched=0 poisoned=0"),
        "{clean_report}"
    );
    // chaos path: exactly one crash, one respawn, one redispatch — and
    // the client never saw any of it
    let sup = line_with(&chaos_report, "supervision: ");
    assert!(sup.contains("respawns=1"), "{chaos_report}");
    assert!(sup.contains("stalls=0"), "{chaos_report}");
    assert!(sup.contains("redispatched=1"), "{chaos_report}");
    assert!(sup.contains("poisoned=0"), "{chaos_report}");
    assert!(chaos_report.contains(&format!("requests={N}")), "{chaos_report}");
    assert!(chaos_report.contains("failures=0"), "{chaos_report}");
    // the analog accounting the paper cares about is also unchanged by
    // the crash: the partial forward on the dead worker never lands in
    // the counters (per-batch delta flush), so the RRNS decode split and
    // fault totals agree line for line.  (DAC counts legitimately differ:
    // the respawned worker re-warms its weight DACs.)
    for prefix in ["decode: ", "faults: "] {
        assert_eq!(
            line_with(&clean_report, prefix),
            line_with(&chaos_report, prefix),
            "`{prefix}` line must match\n--- clean:\n{clean_report}\n--- chaos:\n{chaos_report}"
        );
    }
}

/// A batch that crashes every worker it touches is quarantined after
/// `poison_threshold` crashes and rejected with the typed `Poisoned`
/// code — and the retry client fails fast instead of hammering it.
#[test]
fn poison_batch_is_rejected_typed_and_not_retried() {
    let mut cfg = rns_cfg(2, "poison@synthetic-mlp");
    cfg.poison_threshold = 2;
    let gw = Gateway::start(Coordinator::start(cfg), gw_cfg()).expect("gateway");
    let addr = gw.local_addr().to_string();

    let policy = RetryPolicy { base: Duration::from_millis(1), ..RetryPolicy::default() };
    let mut client = RetryClient::new(&addr, policy);
    let err = client.infer(SYNTHETIC_MLP, &input(0)).expect_err("poisoned batch must fail");
    match &err {
        ClientError::Server { code, message } => {
            assert_eq!(format!("{code:?}"), "Poisoned");
            assert!(message.contains("quarantined"), "{message}");
        }
        other => panic!("expected a typed Poisoned reject, got {other:?}"),
    }
    assert!(!err.is_retryable(), "poison is permanent for this input");
    assert_eq!(client.retries, 0, "fail-fast: no retry budget burned");
    client.close();

    let report = gw.shutdown();
    let sup = line_with(&report, "supervision: ");
    assert!(sup.contains("poisoned=1"), "{report}");
    assert!(sup.contains("respawns=2"), "two crashes before quarantine: {report}");
    assert!(report.contains("failures=1"), "{report}");
}

/// An injected connection drop (`drop@s0:f1`: session 0 severed right
/// after its first frame) is survived by the retry client: it
/// reconnects and re-executes, and the replies are bit-identical to a
/// drop-free run (inference is pure).
#[test]
fn connection_drop_is_survived_by_the_retry_client() {
    let (want, _) = run_gateway(1, "", 2);

    let mut gcfg = gw_cfg();
    gcfg.chaos = ChaosSpec::parse("drop@s0:f1").unwrap();
    let gw = Gateway::start(Coordinator::start(rns_cfg(1, "")), gcfg).expect("gateway");
    let addr = gw.local_addr().to_string();

    let policy =
        RetryPolicy { retries: 4, base: Duration::from_millis(1), ..RetryPolicy::default() };
    let mut client = RetryClient::new(&addr, policy);
    for i in 0..2u64 {
        let reply = client.infer(SYNTHETIC_MLP, &input(i)).expect("retry client recovers");
        let got: Vec<u32> = reply.logits.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want[i as usize], "request {i}: recovered run == clean run, bit-exact");
    }
    // session 0 was severed by chaos, so at least one reconnect happened
    // (whether the first reply escaped the drop is a race; the recovery
    // is what's under test)
    assert!(client.reconnects >= 1, "the drop forced a reconnect");
    client.close();
    gw.shutdown();
}

/// A per-request deadline travels the wire (`Infer.deadline_ms`), is
/// enforced server-side during an injected stall, and comes back as the
/// typed `DeadlineExceeded` code — which the client treats as permanent.
#[test]
fn wire_deadline_is_enforced_and_typed() {
    // one worker whose first batch stalls 200 ms; the stall timeout
    // stays at its 30 s default so the supervisor leaves it alone
    let gw =
        Gateway::start(Coordinator::start(rns_cfg(1, "stall@w0:b1:200ms")), gw_cfg()).expect("gw");
    let addr = gw.local_addr().to_string();

    let mut client = Client::connect(&addr).expect("connect");
    client.set_deadline_ms(30);
    let err = client.infer(SYNTHETIC_MLP, &input(0)).expect_err("deadline must fire");
    assert!(err.contains("DeadlineExceeded"), "typed code in: {err}");
    // the next request (no stall, no deadline) is served normally on the
    // same session
    client.set_deadline_ms(0);
    let reply = client.infer(SYNTHETIC_MLP, &input(1)).expect("infer after the deadline miss");
    assert_eq!((reply.logits.rows, reply.logits.cols), (1, 10));
    client.close();

    let report = gw.shutdown();
    let sup = line_with(&report, "supervision: ");
    assert!(sup.contains("deadline-exceeded=1"), "{report}");
    assert!(sup.contains("respawns=0"), "a stall below the timeout is not a crash: {report}");
    assert!(report.contains("failures=1"), "{report}");
}
