//! Integration tests across the RNS substrate: moduli selection ↔ CRT ↔
//! Barrett ↔ RRNS working together at every Table-I configuration.

use rns_analog::rns::fault_model::{estimate_case_probs, CaseProbs};
use rns_analog::rns::moduli::{extend_moduli, paper_table1, required_output_bits, select_moduli};
use rns_analog::rns::rrns::{Decode, RrnsCode};
use rns_analog::rns::{BarrettReducer, RnsContext};
use rns_analog::tensor::gemm::{gemm_i64, gemm_mod};
use rns_analog::tensor::MatI;
use rns_analog::util::rng::Rng;

#[test]
fn full_dot_product_pipeline_every_table1_config() {
    // quantized dot products through forward conversion -> per-channel
    // modular GEMM (Barrett inside) -> CRT must equal exact i64 GEMM for
    // every paper configuration.
    let mut rng = Rng::seed_from(100);
    for bits in 4..=8u32 {
        let h = 128usize;
        let moduli = select_moduli(bits, h).unwrap();
        assert_eq!(moduli.as_slice(), paper_table1(bits).unwrap());
        let ctx = RnsContext::new(&moduli).unwrap();
        let qm = (1i64 << (bits - 1)) - 1;
        let x = MatI::from_vec(4, h, (0..4 * h).map(|_| rng.gen_range_i64(-qm, qm)).collect());
        let w = MatI::from_vec(h, 8, (0..h * 8).map(|_| rng.gen_range_i64(-qm, qm)).collect());
        let exact = gemm_i64(&x, &w);
        // residue channels
        let outs: Vec<MatI> = moduli
            .iter()
            .map(|&m| {
                let xr = x.map(|v| v.rem_euclid(m as i64));
                let wr = w.map(|v| v.rem_euclid(m as i64));
                gemm_mod(&xr, &wr, m)
            })
            .collect();
        for r in 0..4 {
            for c in 0..8 {
                let res: Vec<u64> = outs.iter().map(|o| o.at(r, c) as u64).collect();
                assert_eq!(
                    ctx.crt_signed(&res),
                    exact.at(r, c) as i128,
                    "bits={bits} r={r} c={c}"
                );
            }
        }
        // Eq. 4 range check: outputs fit the chosen M
        let b_out = required_output_bits(bits, bits, h);
        assert!(exact.data.iter().all(|&v| (v.unsigned_abs() as u128) < (1u128 << b_out)));
    }
}

#[test]
fn barrett_consistent_with_crt_context() {
    let ctx = RnsContext::new(paper_table1(7).unwrap()).unwrap();
    let mut rng = Rng::seed_from(5);
    for _ in 0..500 {
        let v = rng.next_u64() >> 2;
        for &m in &ctx.moduli {
            let b = BarrettReducer::new(m);
            assert_eq!(b.reduce(v), v % m);
        }
    }
}

#[test]
fn rrns_end_to_end_correction_rates() {
    // inject exactly t errors -> always corrected.
    for bits in [6u32, 8] {
        let base = paper_table1(bits).unwrap();
        let all = extend_moduli(base, 2).unwrap();
        let code = RrnsCode::new(&all, base.len()).unwrap();
        let t = code.correctable();
        assert_eq!(t, 1);
        let mut rng = Rng::seed_from(bits as u64);
        let half = (code.legitimate_range / 2) as i64;
        let mut corrected = 0;
        for _ in 0..300 {
            let a = rng.gen_range_i64(-(half - 1), half);
            let mut res = code.encode(a);
            let i = rng.gen_range(code.n() as u64) as usize;
            res[i] = (res[i] + 1 + rng.gen_range(all[i] - 1)) % all[i];
            match code.decode(&res) {
                Decode::Ok { value, .. } => {
                    assert_eq!(value, a as i128, "single error must correct exactly");
                    corrected += 1;
                }
                Decode::Detected => panic!("single error must be correctable"),
            }
        }
        assert_eq!(corrected, 300);
    }
}

#[test]
fn fault_model_matches_decoder_behaviour() {
    // p_err(1) == 1 - p_c by definition; limit sandwiched by attempts
    let base = paper_table1(8).unwrap();
    let all = extend_moduli(base, 2).unwrap();
    let code = RrnsCode::new(&all, base.len()).unwrap();
    let cp: CaseProbs = estimate_case_probs(&code, 0.05, 30_000, 9);
    assert!((cp.p_err(1) - (1.0 - cp.p_c)).abs() < 1e-12);
    assert!(cp.p_err(10) >= cp.p_err_limit() - 1e-12);
    assert!(cp.p_err(1) >= cp.p_err(10));
    // at p = 0.05 with n-k = 2 the decoder should usually succeed
    assert!(cp.p_c > 0.9, "p_c = {}", cp.p_c);
}

#[test]
fn redundant_moduli_have_enob_within_budget() {
    // redundancy must not exceed the data-converter bit budget (paper §V:
    // converters scale linearly with extra moduli but stay b-bit)
    for bits in 4..=8u32 {
        let base = paper_table1(bits).unwrap();
        if let Ok(all) = extend_moduli(base, 2) {
            for &m in &all {
                assert!(m < (1u64 << bits), "bits={bits} m={m}");
            }
        }
    }
}

#[test]
fn signed_range_boundaries_roundtrip() {
    for bits in 4..=8u32 {
        let ctx = RnsContext::new(paper_table1(bits).unwrap()).unwrap();
        let half = (ctx.big_m / 2) as i64;
        for a in [-(half - 1), -1, 0, 1, half - 1, half] {
            assert_eq!(ctx.crt_signed(&ctx.forward(a)), a as i128, "bits={bits} a={a}");
        }
    }
}
