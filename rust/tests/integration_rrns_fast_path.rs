//! Determinism / equivalence of the two-tier batched RRNS decode against
//! the per-element voting reference, end-to-end through `RnsCore`.
//!
//! The contract under test: under identical seeds, the batched pipeline
//! (tier-1 whole-tile consistency pre-check + tier-2 voting fallback) and
//! the reference all-voting path produce bit-identical `MatI`/`MatF`
//! outputs, identical fault counters, and identical energy totals — for
//! clean tiles, <=-correctable fault rates, and beyond-correctable noise.

use rns_analog::analog::{NoiseModel, RnsCore, RnsCoreConfig};
use rns_analog::tensor::MatF;
use rns_analog::util::rng::Rng;

fn rand_mat(seed: u64, rows: usize, cols: usize, scale: f32) -> MatF {
    let mut rng = Rng::seed_from(seed);
    MatF::from_vec(
        rows,
        cols,
        (0..rows * cols).map(|_| rng.uniform_f32(-scale, scale)).collect(),
    )
}

/// Run the same GEMM through a batched-decode core and a reference-decode
/// core under one config, returning both cores for counter inspection.
fn run_pair(cfg: RnsCoreConfig, x: &MatF, w: &MatF) -> (RnsCore, RnsCore) {
    let mut fast = RnsCore::new(cfg.clone()).unwrap();
    let mut refc = RnsCore::new(cfg.with_reference_decode(true)).unwrap();
    let ya = fast.gemm_quantized(x, w);
    let yb = refc.gemm_quantized(x, w);
    assert_eq!(ya.data, yb.data, "batched and reference decode must be bit-identical");
    (fast, refc)
}

#[test]
fn bit_identical_across_fault_regimes_and_seeds() {
    // K = 300 on h = 128 -> 3 K-tiles, 4x6 outputs -> 72 decoded elements
    let x = rand_mat(1, 4, 300, 1.0);
    let w = rand_mat(2, 300, 6, 0.5);
    for p in [0.0, 0.01, 0.05, 0.2] {
        for seed in [3u64, 17, 4242] {
            let cfg = RnsCoreConfig::for_bits(8, 128)
                .with_noise(NoiseModel::ResidueFlip { p })
                .with_rrns(2, 3)
                .with_seed(seed);
            let (fast, refc) = run_pair(cfg, &x, &w);
            // decoded counts each output element exactly once per tile
            assert_eq!(fast.stats.decoded, 3 * 24, "p={p} seed={seed}");
            assert_eq!(fast.stats.decoded, refc.stats.decoded);
            assert_eq!(fast.stats.corrected, refc.stats.corrected, "p={p} seed={seed}");
            assert_eq!(fast.stats.detections, refc.stats.detections, "p={p} seed={seed}");
            assert_eq!(fast.stats.exhausted, refc.stats.exhausted, "p={p} seed={seed}");
            // the two-tier split partitions decoded; the reference votes all
            assert_eq!(
                fast.stats.fast_path_elems + fast.stats.voted_elems,
                fast.stats.decoded
            );
            assert_eq!(refc.stats.voted_elems, refc.stats.decoded);
            assert_eq!(refc.stats.fast_path_elems, 0);
            // energy totals agree: same CRT/ADC/DAC charges on both paths
            assert_eq!(fast.meter.adc_conversions, refc.meter.adc_conversions);
            assert_eq!(fast.meter.dac_conversions, refc.meter.dac_conversions);
            assert!((fast.meter.total_joules() - refc.meter.total_joules()).abs() < 1e-18);
        }
    }
}

#[test]
fn clean_tiles_fully_fast_path() {
    let x = rand_mat(5, 3, 256, 1.0);
    let w = rand_mat(6, 256, 8, 1.0);
    let cfg = RnsCoreConfig::for_bits(6, 128).with_rrns(2, 2);
    let (fast, refc) = run_pair(cfg, &x, &w);
    assert_eq!(fast.stats.decoded, 2 * 24); // 2 K-tiles x 3x8
    assert_eq!(fast.stats.fast_path_elems, fast.stats.decoded);
    assert_eq!(fast.stats.voted_elems, 0);
    assert_eq!(fast.stats.detections, 0);
    assert_eq!(refc.stats.voted_elems, refc.stats.decoded);
}

#[test]
fn heavy_noise_exercises_retry_and_exhaustion_identically() {
    // p = 0.35 with max_attempts = 2: plenty of Case-2 detections and
    // exhausted elements; the retry loop draws fresh noise, so this is
    // the strongest RNG-stream equivalence check
    let x = rand_mat(7, 4, 128, 1.0);
    let w = rand_mat(8, 128, 8, 0.5);
    let cfg = RnsCoreConfig::for_bits(8, 128)
        .with_noise(NoiseModel::ResidueFlip { p: 0.35 })
        .with_rrns(2, 2)
        .with_seed(11);
    let (fast, refc) = run_pair(cfg, &x, &w);
    assert!(fast.stats.detections > 0, "p=0.35 must trigger detections");
    assert!(fast.stats.exhausted > 0, "p=0.35 with R=2 must exhaust some elements");
    assert_eq!(fast.stats.detections, refc.stats.detections);
    assert_eq!(fast.stats.exhausted, refc.stats.exhausted);
    assert!(fast.stats.voted_elems > 0);
}

#[test]
fn decoded_counts_are_exact_under_retries() {
    // retries must inflate `detections`, never `decoded`:
    // decoded == tiles x output elements exactly, on both paths
    let x = rand_mat(9, 4, 384, 1.0);
    let w = rand_mat(10, 384, 5, 1.0);
    let cfg = RnsCoreConfig::for_bits(8, 128)
        .with_noise(NoiseModel::ResidueFlip { p: 0.15 })
        .with_rrns(2, 4)
        .with_seed(23);
    let (fast, refc) = run_pair(cfg, &x, &w);
    let expect = 3 * (4 * 5) as u64; // 3 K-tiles x 4x5 outputs
    assert_eq!(fast.stats.decoded, expect);
    assert_eq!(refc.stats.decoded, expect);
    assert!(fast.stats.detections > 0, "retries must have happened for this check to bite");
    assert_eq!(fast.stats.fast_path_elems + fast.stats.voted_elems, expect);
}

#[test]
fn prepared_and_unprepared_paths_share_the_two_tier_decode() {
    // the plan path (gemm_quantized) and the unprepared reference path
    // must both route through the same decode tiers and stay bit-identical
    let x = rand_mat(12, 3, 200, 1.0);
    let w = rand_mat(13, 200, 4, 0.5);
    let cfg = RnsCoreConfig::for_bits(8, 128)
        .with_noise(NoiseModel::ResidueFlip { p: 0.02 })
        .with_rrns(2, 3)
        .with_seed(31);
    let mut prep = RnsCore::new(cfg.clone()).unwrap();
    let mut unprep = RnsCore::new(cfg).unwrap();
    let ya = prep.gemm_quantized(&x, &w);
    let yb = unprep.gemm_quantized_unprepared(&x, &w);
    assert_eq!(ya.data, yb.data);
    assert_eq!(prep.stats.decoded, unprep.stats.decoded);
    assert_eq!(prep.stats.fast_path_elems, unprep.stats.fast_path_elems);
    assert_eq!(prep.stats.voted_elems, unprep.stats.voted_elems);
}
