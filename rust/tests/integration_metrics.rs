//! Observability integration tests (loopback): the Prometheus text
//! exposition served at `GET /metrics?format=prometheus` is scraped
//! over a real TCP connection, parsed with an in-test grammar checker
//! (HELP/TYPE before samples, cumulative monotone buckets, terminal
//! `le="+Inf"` equal to `_count`), and cross-checked **exactly**
//! against the legacy human-readable report — both render the same
//! registry atomics, so `rns_adc_conversions_total` must equal the
//! report's `adc-conversions=` to the last digit.  Per-stage pipeline
//! histograms must be populated after a served batch, and the `Traces`
//! wire frame must return the slowest-request ring.
//!
//! Serves `synthetic-mlp` (seeded in-process weights), so no
//! `make artifacts` step is needed.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use rns_analog::analog::NoiseModel;
use rns_analog::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use rns_analog::net::{Client, Gateway, GatewayConfig};
use rns_analog::nn::models::{Batch, SYNTHETIC_MLP};
use rns_analog::tensor::Nhwc;
use rns_analog::util::rng::Rng;

fn rns_cfg(workers: usize) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::new(
        BackendKind::Rns { bits: 8, redundant: 2, attempts: 2, noise: NoiseModel::None },
        "/nonexistent",
    );
    cfg.workers = workers;
    cfg.seed = 7;
    cfg
}

fn gw_cfg() -> GatewayConfig {
    GatewayConfig {
        listen_addr: "127.0.0.1:0".into(),
        max_sessions: 8,
        idle_timeout: Duration::from_secs(10),
        ..GatewayConfig::default()
    }
}

fn input(i: u64) -> Batch {
    let mut rng = Rng::seed_from(0xFACE ^ i);
    Batch::Images(Nhwc::from_vec(
        1,
        28,
        28,
        1,
        (0..28 * 28).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
    ))
}

fn http_get(addr: &str, method: &str, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "{method} {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).expect("response");
    let (headers, body) = out.split_once("\r\n\r\n").expect("header terminator");
    (headers.to_string(), body.to_string())
}

/// Minimal exposition parser: samples as `(name, sorted labels) ->
/// value`, validating the 0.0.4 grammar along the way.  Panics on any
/// malformed line — the test *is* the parser's error report.
struct Exposition {
    types: BTreeMap<String, String>,
    samples: Vec<(String, BTreeMap<String, String>, f64)>,
}

impl Exposition {
    fn parse(text: &str) -> Self {
        let mut types = BTreeMap::new();
        let mut helped = std::collections::BTreeSet::new();
        let mut samples = Vec::new();
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let fam = rest.split(' ').next().expect("HELP family");
                assert!(helped.insert(fam.to_string()), "duplicate HELP for {fam}");
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split(' ');
                let fam = it.next().expect("TYPE family").to_string();
                let kind = it.next().expect("TYPE kind").to_string();
                assert!(helped.contains(&fam), "TYPE before HELP for {fam}: {line}");
                assert!(
                    matches!(kind.as_str(), "counter" | "gauge" | "histogram"),
                    "unknown TYPE `{kind}`"
                );
                assert!(types.insert(fam.clone(), kind).is_none(), "duplicate TYPE for {fam}");
                continue;
            }
            assert!(!line.starts_with('#'), "unexpected comment shape: {line}");
            let (series, value) = line.rsplit_once(' ').expect("sample line");
            let value: f64 = value.replace("+Inf", "inf").parse().expect("sample value");
            let (name, labels) = match series.split_once('{') {
                Some((n, rest)) => {
                    let rest = rest.strip_suffix('}').expect("closing brace");
                    let mut labels = BTreeMap::new();
                    for pair in split_pairs(rest) {
                        let (k, v) = pair.split_once('=').expect("label pair");
                        let v = v.strip_prefix('"').and_then(|v| v.strip_suffix('"'));
                        labels.insert(k.to_string(), v.expect("quoted value").to_string());
                    }
                    (n.to_string(), labels)
                }
                None => (series.to_string(), BTreeMap::new()),
            };
            // every sample belongs to an announced family
            let fam = types
                .keys()
                .find(|f| {
                    name == **f
                        || (types[*f] == "histogram"
                            && ["_bucket", "_sum", "_count"]
                                .iter()
                                .any(|s| name == format!("{f}{s}")))
                })
                .unwrap_or_else(|| panic!("sample `{name}` has no HELP/TYPE"));
            if types[fam] == "counter" {
                assert!(value >= 0.0, "negative counter {name}");
            }
            samples.push((name, labels, value));
        }
        let out = Self { types, samples };
        out.check_histograms();
        out
    }

    /// Cumulative monotone buckets per series, `+Inf` terminal == count.
    fn check_histograms(&self) {
        for (fam, kind) in &self.types {
            if kind != "histogram" {
                continue;
            }
            // group buckets by the non-`le` label set
            let mut series: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
            for (name, labels, value) in &self.samples {
                if *name != format!("{fam}_bucket") {
                    continue;
                }
                let le: f64 =
                    labels["le"].replace("+Inf", "inf").parse().expect("le bound");
                let key = key_without_le(labels);
                series.entry(key).or_default().push((le, *value));
            }
            for (key, buckets) in series {
                let mut prev_le = f64::NEG_INFINITY;
                let mut prev_v = -1.0;
                for &(le, v) in &buckets {
                    assert!(le > prev_le, "{fam}{{{key}}}: le bounds out of order");
                    assert!(v >= prev_v, "{fam}{{{key}}}: buckets not cumulative");
                    (prev_le, prev_v) = (le, v);
                }
                let (last_le, last_v) = *buckets.last().expect("buckets");
                assert!(last_le.is_infinite(), "{fam}{{{key}}}: no +Inf bucket");
                let count = self.value(&format!("{fam}_count"), &key);
                assert_eq!(last_v, count, "{fam}{{{key}}}: +Inf bucket != _count");
            }
        }
    }

    /// Sample value by name + non-`le` label key ("" = unlabeled).
    fn value(&self, name: &str, key: &str) -> f64 {
        self.samples
            .iter()
            .find(|(n, labels, _)| n == name && key_without_le(labels) == key)
            .unwrap_or_else(|| panic!("no sample `{name}` with labels `{key}`"))
            .2
    }
}

fn key_without_le(labels: &BTreeMap<String, String>) -> String {
    labels
        .iter()
        .filter(|(k, _)| *k != "le")
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect::<Vec<_>>()
        .join(",")
}

/// Pull `key=<int>` out of the human-readable report.
fn report_value(report: &str, key: &str) -> u64 {
    report
        .split_whitespace()
        .find_map(|t| t.strip_prefix(key).and_then(|v| v.parse().ok()))
        .unwrap_or_else(|| panic!("no `{key}` in report:\n{report}"))
}

/// The tentpole acceptance test: scrape both formats from a live
/// gateway after real traffic, validate the exposition grammar, and
/// cross-check the counters exactly against the legacy report lines.
#[test]
fn prometheus_scrape_agrees_exactly_with_the_legacy_report() {
    let gw = Gateway::start(Coordinator::start(rns_cfg(2)), gw_cfg()).expect("gateway");
    let addr = gw.local_addr().to_string();

    let mut client = Client::connect(&addr).expect("connect");
    for i in 0..6 {
        client.infer(SYNTHETIC_MLP, &input(i)).expect("infer");
    }

    let (headers, legacy) = http_get(&addr, "GET", "/metrics");
    assert!(headers.contains("text/plain; charset=utf-8"), "{headers}");
    let (headers, prom_text) = http_get(&addr, "GET", "/metrics?format=prometheus");
    assert!(headers.contains("text/plain; version=0.0.4"), "{headers}");
    let prom = Exposition::parse(&prom_text);

    // counters agree to the last digit: both render the same atomics
    for (family, report_key) in [
        ("rns_requests_total", "requests="),
        ("rns_samples_total", "samples="),
        ("rns_batches_total", "batches="),
        ("rns_dac_conversions_total", "dac-conversions="),
        ("rns_adc_conversions_total", "adc-conversions="),
        ("rns_decode_fast_path_total", "fast-path="),
        ("rns_decode_voted_total", "voted="),
    ] {
        assert_eq!(
            prom.value(family, "") as u64,
            report_value(&legacy, report_key),
            "`{family}` vs `{report_key}`\n--- exposition:\n{prom_text}\n--- report:\n{legacy}"
        );
    }
    assert_eq!(prom.value("rns_requests_total", "") as u64, 6);
    assert!(prom.value("rns_adc_conversions_total", "") > 0.0, "RRNS traffic converts");

    // per-stage pipeline histograms populated by the served batches;
    // the RNS backend reports compute-stage splits, so every stage of
    // admission → queue → form → dac → gemm → adc → decode → delivery
    // must have observed at least one batch
    for stage in
        ["admission", "queue", "batch_form", "dac_forward", "analog_gemm", "adc_capture", "decode", "delivery"]
    {
        let key = format!("stage=\"{stage}\"");
        let n = prom.value("rns_stage_latency_us_count", &key);
        assert!(n > 0.0, "stage `{stage}` never observed:\n{prom_text}");
    }
    let key = "stage=\"queue\"";
    assert_eq!(
        prom.value("rns_stage_latency_us_count", key) as u64,
        6,
        "one queue observation per request"
    );
    assert!(prom.value("rns_request_latency_us_count", "") >= 6.0, "{prom_text}");

    // gateway counters are in the same exposition
    assert!(prom.value("rns_gateway_sessions_total", "") >= 1.0);
    assert_eq!(prom.value("rns_gateway_active_sessions", ""), 1.0);
    assert!(prom.value("rns_gateway_http_requests_total", "") >= 1.0);

    // the Traces wire frame returns the slowest-request ring
    let traces = client.traces().expect("traces frame");
    assert!(traces.starts_with("slow traces: kept=6"), "{traces}");
    assert_eq!(traces.lines().filter(|l| l.starts_with("trace: id=")).count(), 6, "{traces}");
    for field in ["queue=", "dac=", "gemm=", "adc=", "decode=", "delivery=", "worker="] {
        assert!(traces.lines().nth(1).unwrap().contains(field), "{traces}");
    }

    client.close();
    let report = gw.shutdown();
    // the final report carries the trace block after every legacy line
    assert!(report.contains("slow traces: kept=6"), "{report}");
}

/// HEAD returns the same headers as GET — Content-Length included —
/// with an empty body, and 404s count into both `scrapes` and the
/// dedicated not-found counter.
#[test]
fn head_requests_and_not_found_are_counted() {
    let gw = Gateway::start(Coordinator::start(rns_cfg(1)), gw_cfg()).expect("gateway");
    let addr = gw.local_addr().to_string();

    let mut client = Client::connect(&addr).expect("connect");
    client.infer(SYNTHETIC_MLP, &input(0)).expect("infer");

    let (get_headers, get_body) = http_get(&addr, "GET", "/metrics?format=prometheus");
    let (head_headers, head_body) = http_get(&addr, "HEAD", "/metrics?format=prometheus");
    assert!(head_body.is_empty(), "HEAD body must be empty: {head_body}");
    let content_length = |h: &str| -> usize {
        h.lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .expect("Content-Length header")
            .parse()
            .expect("integer length")
    };
    assert_eq!(content_length(&get_headers), get_body.len(), "GET length is the body");
    // HEAD advertises a freshly rendered body; the exposition only
    // grows (idle gauges aside, same traffic), so just pin it nonzero
    assert!(content_length(&head_headers) > 0, "{head_headers}");
    let (nf_headers, _) = http_get(&addr, "GET", "/nope");
    assert!(nf_headers.starts_with("HTTP/1.1 404"), "{nf_headers}");

    let (_, prom_text) = http_get(&addr, "GET", "/metrics?format=prometheus");
    let prom = Exposition::parse(&prom_text);
    // GET + HEAD + 404 + this scrape
    assert_eq!(prom.value("rns_gateway_http_requests_total", ""), 4.0, "{prom_text}");
    assert_eq!(prom.value("rns_gateway_http_not_found_total", ""), 1.0, "{prom_text}");

    client.close();
    gw.shutdown();
}

fn split_pairs(raw: &str) -> Vec<&str> {
    // label values in these tests never contain commas or escapes; the
    // full escaping path is covered by the unit tests in util::metrics
    raw.split(',').collect()
}
