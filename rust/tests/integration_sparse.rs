//! End-to-end tests for conversion-avoiding sparse capture through the
//! serving stack: a coordinator built with `sparse_capture = true` must
//! produce logits bit-identical to a dense-capture coordinator under
//! `NoiseModel::None`, while its shutdown report's `energy:` line shows
//! nonzero `skipped-dac=` / `skipped-adc=` and strictly fewer performed
//! conversions on a sparse workload.
//!
//! Serves `synthetic-mlp` (seeded in-process weights), so no artifacts.

use std::collections::BTreeMap;

use rns_analog::analog::NoiseModel;
use rns_analog::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use rns_analog::nn::models::{Batch, SYNTHETIC_MLP};
use rns_analog::tensor::{MatF, Nhwc};
use rns_analog::util::rng::Rng;

fn cfg(sparse: bool) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::new(
        BackendKind::Rns { bits: 6, redundant: 0, attempts: 1, noise: NoiseModel::None },
        "/nonexistent",
    );
    cfg.workers = 2;
    cfg.seed = 11;
    cfg.sparse_capture = sparse;
    cfg
}

/// Request #i: even ids are all-zero images (whole-row ADC skips), odd
/// ids are dense uniform(0,1) pixels.
fn input(i: u64) -> Batch {
    if i % 2 == 0 {
        return Batch::Images(Nhwc::zeros(1, 28, 28, 1));
    }
    let mut rng = Rng::seed_from(0xFACE ^ i);
    Batch::Images(Nhwc::from_vec(
        1,
        28,
        28,
        1,
        (0..28 * 28).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
    ))
}

/// Serve the standard 16-request mixed workload; logits keyed by request
/// id plus the final report.
fn run(sparse: bool) -> (BTreeMap<u64, MatF>, String) {
    let coord = Coordinator::start(cfg(sparse));
    let n = 16u64;
    let ids: Vec<u64> = (0..n).map(|i| coord.submit(SYNTHETIC_MLP, input(i))).collect();
    let mut by_id = BTreeMap::new();
    for resp in coord.collect(n as usize) {
        by_id.insert(resp.id, resp.result.expect("request must succeed"));
    }
    assert_eq!(by_id.len(), ids.len());
    (by_id, coord.shutdown())
}

/// Pull `key=<u64>` off the report's `energy:` line.
fn energy_metric(report: &str, key: &str) -> u64 {
    let line = report
        .lines()
        .find(|l| l.starts_with("energy: "))
        .unwrap_or_else(|| panic!("no energy: line in report:\n{report}"));
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(&format!("{key}=")))
        .unwrap_or_else(|| panic!("no {key}= on energy line: {line}"))
        .parse()
        .unwrap_or_else(|_| panic!("unparsable {key}= on energy line: {line}"))
}

#[test]
fn sparse_serving_is_bit_identical_and_reports_skips() {
    let (dense_logits, dense_report) = run(false);
    let (sparse_logits, sparse_report) = run(true);

    // logits bit-identical request-by-request (NoiseModel::None: sparse
    // capture may not change a single ulp)
    for (id, d) in &dense_logits {
        let s = &sparse_logits[id];
        assert_eq!(d.data, s.data, "request {id}: logits diverged under sparse capture");
    }

    // the sparse run skipped real work and says so on the energy line
    let skipped_dac = energy_metric(&sparse_report, "skipped-dac");
    let skipped_adc = energy_metric(&sparse_report, "skipped-adc");
    assert!(skipped_dac > 0, "zero-image workload must skip DACs:\n{sparse_report}");
    assert!(skipped_adc > 0, "all-zero rows must skip ADC capture:\n{sparse_report}");

    // dense mode never skips
    assert_eq!(energy_metric(&dense_report, "skipped-dac"), 0);
    assert_eq!(energy_metric(&dense_report, "skipped-adc"), 0);

    // strictly fewer conversions actually performed on the sparse run,
    // and the skips account exactly for the difference
    let dense_dac = energy_metric(&dense_report, "dac-conversions");
    let dense_adc = energy_metric(&dense_report, "adc-conversions");
    let sparse_dac = energy_metric(&sparse_report, "dac-conversions");
    let sparse_adc = energy_metric(&sparse_report, "adc-conversions");
    assert!(sparse_dac < dense_dac, "dac {sparse_dac} !< {dense_dac}");
    assert!(sparse_adc < dense_adc, "adc {sparse_adc} !< {dense_adc}");
    assert_eq!(sparse_dac + skipped_dac, dense_dac, "dac skips must account for the gap");
    assert_eq!(sparse_adc + skipped_adc, dense_adc, "adc skips must account for the gap");
}

#[test]
fn dense_traffic_through_sparse_capture_is_safe() {
    // all-dense workload (the chaos-smoke shape): sparse capture must be
    // a correctness no-op; element-level DAC skips may still occur from
    // hidden-layer ReLU zeros, but no row may be wrongly dropped
    let coord_dense = Coordinator::start(cfg(false));
    let coord_sparse = Coordinator::start(cfg(true));
    for i in 0..6u64 {
        let img = input(2 * i + 1); // odd ids: dense uniform pixels
        coord_dense.submit(SYNTHETIC_MLP, img);
        coord_sparse.submit(SYNTHETIC_MLP, input(2 * i + 1));
    }
    let mut d: Vec<_> = coord_dense.collect(6).into_iter().map(|r| (r.id, r.result.unwrap())).collect();
    let mut s: Vec<_> = coord_sparse.collect(6).into_iter().map(|r| (r.id, r.result.unwrap())).collect();
    d.sort_by_key(|(id, _)| *id);
    s.sort_by_key(|(id, _)| *id);
    for ((di, dm), (si, sm)) in d.iter().zip(&s) {
        assert_eq!(di, si);
        assert_eq!(dm.data, sm.data, "request {di}: dense traffic diverged");
    }
    coord_dense.shutdown();
    coord_sparse.shutdown();
}
