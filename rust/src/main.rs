//! `rns-analog` — CLI for the RNS analog-accelerator reproduction.
//!
//! Subcommands:
//!   exp <id>    regenerate a paper table/figure (table1, fig1, fig3, fig4,
//!               fig5, fig6, fig7, headline, all)
//!   infer       run one model through a chosen core and report accuracy
//!   serve       run the serving coordinator on a synthetic request stream
//!   loadgen     drive a serving gateway with a composable workload blend
//!               (open-loop arrivals, Zipf model popularity) and report
//!               sustained RPS + latency percentiles
//!   pjrt-demo   prove the AOT path: run the pallas-kernel artifact via PJRT
//!               and check it against the native engine bit-for-bit

use rns_analog::analog::NoiseModel;
use rns_analog::coordinator::server::build_backend;
use rns_analog::coordinator::{BackendKind, BatcherConfig, Coordinator, CoordinatorConfig};
use rns_analog::exp;
use rns_analog::net::{Gateway, GatewayConfig};
use rns_analog::nn::dataset::{dataset_for_model, load_eval_set};
use rns_analog::nn::models::{accuracy, load_model, Batch};
use rns_analog::runtime::{default_artifacts_dir, ModularGemmEngine, NativeEngine, PjrtEngine, PjrtRuntime};
use rns_analog::tensor::{MatI, Nhwc};
use rns_analog::util::cli::Args;
use rns_analog::util::rng::Rng;

fn main() {
    let mut args = match Args::parse_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("exp") => cmd_exp(&mut args),
        Some("infer") => cmd_infer(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("loadgen") => cmd_loadgen(&mut args),
        Some("pjrt-demo") => cmd_pjrt_demo(&mut args),
        Some(other) => {
            eprintln!("unknown subcommand `{other}`");
            usage();
            2
        }
        None => {
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "usage: rns-analog <subcommand> [flags]\n\
         \n\
         exp <table1|fig1|fig3|fig4|fig5|fig6|fig7|headline|ablation|sparsity|all>\n\
             [--samples=N] [--pairs=N] [--trials=N] [--h=128] [--save-dir=results]\n\
         infer --model=<mlp|cnn|resnet|bert> [--backend=fp32|fixed|rns|rns-pjrt]\n\
             [--bits=6] [--redundant=0] [--attempts=1] [--noise-p=0] [--samples=N]\n\
         serve [--config=configs/rns_b6.toml | --backend=...]\n\
             [--requests=64] [--workers=2] [--max-batch=8]\n\
             [--listen=127.0.0.1:7070] [--max-sessions=64] [--idle-timeout-ms=30000]\n\
             [--loop-threads=1]  (readiness-loop threads for the event-driven\n\
              session layer; sessions cost slab entries, not threads)\n\
             [--serve-seconds=N]   (gateway mode: serve TCP clients instead of a\n\
              synthetic stream; drains on a client Shutdown frame, or after N seconds)\n\
             [--admin-token=SECRET]  (require this token on load/unload/shutdown\n\
              frames; unset = loopback-only; env RNS_ADMIN_TOKEN also works)\n\
             [--stall-timeout-ms=30000] [--poison-threshold=2] [--default-deadline-ms=0]\n\
             [--trace-slots=16]  (slowest-request pipeline trace ring; 0 = off;\n\
              Prometheus exposition at GET /metrics?format=prometheus)\n\
             [--trace-sample=0.0]  (span-trace sampling probability for requests\n\
              without a client-chosen trace id; span trees at GET /trace, Chrome\n\
              trace-event JSON at /trace?format=chrome; health at /healthz,\n\
              readiness at /readyz)\n\
             [--chaos=SPEC]  (seeded fault injection, e.g. \"panic@w0:b3,\n\
              stall@w1:b2:50ms,poison@mlp,drop@s1:f2\" — tests/CI only)\n\
             [--sparse-capture]  (conversion-avoiding sparse execution on RNS\n\
              backends; skipped conversions show as skipped-dac=/skipped-adc=\n\
              on the energy: metrics line)\n\
         loadgen --addr=127.0.0.1:7070 [--workload=infer:0.9,stats:0.1]\n\
             [--models=synthetic-mlp] [--zipf-s=1.1] [--conns=4] [--seconds=10]\n\
             [--rate=0]  (open-loop arrivals in req/s across all connections;\n\
              0 = closed-loop with --window=32 requests in flight per conn)\n\
             [--requests=0] [--deadline-ms=0] [--seed=42] [--p99-budget-ms=0]\n\
             [--trace-sample=0]  (fraction of infer ops sent with a trace id;\n\
              the report joins client latency with server span trees in a\n\
              `slowest:` section)\n\
             [--token=SECRET]  (admin token for load/unload ops in the blend;\n\
              env RNS_ADMIN_TOKEN also works)\n\
         pjrt-demo [--bits=6]"
    );
}

fn save_and_print(report: &exp::Report, save_dir: &str, id: &str) {
    println!("{}\n", report.render());
    match report.save(save_dir, id) {
        Ok(path) => println!("[saved {path}]\n"),
        Err(e) => eprintln!("[warn] could not save {id}: {e}"),
    }
}

fn cmd_exp(args: &mut Args) -> i32 {
    let artifacts = args.get_or("artifacts-dir", &default_artifacts_dir());
    let save_dir = args.get_or("save-dir", "results");
    let h = args.get_parsed::<usize>("h", 128).unwrap_or(128);
    let samples = args.get_parsed::<usize>("samples", 256).unwrap_or(256);
    let pairs = args.get_parsed::<usize>("pairs", 10_000).unwrap_or(10_000);
    let trials = args.get_parsed::<u32>("trials", 40_000).unwrap_or(40_000);
    let which = args.positional.first().cloned().unwrap_or_else(|| "all".to_string());

    let run_one = |id: &str| -> Result<(), String> {
        match id {
            "table1" => {
                save_and_print(&exp::table1::run(h), &save_dir, "table1");
            }
            "fig1" => {
                let mut cfg = exp::fig1::Fig1Config::new(&artifacts);
                cfg.samples = samples;
                save_and_print(&exp::fig1::run(&cfg)?, &save_dir, "fig1");
            }
            "fig3" => {
                let cfg = exp::fig3::Fig3Config { h, pairs, ..Default::default() };
                save_and_print(&exp::fig3::run(&cfg), &save_dir, "fig3");
            }
            "fig4" => {
                let mut cfg = exp::fig4::Fig4Config::new(&artifacts);
                cfg.samples = samples;
                cfg.h = h;
                save_and_print(&exp::fig4::run(&cfg)?, &save_dir, "fig4");
            }
            "fig5" => {
                let cfg = exp::fig5::Fig5Config { trials, ..Default::default() };
                save_and_print(&exp::fig5::run(&cfg), &save_dir, "fig5");
            }
            "fig6" => {
                let mut cfg = exp::fig6::Fig6Config::new(&artifacts);
                cfg.samples = samples.min(128);
                save_and_print(&exp::fig6::run(&cfg)?, &save_dir, "fig6");
            }
            "fig7" => {
                save_and_print(&exp::fig7::run(h), &save_dir, "fig7");
            }
            "ablation" => {
                save_and_print(&exp::ablation::run(&artifacts)?, &save_dir, "ablation");
            }
            "headline" => {
                let mut cfg = exp::fig4::Fig4Config::new(&artifacts);
                cfg.samples = samples;
                save_and_print(&exp::fig4::headline(&cfg)?, &save_dir, "headline");
            }
            "sparsity" => {
                let cfg = exp::sparsity::SparsityConfig { h, ..Default::default() };
                save_and_print(&exp::sparsity::run(&cfg), &save_dir, "sparsity");
            }
            other => return Err(format!("unknown experiment `{other}`")),
        }
        Ok(())
    };

    let ids: Vec<&str> = if which == "all" {
        vec![
            "table1", "fig1", "fig3", "fig4", "fig5", "fig6", "fig7", "headline", "ablation",
            "sparsity",
        ]
    } else {
        vec![which.as_str()]
    };
    for id in ids {
        eprintln!("[exp] running {id} ...");
        let t0 = std::time::Instant::now();
        if let Err(e) = run_one(id) {
            eprintln!("experiment {id} failed: {e}");
            return 1;
        }
        eprintln!("[exp] {id} done in {:.1}s", t0.elapsed().as_secs_f64());
    }
    0
}

/// Backend + coordinator config from --config=<file> or individual flags.
fn parse_coordinator_config(args: &mut Args, artifacts: &str) -> Result<CoordinatorConfig, String> {
    if let Some(path) = args.get("config") {
        let mut cfg = rns_analog::coordinator::config_file::from_file(&path, artifacts)?;
        // the flag composes with a config file (CI enables sparse capture
        // on top of a stock config)
        if args.flag("sparse-capture") {
            cfg.sparse_capture = true;
        }
        return Ok(cfg);
    }
    let backend = parse_backend(args)?;
    let mut cfg = CoordinatorConfig::new(backend, artifacts);
    cfg.workers = args.get_parsed::<usize>("workers", 2)?;
    cfg.batcher =
        BatcherConfig { max_batch: args.get_parsed::<usize>("max-batch", 8)?, ..Default::default() };
    cfg.sparse_capture = args.flag("sparse-capture");
    Ok(cfg)
}

fn parse_backend(args: &mut Args) -> Result<BackendKind, String> {
    let bits = args.get_parsed::<u32>("bits", 6)?;
    let redundant = args.get_parsed::<usize>("redundant", 0)?;
    let attempts = args.get_parsed::<u32>("attempts", 1)?;
    let noise_p = args.get_parsed::<f64>("noise-p", 0.0)?;
    let noise = if noise_p > 0.0 { NoiseModel::ResidueFlip { p: noise_p } } else { NoiseModel::None };
    match args.get_or("backend", "rns").as_str() {
        "fp32" => Ok(BackendKind::Fp32),
        "fixed" => Ok(BackendKind::FixedPoint { bits }),
        "rns" => Ok(BackendKind::Rns { bits, redundant, attempts, noise }),
        "rns-pjrt" => Ok(BackendKind::RnsPjrt { bits, redundant, attempts, noise }),
        other => Err(format!("unknown backend `{other}`")),
    }
}

fn cmd_infer(args: &mut Args) -> i32 {
    let artifacts = args.get_or("artifacts-dir", &default_artifacts_dir());
    let model_name = args.get_or("model", "mlp");
    let samples = args.get_parsed::<usize>("samples", 128).unwrap_or(128);
    let model = match load_model(&artifacts, &model_name) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("load model: {e}");
            return 1;
        }
    };
    let eval = match load_eval_set(&artifacts, dataset_for_model(&model_name)) {
        Ok(d) => d.take(samples),
        Err(e) => {
            eprintln!("load eval set: {e}");
            return 1;
        }
    };
    let cfg = match parse_coordinator_config(args, &artifacts) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut backend = match build_backend(&cfg, 0) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("build backend: {e}");
            return 1;
        }
    };
    let t0 = std::time::Instant::now();
    let acc = accuracy(model.as_ref(), &eval.input, &eval.labels, backend.as_mut());
    let dt = t0.elapsed();
    println!(
        "model={model_name} backend={} samples={} accuracy={:.4} (fp32 trained: {:.4})  [{:.2}s]",
        backend.name(),
        eval.len(),
        acc,
        model.trained_fp32_accuracy(),
        dt.as_secs_f64()
    );
    if let Some(meter) = backend.meter() {
        println!(
            "energy: dac={} adc={} ({} dac conv, {} adc conv)",
            rns_analog::util::format_si(meter.dac_joules, "J"),
            rns_analog::util::format_si(meter.adc_joules, "J"),
            meter.dac_conversions,
            meter.adc_conversions
        );
    }
    if let Some(stats) = backend.fault_stats() {
        println!(
            "faults: decoded={} corrected={} detections={} exhausted={} \
             (decode fast-path={} voted={})",
            stats.decoded,
            stats.corrected,
            stats.detections,
            stats.exhausted,
            stats.fast_path_elems,
            stats.voted_elems
        );
    }
    0
}

fn cmd_serve(args: &mut Args) -> i32 {
    let artifacts = args.get_or("artifacts-dir", &default_artifacts_dir());
    let requests = args.get_parsed::<usize>("requests", 64).unwrap_or(64);
    // one parse of --config serves both halves (coordinator + gateway);
    // without a file, the coordinator config comes from the flags and
    // gateway mode needs an explicit --listen
    let (cfg, mut gw_cfg) = match args.get("config") {
        Some(path) => {
            let parsed = match rns_analog::util::config::Config::from_file(&path) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let cfg =
                match rns_analog::coordinator::config_file::from_config(&parsed, &artifacts) {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                };
            let gw = match rns_analog::coordinator::config_file::gateway_from_config(&parsed) {
                Ok(g) => g,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            (cfg, gw)
        }
        None => match parse_coordinator_config(args, &artifacts) {
            Ok(c) => (c, None),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    if let Some(addr) = args.get("listen") {
        let mut g = gw_cfg.take().unwrap_or_default();
        g.listen_addr = addr;
        gw_cfg = Some(g);
    }
    // supervision + chaos flags override whatever the config file said
    let mut cfg = cfg;
    if args.flag("sparse-capture") {
        cfg.sparse_capture = true;
    }
    if let Some(spec) = args.get("chaos") {
        match rns_analog::coordinator::ChaosSpec::parse(&spec) {
            Ok(parsed) => {
                cfg.chaos = parsed.clone();
                if let Some(g) = &mut gw_cfg {
                    g.chaos = parsed;
                }
            }
            Err(e) => {
                eprintln!("--chaos: {e}");
                return 2;
            }
        }
    }
    if let Some(ms) = args.get("stall-timeout-ms") {
        match ms.parse::<u64>() {
            Ok(v) if v >= 1 => cfg.stall_timeout = std::time::Duration::from_millis(v),
            _ => {
                eprintln!("--stall-timeout-ms={ms}: want an integer >= 1");
                return 2;
            }
        }
    }
    if let Some(n) = args.get("poison-threshold") {
        match n.parse::<u32>() {
            Ok(v) if v >= 1 => cfg.poison_threshold = v,
            _ => {
                eprintln!("--poison-threshold={n}: want an integer >= 1");
                return 2;
            }
        }
    }
    if let Some(n) = args.get("trace-slots") {
        match n.parse::<usize>() {
            Ok(v) => cfg.trace_slots = v,
            _ => {
                eprintln!("--trace-slots={n}: want an integer >= 0 (0 = tracing off)");
                return 2;
            }
        }
    }
    if let Some(p) = args.get("trace-sample") {
        match p.parse::<f64>() {
            Ok(v) if (0.0..=1.0).contains(&v) => cfg.trace_sample = v,
            _ => {
                eprintln!("--trace-sample={p}: want a probability in [0, 1]");
                return 2;
            }
        }
    }
    if let Some(ms) = args.get("default-deadline-ms") {
        match ms.parse::<u64>() {
            Ok(0) => cfg.default_deadline = None,
            Ok(v) => cfg.default_deadline = Some(std::time::Duration::from_millis(v)),
            _ => {
                eprintln!("--default-deadline-ms={ms}: want an integer >= 0 (0 = none)");
                return 2;
            }
        }
    }
    if let Some(g) = &mut gw_cfg {
        if let Some(token) = args.get("admin-token") {
            g.admin_token = if token.is_empty() { None } else { Some(token) };
        } else if g.admin_token.is_none() {
            if let Ok(token) = std::env::var("RNS_ADMIN_TOKEN") {
                if !token.is_empty() {
                    g.admin_token = Some(token);
                }
            }
        }
        if let Some(ms) = args.get("max-sessions") {
            match ms.parse::<usize>() {
                Ok(v) if v >= 1 => g.max_sessions = v,
                _ => {
                    eprintln!("--max-sessions={ms}: want an integer >= 1");
                    return 2;
                }
            }
        }
        if let Some(t) = args.get("idle-timeout-ms") {
            match t.parse::<u64>() {
                Ok(v) if v >= 1 => g.idle_timeout = std::time::Duration::from_millis(v),
                _ => {
                    eprintln!("--idle-timeout-ms={t}: want an integer >= 1");
                    return 2;
                }
            }
        }
        if let Some(n) = args.get("loop-threads") {
            match n.parse::<usize>() {
                Ok(v) if v >= 1 => g.loop_threads = v,
                _ => {
                    eprintln!("--loop-threads={n}: want an integer >= 1");
                    return 2;
                }
            }
        }
    }
    // 0 = serve until a client Shutdown frame; a typo must not silently
    // become "forever", so parse errors are fatal like the other flags
    let serve_seconds = match args.get_parsed::<u64>("serve-seconds", 0) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if let Some(gw_cfg) = gw_cfg {
        return cmd_serve_gateway(cfg, gw_cfg, serve_seconds);
    }
    let eval = match load_eval_set(&artifacts, "digits") {
        Ok(d) => d,
        Err(e) => {
            eprintln!("load digits eval set: {e}");
            return 1;
        }
    };
    let coord = Coordinator::start(cfg);
    let imgs = match &eval.input {
        Batch::Images(t) => t.clone(),
        _ => unreachable!(),
    };
    let stride = imgs.h * imgs.w * imgs.c;
    for i in 0..requests {
        let idx = i % imgs.n;
        let data = imgs.data[idx * stride..(idx + 1) * stride].to_vec();
        let img = Nhwc::from_vec(1, imgs.h, imgs.w, imgs.c, data);
        coord.submit("mlp", Batch::Images(img));
    }
    let resps = coord.collect(requests);
    let ok = resps.iter().filter(|r| r.result.is_ok()).count();
    println!("completed {ok}/{requests} requests");
    println!("{}", coord.shutdown());
    if ok == requests {
        0
    } else {
        1
    }
}

/// Gateway mode: serve TCP clients on `listen_addr` until a client sends
/// a `Shutdown` frame (or `serve_seconds` elapses), then drain and print
/// the final report.
fn cmd_serve_gateway(cfg: CoordinatorConfig, gw_cfg: GatewayConfig, serve_seconds: u64) -> i32 {
    use std::io::Write;
    let coord = Coordinator::start(cfg);
    let gw = match Gateway::start(coord, gw_cfg) {
        Ok(g) => g,
        Err(e) => {
            eprintln!("gateway: {e}");
            return 1;
        }
    };
    println!(
        "[gateway] listening on {} — binary wire protocol + HTTP GET/HEAD /metrics \
         (Prometheus: /metrics?format=prometheus), /trace (?format=chrome), \
         /healthz, /readyz",
        gw.local_addr()
    );
    // flush: smoke scripts poll the log for the listening line before
    // connecting, and stdout is block-buffered into a pipe
    std::io::stdout().flush().ok();
    let timeout =
        if serve_seconds > 0 { Some(std::time::Duration::from_secs(serve_seconds)) } else { None };
    if gw.wait_shutdown(timeout) {
        println!("[gateway] shutdown requested by client; draining");
    } else {
        println!("[gateway] serve window ({serve_seconds}s) elapsed; draining");
    }
    let report = gw.shutdown();
    println!("[gateway] clean shutdown\n--- final report ---\n{report}");
    0
}

/// Drive a running gateway with a composable workload blend and print
/// the one-line load report (`failures=`, `rps=`, `p99_us=` are the
/// greppable fields CI and the bench trend consume).
fn cmd_loadgen(args: &mut Args) -> i32 {
    use rns_analog::net::{DataSet, LoadgenConfig, Workload};
    let workload = match Workload::parse(&args.get_or("workload", "infer")) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("--workload: {e}");
            return 2;
        }
    };
    let models: Vec<String> = args
        .get_or("models", "synthetic-mlp")
        .split(',')
        .filter(|m| !m.trim().is_empty())
        .map(|m| m.trim().to_string())
        .collect();
    let admin_token = match args.get("token") {
        Some(t) => t,
        None => std::env::var("RNS_ADMIN_TOKEN").unwrap_or_default(),
    };
    let parsed = (|| -> Result<LoadgenConfig, String> {
        Ok(LoadgenConfig {
            addr: args.get_or("addr", "127.0.0.1:7070"),
            workload,
            data: DataSet::default(),
            models,
            zipf_s: args.get_parsed::<f64>("zipf-s", 1.1)?,
            rate: args.get_parsed::<f64>("rate", 0.0)?,
            conns: args.get_parsed::<usize>("conns", 4)?,
            duration: std::time::Duration::from_secs(args.get_parsed::<u64>("seconds", 10)?),
            requests: args.get_parsed::<u64>("requests", 0)?,
            window: args.get_parsed::<usize>("window", 32)?,
            deadline_ms: args.get_parsed::<u32>("deadline-ms", 0)?,
            admin_token,
            seed: args.get_parsed::<u64>("seed", 42)?,
            p99_budget_us: args.get_parsed::<f64>("p99-budget-ms", 0.0)? * 1000.0,
            trace_sample: {
                let p = args.get_parsed::<f64>("trace-sample", 0.0)?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("--trace-sample={p}: want a probability in [0, 1]"));
                }
                p
            },
        })
    })();
    let cfg = match parsed {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let report = match rns_analog::net::loadgen::run(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("loadgen: {e}");
            return 1;
        }
    };
    println!("{report}");
    if let Some(err) = &report.last_error {
        eprintln!("loadgen: last failure: {err}");
    }
    if report.failures > 0 || report.p99_within_budget == Some(false) {
        1
    } else {
        0
    }
}

fn cmd_pjrt_demo(args: &mut Args) -> i32 {
    let artifacts = args.get_or("artifacts-dir", &default_artifacts_dir());
    let bits = args.get_parsed::<u32>("bits", 6).unwrap_or(6);
    let rt = match PjrtRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT client: {e:#}");
            return 1;
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let mut engine = match PjrtEngine::load(&rt, &artifacts, bits) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("load artifact: {e:#}");
            return 1;
        }
    };
    let moduli = engine.moduli.clone();
    println!("loaded rns_mvm_b{bits}.hlo.txt (moduli {moduli:?})");
    // random residues through both engines, must agree bit-for-bit
    let mut rng = Rng::seed_from(42);
    let (b, k, n) = (8usize, 128usize, 96usize);
    let xr: Vec<MatI> = moduli
        .iter()
        .map(|&m| MatI::from_vec(b, k, (0..b * k).map(|_| rng.gen_range(m) as i64).collect()))
        .collect();
    let wr: Vec<MatI> = moduli
        .iter()
        .map(|&m| MatI::from_vec(k, n, (0..k * n).map(|_| rng.gen_range(m) as i64).collect()))
        .collect();
    let got = engine.matmul_mod(&xr, &wr, &moduli);
    let want = NativeEngine::default().matmul_mod(&xr, &wr, &moduli);
    for (ch, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.data, w.data, "channel {ch} mismatch");
    }
    println!(
        "PJRT (pallas AOT) == native rust engine: bit-identical over {} channels. OK",
        moduli.len()
    );
    // full-pipeline artifact too
    let full = match rt.load(&format!("{artifacts}/rns_gemm_b{bits}.hlo.txt")) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("load rns_gemm artifact: {e:#}");
            return 1;
        }
    };
    let x: Vec<f32> = (0..8 * 128).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
    let w: Vec<f32> = (0..128 * 128).map(|_| rng.uniform_f32(-0.5, 0.5)).collect();
    let out = full
        .run_f32(&[
            rns_analog::runtime::F32Input { data: &x, dims: vec![8, 128] },
            rns_analog::runtime::F32Input { data: &w, dims: vec![128, 128] },
        ])
        .expect("run full pipeline");
    // compare against fp32 matmul: error should be quantization-scale only
    let xm = rns_analog::tensor::MatF::from_vec(8, 128, x);
    let wm = rns_analog::tensor::MatF::from_vec(128, 128, w);
    let want = rns_analog::tensor::gemm::gemm_f32(&xm, &wm);
    let max_err =
        out.iter().zip(&want.data).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    println!("full RNS pipeline via PJRT: max |err| vs fp32 = {max_err:.4} (quantization-only)");
    0
}
