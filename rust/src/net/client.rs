//! Blocking gateway client — the reference wire-protocol implementation
//! used by tests, the CI smoke job, and `examples/gateway_client.rs`.
//!
//! Single-threaded and synchronous on purpose: `infer` is one
//! request/response round trip, while `submit` + `recv_infer` pipeline
//! many requests over one session (the server replies carry the request
//! id, so out-of-order completion is fine).
//!
//! Two layers:
//!
//!   * `Client` — one session, no policy.  Errors are typed
//!     (`ClientError`) so callers can tell a dead socket from a typed
//!     server reject.
//!   * `RetryClient` — `Client` plus supervision-aware retry: transient
//!     failures (connection drops, `Overloaded`, `Internal`) are retried
//!     with seeded exponential backoff + jitter, reconnecting as needed;
//!     permanent rejects (`Model`, `Unauthorized`, `DeadlineExceeded`,
//!     `Poisoned`, protocol errors) fail fast.  Inference is pure, so a
//!     retried request that was secretly served twice is harmless — the
//!     logits are bit-identical.
//!
//! Admin frames (load/unload/shutdown) carry the client's configured
//! admin token (`set_admin_token`); inference frames carry the
//! configured per-request deadline (`set_deadline_ms`, 0 = server
//! default).

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

use crate::nn::models::Batch;
use crate::net::protocol::{ErrorCode, Frame, HelloStatus, WireBatch, WireError, MAGIC, VERSION};
use crate::tensor::MatF;
use crate::util::rng::Rng;

/// One completed inference over the wire.
#[derive(Clone, Debug)]
pub struct InferReply {
    pub id: u64,
    pub logits: MatF,
    /// RRNS decode detections in the batch that served this request.
    pub faults_detected: u64,
    /// Worker that executed the batch.
    pub worker: u32,
    /// Span-trace id this request was recorded under (echoed from the
    /// request, or assigned by server-side sampling); 0 = untraced.
    /// Join against the server's `trace_spans` report to attribute this
    /// request's latency to pipeline stages.
    pub trace_id: u64,
}

/// Why a client call failed — the split that drives the retry policy.
#[derive(Clone, Debug)]
pub enum ClientError {
    /// The transport died: connect failure, mid-frame close, timeout.
    /// Always worth a reconnect + retry (the request may or may not have
    /// executed; inference is pure, so a double execution is harmless).
    Transport(String),
    /// The server replied with a typed error frame.  Retryability
    /// follows `ErrorCode::is_retryable`.
    Server { code: ErrorCode, message: String },
    /// Local misuse (oversized name, unexpected reply kind) — never
    /// retried.
    Other(String),
}

impl ClientError {
    pub fn is_retryable(&self) -> bool {
        match self {
            ClientError::Transport(_) => true,
            ClientError::Server { code, .. } => code.is_retryable(),
            ClientError::Other(_) => false,
        }
    }
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Transport(m) => write!(f, "transport: {m}"),
            ClientError::Server { code, message } => write!(f, "{code:?}: {message}"),
            ClientError::Other(m) => write!(f, "{m}"),
        }
    }
}

pub struct Client {
    stream: TcpStream,
    next_id: u64,
    /// Sent in every admin frame; empty = none.
    admin_token: String,
    /// Sent in every `Infer` frame; 0 = server default.
    deadline_ms: u32,
}

impl Client {
    /// Connect + handshake.  A refused session (overloaded, draining,
    /// version mismatch) surfaces the server's typed reason as the
    /// error string.
    pub fn connect(addr: &str) -> Result<Client, String> {
        Self::connect_typed(addr).map_err(|e| e.to_string())
    }

    /// `connect` with the typed error split (used by `RetryClient`).
    pub fn connect_typed(addr: &str) -> Result<Client, ClientError> {
        let mut stream = TcpStream::connect(addr)
            .map_err(|e| ClientError::Transport(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        let mut hello = Vec::with_capacity(6);
        hello.extend_from_slice(&MAGIC);
        hello.extend_from_slice(&VERSION.to_le_bytes());
        stream
            .write_all(&hello)
            .map_err(|e| ClientError::Transport(format!("handshake write: {e}")))?;
        let mut reply = [0u8; 7];
        std::io::Read::read_exact(&mut stream, &mut reply)
            .map_err(|e| ClientError::Transport(format!("handshake read: {e}")))?;
        if reply[..4] != MAGIC {
            return Err(ClientError::Other("not an rns-analog gateway (bad magic)".into()));
        }
        let version = u16::from_le_bytes([reply[4], reply[5]]);
        let status = HelloStatus::from_byte(reply[6])
            .ok_or_else(|| ClientError::Other(format!("unknown hello status {}", reply[6])))?;
        if status != HelloStatus::Ok {
            // the refusal is followed by one typed Error frame with the
            // human-readable reason
            let (code, reason) = match Frame::read_from(&mut stream) {
                Ok(Frame::Error { code, message, .. }) => (code, message),
                _ => (ErrorCode::Internal, format!("{status:?}")),
            };
            return Err(ClientError::Server {
                code,
                message: format!("session refused (v{version} {status:?}): {reason}"),
            });
        }
        Ok(Client { stream, next_id: 1, admin_token: String::new(), deadline_ms: 0 })
    }

    /// Per-call read timeout (`None` blocks indefinitely).
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<(), String> {
        self.stream.set_read_timeout(timeout).map_err(|e| e.to_string())
    }

    /// Shared secret sent in every admin frame (load/unload/shutdown).
    pub fn set_admin_token(&mut self, token: &str) {
        self.admin_token = token.to_string();
    }

    /// Per-request deadline attached to every `Infer` frame; 0 = the
    /// server default.
    pub fn set_deadline_ms(&mut self, deadline_ms: u32) {
        self.deadline_ms = deadline_ms;
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn send(&mut self, frame: &Frame) -> Result<(), ClientError> {
        self.stream
            .write_all(&frame.encode())
            .map_err(|e| ClientError::Transport(format!("send: {e}")))
    }

    fn recv(&mut self) -> Result<Frame, ClientError> {
        Frame::read_from(&mut self.stream).map_err(|e| match e {
            WireError::Eof => ClientError::Transport("server closed the session".to_string()),
            WireError::Io(e) => ClientError::Transport(format!("io error: {e}")),
            WireError::Protocol(m) => ClientError::Other(format!("protocol error: {m}")),
        })
    }

    /// Round-trip liveness probe.
    pub fn ping(&mut self) -> Result<(), String> {
        let id = self.fresh_id();
        self.send(&Frame::Ping { id }).map_err(|e| e.to_string())?;
        match self.recv().map_err(|e| e.to_string())? {
            Frame::Pong { id: got } if got == id => Ok(()),
            Frame::Error { code, message, .. } => Err(format!("{code:?}: {message}")),
            other => Err(format!("unexpected reply to ping: {other:?}")),
        }
    }

    /// Submit without waiting (pipelining); returns the request id the
    /// eventual `InferOk`/`Error` reply will carry.
    pub fn submit(&mut self, model: &str, input: &Batch) -> Result<u64, String> {
        self.submit_typed(model, input).map_err(|e| e.to_string())
    }

    /// `submit` with a caller-chosen span-trace id: a nonzero `trace_id`
    /// asks the server to record this request's span tree under that id
    /// regardless of its sampling rate (the id comes back in `InferOk`
    /// and in the `trace_spans` report).
    pub fn submit_traced(
        &mut self,
        model: &str,
        input: &Batch,
        trace_id: u64,
    ) -> Result<u64, String> {
        self.submit_traced_typed(model, input, trace_id).map_err(|e| e.to_string())
    }

    fn submit_typed(&mut self, model: &str, input: &Batch) -> Result<u64, ClientError> {
        self.submit_traced_typed(model, input, 0)
    }

    fn submit_traced_typed(
        &mut self,
        model: &str,
        input: &Batch,
        trace_id: u64,
    ) -> Result<u64, ClientError> {
        let id = self.fresh_id();
        let frame = Frame::Infer {
            id,
            model: to_name(model)?,
            deadline_ms: self.deadline_ms,
            input: WireBatch::from_batch(input),
            trace_id,
        };
        self.send(&frame)?;
        Ok(id)
    }

    /// Receive the next inference reply (any id).  A typed `Error` reply
    /// becomes `Err` with the server's code + message.
    pub fn recv_infer(&mut self) -> Result<InferReply, String> {
        self.recv_infer_typed().map_err(|e| e.to_string())
    }

    /// `recv_infer` with the typed error split.
    pub fn recv_infer_typed(&mut self) -> Result<InferReply, ClientError> {
        match self.recv()? {
            Frame::InferOk { id, rows, cols, logits, faults_detected, worker, trace_id } => {
                Ok(InferReply {
                    id,
                    logits: MatF::from_vec(rows as usize, cols as usize, logits),
                    faults_detected,
                    worker,
                    trace_id,
                })
            }
            Frame::Error { id, code, message } => {
                Err(ClientError::Server { code, message: format!("request {id}: {message}") })
            }
            other => Err(ClientError::Other(format!("unexpected reply: {other:?}"))),
        }
    }

    /// One blocking inference round trip.
    pub fn infer(&mut self, model: &str, input: &Batch) -> Result<InferReply, String> {
        self.infer_typed(model, input).map_err(|e| e.to_string())
    }

    /// `infer` with the typed error split (used by `RetryClient`).
    pub fn infer_typed(&mut self, model: &str, input: &Batch) -> Result<InferReply, ClientError> {
        let id = self.submit_typed(model, input)?;
        let reply = self.recv_infer_typed()?;
        if reply.id != id {
            return Err(ClientError::Other(format!(
                "reply id {} does not match request id {id}",
                reply.id
            )));
        }
        Ok(reply)
    }

    /// Fetch the live `ServingMetrics` report.
    pub fn stats(&mut self) -> Result<String, String> {
        let id = self.fresh_id();
        self.send(&Frame::Stats { id }).map_err(|e| e.to_string())?;
        match self.recv().map_err(|e| e.to_string())? {
            Frame::StatsReport { text, .. } => Ok(text),
            Frame::Error { code, message, .. } => Err(format!("{code:?}: {message}")),
            other => Err(format!("unexpected reply to stats: {other:?}")),
        }
    }

    /// Fetch the slowest-request pipeline trace report (one line per
    /// retained trace; empty until a request has been served).
    pub fn traces(&mut self) -> Result<String, String> {
        let id = self.fresh_id();
        self.send(&Frame::Traces { id }).map_err(|e| e.to_string())?;
        match self.recv().map_err(|e| e.to_string())? {
            Frame::TracesReport { text, .. } => Ok(text),
            Frame::Error { code, message, .. } => Err(format!("{code:?}: {message}")),
            other => Err(format!("unexpected reply to traces: {other:?}")),
        }
    }

    /// Fetch the span-trace report (`TraceCollector::summary`): one
    /// header line plus one `span-trace:` line per retained tree —
    /// parseable with `trace::parse_summary_line`.
    pub fn trace_spans(&mut self) -> Result<String, String> {
        let id = self.fresh_id();
        self.send(&Frame::TraceSpans { id }).map_err(|e| e.to_string())?;
        match self.recv().map_err(|e| e.to_string())? {
            Frame::TraceSpansReport { text, .. } => Ok(text),
            Frame::Error { code, message, .. } => Err(format!("{code:?}: {message}")),
            other => Err(format!("unexpected reply to trace_spans: {other:?}")),
        }
    }

    /// Load a model into the server's shared registry now.
    pub fn load_model(&mut self, model: &str) -> Result<String, String> {
        let id = self.fresh_id();
        let model = to_name(model).map_err(|e| e.to_string())?;
        let frame = Frame::LoadModel { id, model, token: self.admin_token.clone() };
        self.send(&frame).map_err(|e| e.to_string())?;
        self.expect_ack(id)
    }

    /// Proactively unload a model server-side (registry + plan store +
    /// worker-held state).
    pub fn unload_model(&mut self, model: &str) -> Result<String, String> {
        let id = self.fresh_id();
        let model = to_name(model).map_err(|e| e.to_string())?;
        let frame = Frame::UnloadModel { id, model, token: self.admin_token.clone() };
        self.send(&frame).map_err(|e| e.to_string())?;
        self.expect_ack(id)
    }

    /// Ask the server to drain and exit (admin).
    pub fn shutdown_server(&mut self) -> Result<String, String> {
        let id = self.fresh_id();
        let frame = Frame::Shutdown { id, token: self.admin_token.clone() };
        self.send(&frame).map_err(|e| e.to_string())?;
        self.expect_ack(id)
    }

    fn expect_ack(&mut self, id: u64) -> Result<String, String> {
        match self.recv().map_err(|e| e.to_string())? {
            Frame::Ack { id: got, info } if got == id => Ok(info),
            Frame::Error { code, message, .. } => Err(format!("{code:?}: {message}")),
            other => Err(format!("unexpected reply: {other:?}")),
        }
    }

    pub fn close(self) {
        self.stream.shutdown(Shutdown::Both).ok();
    }
}

fn to_name(model: &str) -> Result<String, ClientError> {
    if model.len() > crate::net::protocol::MAX_NAME_LEN {
        return Err(ClientError::Other(format!(
            "model name longer than {} bytes",
            crate::net::protocol::MAX_NAME_LEN
        )));
    }
    Ok(model.to_string())
}

/// Retry/backoff knobs for `RetryClient`.  Backoff for attempt *k*
/// (0-based) is `min(max, base · factor^k)` scaled by a jitter factor in
/// `[0.5, 1.0)` drawn from a client-seeded RNG — deterministic per seed
/// (testable), decorrelated across clients (no thundering herd when a
/// worker crash fails many requests at once).
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (total attempts = retries + 1).
    pub retries: u32,
    pub base: Duration,
    pub factor: f64,
    pub max: Duration,
    /// Seed for the jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            retries: 3,
            base: Duration::from_millis(20),
            factor: 2.0,
            max: Duration::from_secs(1),
            seed: 0xB0FF,
        }
    }
}

impl RetryPolicy {
    /// The deterministic jittered backoff schedule this policy produces.
    pub fn schedule(&self) -> BackoffSchedule {
        BackoffSchedule { policy: self.clone(), rng: Rng::seed_from(self.seed), attempt: 0 }
    }
}

/// Iterator over a `RetryPolicy`'s jittered delays (one per retry).
pub struct BackoffSchedule {
    policy: RetryPolicy,
    rng: Rng,
    attempt: u32,
}

impl Iterator for BackoffSchedule {
    type Item = Duration;

    fn next(&mut self) -> Option<Duration> {
        let raw = self.policy.base.as_secs_f64() * self.policy.factor.powi(self.attempt as i32);
        let capped = raw.min(self.policy.max.as_secs_f64());
        self.attempt = self.attempt.saturating_add(1);
        // jitter in [0.5, 1.0): keeps the exponential shape but spreads
        // simultaneous retriers across half the window
        let jitter = 0.5 + 0.5 * self.rng.uniform();
        Some(Duration::from_secs_f64(capped * jitter))
    }
}

/// A gateway client with crash-tolerant delivery: reconnects on
/// transport failure and retries transient errors under the policy's
/// seeded backoff.  Permanent rejects (`Model`, `Unauthorized`,
/// `DeadlineExceeded`, `Poisoned`, protocol errors) are returned
/// immediately.
pub struct RetryClient {
    addr: String,
    policy: RetryPolicy,
    admin_token: String,
    deadline_ms: u32,
    conn: Option<Client>,
    /// Connections established beyond the first (observability).
    pub reconnects: u64,
    /// Retried attempts across all calls (observability).
    pub retries: u64,
    connected_once: bool,
}

impl RetryClient {
    pub fn new(addr: &str, policy: RetryPolicy) -> Self {
        RetryClient {
            addr: addr.to_string(),
            policy,
            admin_token: String::new(),
            deadline_ms: 0,
            conn: None,
            reconnects: 0,
            retries: 0,
            connected_once: false,
        }
    }

    pub fn set_admin_token(&mut self, token: &str) {
        self.admin_token = token.to_string();
        if let Some(c) = &mut self.conn {
            c.set_admin_token(token);
        }
    }

    pub fn set_deadline_ms(&mut self, deadline_ms: u32) {
        self.deadline_ms = deadline_ms;
        if let Some(c) = &mut self.conn {
            c.set_deadline_ms(deadline_ms);
        }
    }

    fn conn(&mut self) -> Result<&mut Client, ClientError> {
        if self.conn.is_none() {
            let mut c = Client::connect_typed(&self.addr)?;
            c.set_admin_token(&self.admin_token);
            c.set_deadline_ms(self.deadline_ms);
            if self.connected_once {
                self.reconnects += 1;
            }
            self.connected_once = true;
            self.conn = Some(c);
        }
        Ok(self.conn.as_mut().expect("connection just established"))
    }

    /// One inference with reconnect + seeded-backoff retry.  Note a
    /// transport failure after submit may mean the server already served
    /// the request; the retry re-executes it, which is safe because
    /// inference is pure (the replay is bit-identical).
    pub fn infer(&mut self, model: &str, input: &Batch) -> Result<InferReply, ClientError> {
        let mut schedule = self.policy.schedule();
        let mut attempt: u32 = 0;
        loop {
            let result = match self.conn() {
                Ok(c) => c.infer_typed(model, input),
                Err(e) => Err(e),
            };
            let err = match result {
                Ok(reply) => return Ok(reply),
                Err(e) => e,
            };
            if matches!(err, ClientError::Transport(_)) {
                // the socket is in an unknown state: drop it so the next
                // attempt reconnects
                self.conn = None;
            }
            if attempt >= self.policy.retries || !err.is_retryable() {
                return Err(err);
            }
            attempt += 1;
            self.retries += 1;
            let delay = schedule.next().expect("schedule is infinite");
            crate::log_debug!(
                "client",
                "retry {attempt}/{} after {delay:?}: {err}",
                self.policy.retries
            );
            std::thread::sleep(delay);
        }
    }

    /// Close the current connection (the next call reconnects).
    pub fn close(&mut self) {
        if let Some(c) = self.conn.take() {
            c.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_deterministic_in_the_seed() {
        let policy = RetryPolicy { seed: 42, ..RetryPolicy::default() };
        let a: Vec<Duration> = policy.schedule().take(6).collect();
        let b: Vec<Duration> = policy.schedule().take(6).collect();
        assert_eq!(a, b, "same seed, same jitter stream");
        let other = RetryPolicy { seed: 43, ..RetryPolicy::default() };
        let c: Vec<Duration> = other.schedule().take(6).collect();
        assert_ne!(a, c, "different seed, decorrelated jitter");
    }

    #[test]
    fn backoff_grows_exponentially_and_caps() {
        let policy = RetryPolicy {
            retries: 8,
            base: Duration::from_millis(20),
            factor: 2.0,
            max: Duration::from_millis(200),
            seed: 7,
        };
        let delays: Vec<Duration> = policy.schedule().take(8).collect();
        for (k, d) in delays.iter().enumerate() {
            let cap = (0.02 * 2f64.powi(k as i32)).min(0.2);
            let lo = cap * 0.5;
            let secs = d.as_secs_f64();
            assert!(secs >= lo - 1e-12 && secs < cap + 1e-12, "delay[{k}] = {secs}s, cap {cap}s");
        }
        // the cap actually binds on late attempts
        assert!(delays[7].as_secs_f64() <= 0.2);
    }

    #[test]
    fn retryability_split() {
        assert!(ClientError::Transport("reset".into()).is_retryable());
        assert!(ClientError::Server { code: ErrorCode::Overloaded, message: String::new() }
            .is_retryable());
        assert!(!ClientError::Server { code: ErrorCode::Poisoned, message: String::new() }
            .is_retryable());
        assert!(!ClientError::Server {
            code: ErrorCode::DeadlineExceeded,
            message: String::new()
        }
        .is_retryable());
        assert!(!ClientError::Other("bug".into()).is_retryable());
    }
}
