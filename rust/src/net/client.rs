//! Blocking gateway client — the reference wire-protocol implementation
//! used by tests, the CI smoke job, and `examples/gateway_client.rs`.
//!
//! Single-threaded and synchronous on purpose: `infer` is one
//! request/response round trip, while `submit` + `recv_infer` pipeline
//! many requests over one session (the server replies carry the request
//! id, so out-of-order completion is fine).

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

use crate::nn::models::Batch;
use crate::net::protocol::{Frame, HelloStatus, WireBatch, WireError, MAGIC, VERSION};
use crate::tensor::MatF;

/// One completed inference over the wire.
#[derive(Clone, Debug)]
pub struct InferReply {
    pub id: u64,
    pub logits: MatF,
    /// RRNS decode detections in the batch that served this request.
    pub faults_detected: u64,
    /// Worker that executed the batch.
    pub worker: u32,
}

pub struct Client {
    stream: TcpStream,
    next_id: u64,
}

impl Client {
    /// Connect + handshake.  A refused session (overloaded, draining,
    /// version mismatch) surfaces the server's typed reason as the
    /// error string.
    pub fn connect(addr: &str) -> Result<Client, String> {
        let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        let mut hello = Vec::with_capacity(6);
        hello.extend_from_slice(&MAGIC);
        hello.extend_from_slice(&VERSION.to_le_bytes());
        stream.write_all(&hello).map_err(|e| format!("handshake write: {e}"))?;
        let mut reply = [0u8; 7];
        std::io::Read::read_exact(&mut stream, &mut reply)
            .map_err(|e| format!("handshake read: {e}"))?;
        if reply[..4] != MAGIC {
            return Err("not an rns-analog gateway (bad magic)".into());
        }
        let version = u16::from_le_bytes([reply[4], reply[5]]);
        let status = HelloStatus::from_byte(reply[6])
            .ok_or_else(|| format!("unknown hello status {}", reply[6]))?;
        if status != HelloStatus::Ok {
            // the refusal is followed by one typed Error frame with the
            // human-readable reason
            let reason = match Frame::read_from(&mut stream) {
                Ok(Frame::Error { message, .. }) => message,
                _ => format!("{status:?}"),
            };
            return Err(format!("session refused (v{version} {status:?}): {reason}"));
        }
        Ok(Client { stream, next_id: 1 })
    }

    /// Per-call read timeout (`None` blocks indefinitely).
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<(), String> {
        self.stream.set_read_timeout(timeout).map_err(|e| e.to_string())
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn send(&mut self, frame: &Frame) -> Result<(), String> {
        self.stream.write_all(&frame.encode()).map_err(|e| format!("send: {e}"))
    }

    fn recv(&mut self) -> Result<Frame, String> {
        Frame::read_from(&mut self.stream).map_err(|e| match e {
            WireError::Eof => "server closed the session".to_string(),
            other => other.to_string(),
        })
    }

    /// Round-trip liveness probe.
    pub fn ping(&mut self) -> Result<(), String> {
        let id = self.fresh_id();
        self.send(&Frame::Ping { id })?;
        match self.recv()? {
            Frame::Pong { id: got } if got == id => Ok(()),
            Frame::Error { code, message, .. } => Err(format!("{code:?}: {message}")),
            other => Err(format!("unexpected reply to ping: {other:?}")),
        }
    }

    /// Submit without waiting (pipelining); returns the request id the
    /// eventual `InferOk`/`Error` reply will carry.
    pub fn submit(&mut self, model: &str, input: &Batch) -> Result<u64, String> {
        let id = self.fresh_id();
        let frame =
            Frame::Infer { id, model: to_name(model)?, input: WireBatch::from_batch(input) };
        self.send(&frame)?;
        Ok(id)
    }

    /// Receive the next inference reply (any id).  A typed `Error` reply
    /// becomes `Err` with the server's code + message.
    pub fn recv_infer(&mut self) -> Result<InferReply, String> {
        match self.recv()? {
            Frame::InferOk { id, rows, cols, logits, faults_detected, worker } => Ok(InferReply {
                id,
                logits: MatF::from_vec(rows as usize, cols as usize, logits),
                faults_detected,
                worker,
            }),
            Frame::Error { id, code, message } => {
                Err(format!("request {id} failed ({code:?}): {message}"))
            }
            other => Err(format!("unexpected reply: {other:?}")),
        }
    }

    /// One blocking inference round trip.
    pub fn infer(&mut self, model: &str, input: &Batch) -> Result<InferReply, String> {
        let id = self.submit(model, input)?;
        let reply = self.recv_infer()?;
        if reply.id != id {
            return Err(format!("reply id {} does not match request id {id}", reply.id));
        }
        Ok(reply)
    }

    /// Fetch the live `ServingMetrics` report.
    pub fn stats(&mut self) -> Result<String, String> {
        let id = self.fresh_id();
        self.send(&Frame::Stats { id })?;
        match self.recv()? {
            Frame::StatsReport { text, .. } => Ok(text),
            Frame::Error { code, message, .. } => Err(format!("{code:?}: {message}")),
            other => Err(format!("unexpected reply to stats: {other:?}")),
        }
    }

    /// Load a model into the server's shared registry now.
    pub fn load_model(&mut self, model: &str) -> Result<String, String> {
        let id = self.fresh_id();
        self.send(&Frame::LoadModel { id, model: to_name(model)? })?;
        self.expect_ack(id)
    }

    /// Proactively unload a model server-side (registry + plan store +
    /// worker-held state).
    pub fn unload_model(&mut self, model: &str) -> Result<String, String> {
        let id = self.fresh_id();
        self.send(&Frame::UnloadModel { id, model: to_name(model)? })?;
        self.expect_ack(id)
    }

    /// Ask the server to drain and exit (admin).
    pub fn shutdown_server(&mut self) -> Result<String, String> {
        let id = self.fresh_id();
        self.send(&Frame::Shutdown { id })?;
        self.expect_ack(id)
    }

    fn expect_ack(&mut self, id: u64) -> Result<String, String> {
        match self.recv()? {
            Frame::Ack { id: got, info } if got == id => Ok(info),
            Frame::Error { code, message, .. } => Err(format!("{code:?}: {message}")),
            other => Err(format!("unexpected reply: {other:?}")),
        }
    }

    pub fn close(self) {
        self.stream.shutdown(Shutdown::Both).ok();
    }
}

fn to_name(model: &str) -> Result<String, String> {
    if model.len() > crate::net::protocol::MAX_NAME_LEN {
        return Err(format!("model name longer than {} bytes", crate::net::protocol::MAX_NAME_LEN));
    }
    Ok(model.to_string())
}
