//! Composable load-generation harness for the TCP gateway (the
//! `loadgen` subcommand).
//!
//! Modeled on compositional load-harness designs: a **workload** is a
//! value — a leaf operation or a weighted blend of workloads — sampled
//! per request, and the **data set** (what an `Infer` carries) is a
//! separate value, so the same blend can run over different payloads.
//! Model popularity is drawn from a **Zipf** distribution over the
//! model list (rank 1 most popular), matching the skew real serving
//! fleets see.  Arrivals are **open-loop**: requests are injected on a
//! Poisson schedule at a fixed rate regardless of completions, so
//! queueing delay shows up as latency (closed-loop harnesses hide it by
//! slowing the offered load down to the service rate).  `rate = 0`
//! switches to closed-loop with a bounded in-flight window — the
//! throughput-probe mode the `serve/loadgen` bench uses.
//!
//! Each connection runs a paced sender thread and a reply-reader
//! thread over the same pipelined wire session the reference client
//! speaks; replies correlate by request id.  The report line is
//! greppable (`failures=0`, `rps=`, `p99_us=`) — CI's loadgen-smoke job
//! and the `rps` bench headline both consume it.

use std::collections::HashMap;
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::net::protocol::{Frame, HelloStatus, WireBatch, MAGIC, VERSION};
use crate::util::rng::Rng;
use crate::util::stats::Reservoir;
use crate::util::trace::parse_summary_line;

/// Cap on client-side (trace_id, latency) samples retained for the
/// post-run span join — matches the server's own keep-slowest bound in
/// spirit: enough for a tail, not a transcript.
const MAX_SAMPLED: usize = 512;

/// Rows in the report's `slowest:` section.
const SLOWEST_ROWS: usize = 5;

/// A leaf operation, after sampling a workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Infer,
    Stats,
    Load,
    Unload,
}

const OP_KINDS: usize = 4;

impl Op {
    fn index(self) -> usize {
        match self {
            Op::Infer => 0,
            Op::Stats => 1,
            Op::Load => 2,
            Op::Unload => 3,
        }
    }
}

/// A workload as a compositional value: leaves are wire operations,
/// `Blend` mixes sub-workloads by weight.  Blends nest, so e.g. a 90/10
/// read/admin split whose admin half is itself a load/unload blend is
/// one value.
#[derive(Clone, Debug, PartialEq)]
pub enum Workload {
    Infer,
    Stats,
    Load,
    Unload,
    Blend(Vec<(f64, Workload)>),
}

impl Workload {
    /// Parse a blend spec: comma-separated `name:weight` terms, e.g.
    /// `infer:0.92,stats:0.04,load:0.02,unload:0.02` (a bare `infer`
    /// weighs 1).  Weights are relative, not required to sum to 1.
    pub fn parse(spec: &str) -> Result<Workload, String> {
        let mut terms = Vec::new();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (name, weight) = match part.split_once(':') {
                Some((n, w)) => {
                    let w: f64 = w
                        .trim()
                        .parse()
                        .map_err(|_| format!("workload `{part}`: weight is not a number"))?;
                    (n.trim(), w)
                }
                None => (part.trim(), 1.0),
            };
            if !weight.is_finite() || weight <= 0.0 {
                return Err(format!("workload `{part}`: weight must be > 0"));
            }
            let leaf = match name {
                "infer" => Workload::Infer,
                "stats" => Workload::Stats,
                "load" => Workload::Load,
                "unload" => Workload::Unload,
                other => {
                    return Err(format!(
                        "unknown workload `{other}` (expected infer/stats/load/unload)"
                    ))
                }
            };
            terms.push((weight, leaf));
        }
        match terms.len() {
            0 => Err("empty workload spec".into()),
            1 => Ok(terms.pop().unwrap().1),
            _ => Ok(Workload::Blend(terms)),
        }
    }

    /// Sample one leaf operation.
    pub fn sample(&self, rng: &mut Rng) -> Op {
        match self {
            Workload::Infer => Op::Infer,
            Workload::Stats => Op::Stats,
            Workload::Load => Op::Load,
            Workload::Unload => Op::Unload,
            Workload::Blend(terms) => {
                let total: f64 = terms.iter().map(|(w, _)| w).sum();
                let mut u = rng.uniform() * total;
                for (w, sub) in terms {
                    u -= w;
                    if u <= 0.0 {
                        return sub.sample(rng);
                    }
                }
                // float drift: fall through to the last term
                terms.last().expect("non-empty blend").1.sample(rng)
            }
        }
    }
}

/// What an `Infer` request carries — separate from the workload, so the
/// same blend runs over any payload shape.
#[derive(Clone, Debug)]
pub enum DataSet {
    /// Fresh seeded-uniform NHWC images each draw (the shape the
    /// in-tree image models eat; 28×28×1 matches `synthetic-mlp`).
    SyntheticImages { h: u32, w: u32, c: u32 },
}

impl Default for DataSet {
    fn default() -> Self {
        DataSet::SyntheticImages { h: 28, w: 28, c: 1 }
    }
}

impl DataSet {
    /// Draw one single-sample wire batch.
    pub fn draw(&self, rng: &mut Rng) -> WireBatch {
        match self {
            DataSet::SyntheticImages { h, w, c } => {
                let len = (h * w * c) as usize;
                let data = (0..len).map(|_| rng.uniform_f32(0.0, 1.0)).collect();
                WireBatch::Images { n: 1, h: *h, w: *w, c: *c, data }
            }
        }
    }
}

/// Zipf(s) sampler over ranks `0..n` (rank 0 most popular): CDF table +
/// binary search on a uniform draw.  `s = 0` degenerates to uniform.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n > 0, "Zipf over an empty set");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// Everything one `loadgen` run needs.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    pub addr: String,
    pub workload: Workload,
    pub data: DataSet,
    /// Models to target; popularity is Zipf-ranked in list order.
    pub models: Vec<String>,
    pub zipf_s: f64,
    /// Open-loop arrival rate, requests/second across all connections.
    /// `0` = closed-loop: each connection keeps up to `window` requests
    /// in flight (throughput probe).
    pub rate: f64,
    pub conns: usize,
    /// Wall-clock budget for the run (senders stop at the deadline).
    pub duration: Duration,
    /// Total request budget; `0` = until `duration` elapses.
    pub requests: u64,
    /// Closed-loop in-flight cap per connection (`rate = 0` mode).
    pub window: usize,
    pub deadline_ms: u32,
    /// Token for admin ops in the blend (load/unload); empty relies on
    /// the gateway's loopback-only fallback.
    pub admin_token: String,
    pub seed: u64,
    /// Flag the run if p99 exceeds this budget (µs); `0` disables.
    pub p99_budget_us: f64,
    /// Fraction of `Infer` requests sent with a client-chosen span-trace
    /// id (`0` = none).  Traced replies are joined with the server's
    /// span report after the run to attribute tail latency to pipeline
    /// stages (`slowest:` report lines).
    pub trace_sample: f64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7070".into(),
            workload: Workload::Infer,
            data: DataSet::default(),
            models: vec!["synthetic-mlp".into()],
            zipf_s: 1.1,
            rate: 0.0,
            conns: 4,
            duration: Duration::from_secs(10),
            requests: 0,
            window: 32,
            deadline_ms: 0,
            admin_token: String::new(),
            seed: 42,
            p99_budget_us: 0.0,
            trace_sample: 0.0,
        }
    }
}

/// Aggregated outcome of a run.  `Display` renders the greppable
/// one-line summary.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub sent: u64,
    pub ok: u64,
    pub failures: u64,
    pub elapsed: Duration,
    /// Sustained completion rate: ok replies / elapsed.
    pub rps: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    /// Per-op completed counts, indexed like `Op::index`.
    pub ops: [u64; OP_KINDS],
    /// Per-op latency percentiles (µs), indexed like `Op::index`.
    pub op_p50_us: [f64; OP_KINDS],
    pub op_p99_us: [f64; OP_KINDS],
    /// `Some(false)` when a p99 budget was set and blown.
    pub p99_within_budget: Option<bool>,
    pub last_error: Option<String>,
    /// Slowest traced requests joined with the server's span report —
    /// client latency next to the dominant server-side span.
    pub slowest: Vec<SlowTrace>,
}

/// One row of the `slowest:` section: a traced request's client-observed
/// latency joined with the server's span tree for the same trace id.
#[derive(Clone, Debug)]
pub struct SlowTrace {
    pub trace_id: u64,
    /// Client-observed latency (send → reply), µs.
    pub client_us: f64,
    /// Server-side span-tree total, µs.
    pub server_us: u64,
    /// Widest non-structural span in the tree (where the time went).
    pub dominant: String,
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "loadgen: sent={} ok={} failures={} elapsed_s={:.2} rps={:.1} \
             p50_us={:.0} p99_us={:.0} infer={} stats={} load={} unload={}",
            self.sent,
            self.ok,
            self.failures,
            self.elapsed.as_secs_f64(),
            self.rps,
            self.p50_us,
            self.p99_us,
            self.ops[0],
            self.ops[1],
            self.ops[2],
            self.ops[3],
        )?;
        if let Some(within) = self.p99_within_budget {
            write!(f, " p99_budget={}", if within { "ok" } else { "EXCEEDED" })?;
        }
        const OP_NAMES: [&str; OP_KINDS] = ["infer", "stats", "load", "unload"];
        for i in 0..OP_KINDS {
            if self.ops[i] > 0 {
                write!(
                    f,
                    "\nloadgen-op: op={} count={} p50_us={:.0} p99_us={:.0}",
                    OP_NAMES[i], self.ops[i], self.op_p50_us[i], self.op_p99_us[i]
                )?;
            }
        }
        for s in &self.slowest {
            write!(
                f,
                "\nslowest: id={:#018x} client_us={:.0} server_us={} dominant={}",
                s.trace_id, s.client_us, s.server_us, s.dominant
            )?;
        }
        Ok(())
    }
}

/// Counters shared across every connection's threads.
struct Totals {
    sent: AtomicU64,
    ok: AtomicU64,
    failures: AtomicU64,
    ops: [AtomicU64; OP_KINDS],
    latency_us: Mutex<Reservoir>,
    /// Per-op latency reservoirs, indexed like `Op::index`.
    op_latency_us: Mutex<Vec<Reservoir>>,
    /// Completed traced requests: `(trace_id, client latency µs)`,
    /// bounded at `MAX_SAMPLED`.
    sampled: Mutex<Vec<(u64, f64)>>,
    last_error: Mutex<Option<String>>,
}

/// Per-connection shared state between its sender and receiver.
struct ConnShared {
    /// id → (send time, op) for in-flight requests.
    pending: Mutex<HashMap<u64, (Instant, Op)>>,
    outstanding: AtomicUsize,
    done_sending: AtomicBool,
}

/// Handshake mirror of `Client::connect`: client hello, 7-byte server
/// hello, status check.
fn connect(addr: &str) -> Result<TcpStream, String> {
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    let mut hello = Vec::with_capacity(6);
    hello.extend_from_slice(&MAGIC);
    hello.extend_from_slice(&VERSION.to_le_bytes());
    stream.write_all(&hello).map_err(|e| format!("handshake write: {e}"))?;
    let mut reply = [0u8; 7];
    std::io::Read::read_exact(&mut stream, &mut reply)
        .map_err(|e| format!("handshake read: {e}"))?;
    if reply[..4] != MAGIC {
        return Err("server hello: bad magic".into());
    }
    match HelloStatus::from_byte(reply[6]) {
        Some(HelloStatus::Ok) => Ok(stream),
        Some(other) => Err(format!("server refused session: {other:?}")),
        None => Err(format!("server hello: unknown status byte {}", reply[6])),
    }
}

/// Run one load-generation campaign; blocks until every connection
/// finishes.  Errors only on setup failure (bad spec, no connection) —
/// mid-run transport errors count as request failures in the report.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport, String> {
    if cfg.models.is_empty() {
        return Err("loadgen needs at least one model".into());
    }
    if cfg.conns == 0 {
        return Err("loadgen needs at least one connection".into());
    }
    let totals = Arc::new(Totals {
        sent: AtomicU64::new(0),
        ok: AtomicU64::new(0),
        failures: AtomicU64::new(0),
        ops: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
        latency_us: Mutex::new(Reservoir::new(8192, cfg.seed ^ 0x10AD_6E11)),
        op_latency_us: Mutex::new(
            (0..OP_KINDS).map(|i| Reservoir::new(2048, cfg.seed ^ (0xD15C0 + i as u64))).collect(),
        ),
        sampled: Mutex::new(Vec::new()),
        last_error: Mutex::new(None),
    });
    let zipf = Arc::new(Zipf::new(cfg.models.len(), cfg.zipf_s));
    let t0 = Instant::now();
    let deadline = t0 + cfg.duration;
    let mut threads = Vec::new();
    for ci in 0..cfg.conns {
        let stream = connect(&cfg.addr)?;
        let read_half = stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
        let shared = Arc::new(ConnShared {
            pending: Mutex::new(HashMap::new()),
            outstanding: AtomicUsize::new(0),
            done_sending: AtomicBool::new(false),
        });
        // split a total-request budget evenly, remainder to low conns
        let quota = if cfg.requests == 0 {
            u64::MAX
        } else {
            cfg.requests / cfg.conns as u64
                + u64::from((ci as u64) < cfg.requests % cfg.conns as u64)
        };
        let cfg_c = cfg.clone();
        let totals_c = Arc::clone(&totals);
        let shared_c = Arc::clone(&shared);
        let zipf_c = Arc::clone(&zipf);
        threads.push(
            std::thread::Builder::new()
                .name(format!("loadgen-tx{ci}"))
                .spawn(move || sender(stream, ci, quota, deadline, cfg_c, totals_c, shared_c, zipf_c))
                .map_err(|e| e.to_string())?,
        );
        let totals_c = Arc::clone(&totals);
        threads.push(
            std::thread::Builder::new()
                .name(format!("loadgen-rx{ci}"))
                .spawn(move || receiver(read_half, totals_c, shared))
                .map_err(|e| e.to_string())?,
        );
    }
    for t in threads {
        t.join().map_err(|_| "loadgen thread panicked".to_string())?;
    }
    let elapsed = t0.elapsed();
    let ok = totals.ok.load(Ordering::SeqCst);
    let (p50_us, p99_us) = {
        let r = totals.latency_us.lock().unwrap();
        (r.percentile(50.0), r.percentile(99.0))
    };
    let p99_within_budget = (cfg.p99_budget_us > 0.0).then(|| p99_us <= cfg.p99_budget_us);
    let (op_p50_us, op_p99_us) = {
        let rs = totals.op_latency_us.lock().unwrap();
        let mut p50 = [0.0; OP_KINDS];
        let mut p99 = [0.0; OP_KINDS];
        for i in 0..OP_KINDS {
            p50[i] = rs[i].percentile(50.0);
            p99[i] = rs[i].percentile(99.0);
        }
        (p50, p99)
    };
    let sampled = totals.sampled.lock().unwrap().clone();
    let slowest = join_slowest(&cfg.addr, &sampled);
    Ok(LoadReport {
        sent: totals.sent.load(Ordering::SeqCst),
        ok,
        failures: totals.failures.load(Ordering::SeqCst),
        elapsed,
        rps: ok as f64 / elapsed.as_secs_f64().max(1e-9),
        p50_us,
        p99_us,
        ops: [
            totals.ops[0].load(Ordering::SeqCst),
            totals.ops[1].load(Ordering::SeqCst),
            totals.ops[2].load(Ordering::SeqCst),
            totals.ops[3].load(Ordering::SeqCst),
        ],
        op_p50_us,
        op_p99_us,
        p99_within_budget,
        last_error: totals.last_error.lock().unwrap().clone(),
        slowest,
    })
}

/// Join the client-observed latencies of traced requests with the
/// server's span-trace report (one extra session, post-run): the
/// slowest few come back with the server-side total and dominant span,
/// so the tail is attributed, not just measured.
fn join_slowest(addr: &str, sampled: &[(u64, f64)]) -> Vec<SlowTrace> {
    if sampled.is_empty() {
        return Vec::new();
    }
    let Ok(mut client) = crate::net::client::Client::connect(addr) else {
        return Vec::new();
    };
    let report = match client.trace_spans() {
        Ok(text) => text,
        Err(_) => return Vec::new(),
    };
    client.close();
    let mut by_id = HashMap::new();
    for line in report.lines() {
        if let Some(entry) = parse_summary_line(line) {
            by_id.insert(entry.id, entry);
        }
    }
    let mut rows: Vec<SlowTrace> = sampled
        .iter()
        .filter_map(|&(id, client_us)| {
            by_id.get(&id).map(|e| SlowTrace {
                trace_id: id,
                client_us,
                server_us: e.total_us,
                dominant: e.dominant.clone().unwrap_or_else(|| "-".into()),
            })
        })
        .collect();
    rows.sort_by(|a, b| b.client_us.partial_cmp(&a.client_us).unwrap_or(std::cmp::Ordering::Equal));
    rows.truncate(SLOWEST_ROWS);
    rows
}

#[allow(clippy::too_many_arguments)]
fn sender(
    mut stream: TcpStream,
    conn_index: usize,
    quota: u64,
    deadline: Instant,
    cfg: LoadgenConfig,
    totals: Arc<Totals>,
    shared: Arc<ConnShared>,
    zipf: Arc<Zipf>,
) {
    let mut rng = Rng::seed_from(cfg.seed.wrapping_add(conn_index as u64 * 0x9E37_79B9));
    let per_conn_rate = cfg.rate / cfg.conns as f64;
    let mut next_arrival = Instant::now();
    let mut id: u64 = 0;
    let mut sent: u64 = 0;
    while sent < quota && Instant::now() < deadline {
        if cfg.rate > 0.0 {
            // open-loop Poisson arrivals: exponential inter-arrival at
            // the per-connection rate, independent of completions
            let gap = -(1.0 - rng.uniform()).ln() / per_conn_rate;
            next_arrival += Duration::from_secs_f64(gap);
            let now = Instant::now();
            if next_arrival > now {
                std::thread::sleep(next_arrival - now);
            }
        } else {
            // closed-loop: cap in-flight per connection
            while shared.outstanding.load(Ordering::SeqCst) >= cfg.window {
                if Instant::now() >= deadline {
                    break;
                }
                std::thread::sleep(Duration::from_micros(100));
            }
        }
        if Instant::now() >= deadline {
            break;
        }
        let op = cfg.workload.sample(&mut rng);
        let model = cfg.models[zipf.sample(&mut rng)].clone();
        id += 1;
        let frame = match op {
            Op::Infer => {
                // derived id: connection in the high half, sequence in
                // the low — unique across the whole run, join key for
                // the post-run span report (&& short-circuits the draw,
                // so trace_sample=0 leaves the rng stream untouched)
                let trace_id = if cfg.trace_sample > 0.0 && rng.uniform() < cfg.trace_sample {
                    ((conn_index as u64 + 1) << 32) | id
                } else {
                    0
                };
                Frame::Infer {
                    id,
                    model,
                    deadline_ms: cfg.deadline_ms,
                    input: cfg.data.draw(&mut rng),
                    trace_id,
                }
            }
            Op::Stats => Frame::Stats { id },
            Op::Load => Frame::LoadModel { id, model, token: cfg.admin_token.clone() },
            Op::Unload => Frame::UnloadModel { id, model, token: cfg.admin_token.clone() },
        };
        shared.pending.lock().unwrap().insert(id, (Instant::now(), op));
        shared.outstanding.fetch_add(1, Ordering::SeqCst);
        if stream.write_all(&frame.encode()).is_err() {
            // transport gone: the receiver will account the in-flight
            // loss; stop offering
            shared.pending.lock().unwrap().remove(&id);
            shared.outstanding.fetch_sub(1, Ordering::SeqCst);
            break;
        }
        sent += 1;
        totals.sent.fetch_add(1, Ordering::SeqCst);
    }
    shared.done_sending.store(true, Ordering::SeqCst);
    // Wait for in-flight replies (bounded grace past the deadline),
    // then shut the socket down: that is what unblocks the receiver —
    // a read timeout instead could fire mid-frame and desync framing.
    let grace = deadline + Duration::from_secs(10);
    while shared.outstanding.load(Ordering::SeqCst) > 0 && Instant::now() < grace {
        std::thread::sleep(Duration::from_millis(1));
    }
    stream.shutdown(std::net::Shutdown::Both).ok();
}

fn receiver(mut stream: TcpStream, totals: Arc<Totals>, shared: Arc<ConnShared>) {
    loop {
        let frame = match Frame::read_from(&mut stream) {
            Ok(f) => f,
            Err(_) => {
                // EOF or error: clean if the sender finished and every
                // reply came back, otherwise the in-flight ones are lost
                let lost = shared.outstanding.swap(0, Ordering::SeqCst) as u64;
                if lost > 0 {
                    totals.failures.fetch_add(lost, Ordering::SeqCst);
                    let mut last = totals.last_error.lock().unwrap();
                    *last = Some("connection lost with requests in flight".into());
                }
                return;
            }
        };
        let id = frame.id();
        let Some((t_sent, op)) = shared.pending.lock().unwrap().remove(&id) else {
            continue; // unsolicited (e.g. server error with id 0)
        };
        shared.outstanding.fetch_sub(1, Ordering::SeqCst);
        let lat_us = t_sent.elapsed().as_secs_f64() * 1e6;
        totals.latency_us.lock().unwrap().add(lat_us);
        totals.op_latency_us.lock().unwrap()[op.index()].add(lat_us);
        match frame {
            Frame::Error { message, code, .. } => {
                totals.failures.fetch_add(1, Ordering::SeqCst);
                let mut last = totals.last_error.lock().unwrap();
                *last = Some(format!("{code:?}: {message}"));
            }
            other => {
                totals.ok.fetch_add(1, Ordering::SeqCst);
                totals.ops[op.index()].fetch_add(1, Ordering::SeqCst);
                if let Frame::InferOk { trace_id, .. } = other {
                    if trace_id != 0 {
                        let mut s = totals.sampled.lock().unwrap();
                        if s.len() < MAX_SAMPLED {
                            s.push((trace_id, lat_us));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_parse_roundtrips_blends() {
        let w = Workload::parse("infer:0.9,stats:0.05,load:0.03,unload:0.02").unwrap();
        let Workload::Blend(terms) = &w else { panic!("expected blend") };
        assert_eq!(terms.len(), 4);
        assert_eq!(Workload::parse("infer").unwrap(), Workload::Infer);
        assert!(Workload::parse("").is_err());
        assert!(Workload::parse("infer:nope").is_err());
        assert!(Workload::parse("mystery:1").is_err());
        assert!(Workload::parse("infer:0").is_err());
    }

    #[test]
    fn workload_sampling_tracks_weights() {
        let w = Workload::parse("infer:0.9,stats:0.1").unwrap();
        let mut rng = Rng::seed_from(7);
        let mut counts = [0u32; OP_KINDS];
        for _ in 0..10_000 {
            counts[w.sample(&mut rng).index()] += 1;
        }
        assert!(counts[Op::Infer.index()] > 8_500, "{counts:?}");
        assert!(counts[Op::Stats.index()] > 500, "{counts:?}");
        assert_eq!(counts[Op::Load.index()], 0);
    }

    #[test]
    fn nested_blends_sample_leaves() {
        let w = Workload::Blend(vec![
            (0.5, Workload::Infer),
            (0.5, Workload::Blend(vec![(1.0, Workload::Load), (1.0, Workload::Unload)])),
        ]);
        let mut rng = Rng::seed_from(11);
        let mut counts = [0u32; OP_KINDS];
        for _ in 0..4_000 {
            counts[w.sample(&mut rng).index()] += 1;
        }
        assert!(counts[Op::Infer.index()] > 1_500, "{counts:?}");
        assert!(counts[Op::Load.index()] > 500, "{counts:?}");
        assert!(counts[Op::Unload.index()] > 500, "{counts:?}");
    }

    #[test]
    fn zipf_rank_zero_dominates_and_covers_all_ranks() {
        let z = Zipf::new(8, 1.1);
        let mut rng = Rng::seed_from(3);
        let mut counts = [0u32; 8];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 0, "rank {i} never sampled: {counts:?}");
        }
        assert!(counts[0] > counts[3] && counts[3] > counts[7], "{counts:?}");
        // s = 0 degenerates to uniform-ish
        let z = Zipf::new(4, 0.0);
        let mut counts = [0u32; 4];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!(c > 3_500, "{counts:?}");
        }
    }

    #[test]
    fn dataset_draws_are_seed_deterministic() {
        let ds = DataSet::default();
        let a = ds.draw(&mut Rng::seed_from(9));
        let b = ds.draw(&mut Rng::seed_from(9));
        let (WireBatch::Images { data: da, h, w, c, .. }, WireBatch::Images { data: db, .. }) =
            (a, b)
        else {
            panic!("expected images")
        };
        assert_eq!((h, w, c), (28, 28, 1));
        assert_eq!(da.len(), 28 * 28);
        assert_eq!(da, db);
    }

    #[test]
    fn report_line_is_greppable() {
        let rep = LoadReport {
            sent: 10,
            ok: 10,
            failures: 0,
            elapsed: Duration::from_secs(2),
            rps: 5.0,
            p50_us: 900.0,
            p99_us: 4200.0,
            ops: [8, 2, 0, 0],
            op_p50_us: [850.0, 120.0, 0.0, 0.0],
            op_p99_us: [4100.0, 300.0, 0.0, 0.0],
            p99_within_budget: Some(true),
            last_error: None,
            slowest: vec![SlowTrace {
                trace_id: 0x1_0000_0007,
                client_us: 4180.0,
                server_us: 3900,
                dominant: "analog_gemm".into(),
            }],
        };
        let text = rep.to_string();
        let headline = text.lines().next().unwrap();
        assert!(headline.contains("failures=0"), "{headline}");
        assert!(headline.contains("rps=5.0"), "{headline}");
        assert!(headline.contains("p99_us=4200"), "{headline}");
        assert!(headline.contains("p99_budget=ok"), "{headline}");
        // per-op breakdown only for ops that completed
        assert!(text.contains("loadgen-op: op=infer count=8 p50_us=850 p99_us=4100"), "{text}");
        assert!(text.contains("loadgen-op: op=stats count=2"), "{text}");
        assert!(!text.contains("op=load"), "{text}");
        // slowest section attributes the tail to the dominant span
        assert!(text.contains("slowest: id=0x0000000100000007 client_us=4180"), "{text}");
        assert!(text.contains("server_us=3900 dominant=analog_gemm"), "{text}");
    }
}
