//! Event-driven session layer: readiness loops over nonblocking sockets.
//!
//! The pre-PR-9 gateway spent two OS threads per TCP session (blocking
//! reader + writer).  This module replaces the pair with a small fixed
//! pool of **readiness loops** (`GatewayConfig::loop_threads`, default
//! 1), each owning a slab of nonblocking connections — session count no
//! longer moves the thread count at all.  std has no `epoll`/`kqueue`
//! surface, so readiness is hand-rolled: each loop sweeps its
//! connections with nonblocking reads/writes, then parks on a **wakeup
//! socketpair** with a bounded timeout.
//!
//! ## Ownership
//!
//! ```text
//!  acceptor ──LoopMsg::Conn──▶ loop 0 ─┬─ conn slab [token → Conn]
//!                (round-robin)  loop 1 ─┤    state: Sniff → Active
//!                                  …    │    FrameAssembler (reads)
//!                                       │    WriteBuf       (writes)
//!  coordinator delivery callbacks       │    in_flight, deadline
//!     └─LoopMsg::Reply{token,gen}──▶────┘
//!            + 1 byte on the wakeup socketpair
//! ```
//!
//! A connection is owned by exactly one loop for its whole life; no
//! lock is ever taken on a per-session basis.  Delivery callbacks from
//! the coordinator run on worker threads, so they cannot touch the slab
//! directly: they enqueue a `LoopMsg::Reply` on the loop's channel and
//! write one byte to the wakeup pipe, which pops the loop out of its
//! idle park immediately (replies never wait for the sweep tick).
//! Tokens are generation-fenced: a reply for a connection that died and
//! whose slot was reused is dropped, never cross-delivered.
//!
//! ## Backpressure + timeouts
//!
//! Writes go through a per-connection buffer flushed opportunistically
//! until `WouldBlock`.  A peer that stops reading grows its buffer; past
//! `WRITE_BACKPRESSURE` bytes the loop stops *reading* from that
//! connection (no new requests → no new replies) until the buffer
//! drains.  A lazy timer wheel enforces the idle timeout: every
//! connection keeps one wheel entry; firing re-checks the live deadline
//! (refreshed on any read or write progress) and either reschedules or
//! severs the connection.
//!
//! ## Latency/CPU trade
//!
//! Without kernel readiness, inbound bytes on an otherwise idle loop are
//! only seen on the next sweep, so the park timeout bounds added request
//! latency.  The timeout adapts to the slab: ~1 ms up to 256 connections
//! (latency-first), growing to 8 ms at several thousand (CPU-first —
//! a full sweep of N sockets costs N nonblocking reads), and 10 ms for
//! an empty loop.  A busy loop never parks: any progress re-sweeps
//! immediately, so under load the added latency is ~0 and throughput is
//! bounded by the work, not the tick.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::net::gateway::{handle_frame, hello_bytes, reject, serve_http, GatewayShared};
use crate::net::protocol::{ErrorCode, Frame, FrameAssembler, HelloStatus, MAGIC, VERSION};
use crate::util::metrics::Counter;
use crate::util::trace::{self, Span};

/// Stop reading from a connection whose un-flushed reply bytes exceed
/// this (resume when the peer drains its socket).
const WRITE_BACKPRESSURE: usize = 4 << 20;

/// Per-connection, per-sweep read bound: after this many bytes the loop
/// moves on (fairness); leftover socket data re-sweeps immediately.
const READ_QUANTUM: usize = 64 << 10;

/// Park-timeout shape (see module doc): min / max with live sessions,
/// and the relaxed tick for a loop with nothing connected.
const PARK_MIN: Duration = Duration::from_millis(1);
const PARK_MAX: Duration = Duration::from_millis(8);
const PARK_EMPTY: Duration = Duration::from_millis(10);

/// Timer-wheel geometry: 128 slots; the slot width scales with the idle
/// timeout so one rotation comfortably covers it (entries further out
/// simply re-check and reschedule — the wheel is lazy).
const WHEEL_SLOTS: usize = 128;

/// Work sent to a readiness loop (always paired with a wakeup byte).
pub(crate) enum LoopMsg {
    /// A freshly accepted connection, pre-handshake.
    Conn(TcpStream, SocketAddr),
    /// A coordinator reply for session `token` (dropped unless `gen`
    /// still matches — slots are reused).
    Reply { token: usize, gen: u64, frame: Frame },
    /// Graceful drain: stop reading, deliver every owed reply, exit.
    Drain,
}

/// Write end of a loop's wakeup socketpair.  Nonblocking: if the socket
/// buffer is full a wakeup is already pending, so `WouldBlock` is a
/// success.
struct WakeHalf {
    stream: TcpStream,
}

impl WakeHalf {
    fn wake(&self) {
        (&self.stream).write_all(&[1u8]).ok();
    }
}

/// Cheap clonable address of one readiness loop; the acceptor and every
/// delivery callback hold one.
#[derive(Clone)]
pub(crate) struct LoopHandle {
    tx: Sender<LoopMsg>,
    wake: Arc<WakeHalf>,
}

impl LoopHandle {
    pub(crate) fn send(&self, msg: LoopMsg) {
        if self.tx.send(msg).is_ok() {
            self.wake.wake();
        }
    }
}

/// Where a routed delivery callback sends its reply frame: loop +
/// generation-fenced slot.
#[derive(Clone)]
pub(crate) struct ReplyRoute {
    pub(crate) handle: LoopHandle,
    pub(crate) token: usize,
    pub(crate) gen: u64,
}

impl ReplyRoute {
    pub(crate) fn deliver(&self, frame: Frame) {
        self.handle.send(LoopMsg::Reply { token: self.token, gen: self.gen, frame });
    }
}

/// A loopback socketpair: std exposes no `pipe(2)`, so the wakeup
/// channel is a connected TCP pair on 127.0.0.1 (write end nonblocking,
/// read end blocking — the loop parks on it with a read timeout).
fn socketpair() -> std::io::Result<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let writer = TcpStream::connect(addr)?;
    let (reader, _) = listener.accept()?;
    writer.set_nonblocking(true)?;
    writer.set_nodelay(true).ok();
    reader.set_nodelay(true).ok();
    Ok((writer, reader))
}

/// Absolute-tick lazy timer wheel.  Each connection keeps at most one
/// entry; firing verifies against the connection's live deadline and
/// reschedules when the deadline moved (activity refreshes it).
struct TimerWheel {
    slots: Vec<Vec<(usize, u64)>>,
    tick: Duration,
    epoch: Instant,
    /// Next absolute tick to fire (everything below already fired).
    cursor: u64,
}

impl TimerWheel {
    fn new(idle_timeout: Duration, epoch: Instant) -> TimerWheel {
        // one rotation ≈ 2× the idle timeout, floored at 5 ms slots
        let tick_ms = (2 * idle_timeout.as_millis() as u64 / WHEEL_SLOTS as u64).clamp(5, 1000);
        TimerWheel {
            slots: vec![Vec::new(); WHEEL_SLOTS],
            tick: Duration::from_millis(tick_ms),
            epoch,
            cursor: 1,
        }
    }

    fn abs_tick(&self, t: Instant) -> u64 {
        let ms = t.saturating_duration_since(self.epoch).as_millis() as u64;
        ms / self.tick.as_millis() as u64
    }

    /// Insert `(token, gen)` to fire at (or after) `deadline`.
    fn schedule(&mut self, token: usize, gen: u64, deadline: Instant) {
        // +1: round up so an entry never fires before its deadline tick
        let tick = (self.abs_tick(deadline) + 1).max(self.cursor);
        self.slots[(tick % WHEEL_SLOTS as u64) as usize].push((token, gen));
    }

    /// Pop every entry whose slot has come due by `now`.
    fn expired(&mut self, now: Instant) -> Vec<(usize, u64)> {
        let now_tick = self.abs_tick(now);
        let mut out = Vec::new();
        while self.cursor <= now_tick {
            let slot = (self.cursor % WHEEL_SLOTS as u64) as usize;
            out.append(&mut self.slots[slot]);
            self.cursor += 1;
        }
        out
    }
}

/// Buffered nonblocking writes for one connection.
struct WriteBuf {
    buf: Vec<u8>,
    pos: usize,
}

impl WriteBuf {
    fn new() -> WriteBuf {
        WriteBuf { buf: Vec::new(), pos: 0 }
    }

    fn queue(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Write until `WouldBlock` or empty.  `Ok(true)` = made progress.
    fn flush(&mut self, stream: &mut TcpStream) -> std::io::Result<bool> {
        let mut progress = false;
        while self.pos < self.buf.len() {
            match stream.write(&self.buf[self.pos..]) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(n) => {
                    self.pos += n;
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > (1 << 20) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        Ok(progress)
    }
}

enum ConnState {
    /// Accumulating the first ≤6 bytes: HTTP method sniff, then the
    /// binary hello (magic + version) and the admission decision.
    Sniff,
    /// Handshake accepted (or typed-reject queued with
    /// `close_after_flush`); frames flow.
    Active,
}

struct Conn {
    stream: TcpStream,
    peer: SocketAddr,
    gen: u64,
    state: ConnState,
    sniff: Vec<u8>,
    assembler: FrameAssembler,
    write_buf: WriteBuf,
    /// Admitted `Infer` submissions whose delivery callback has not yet
    /// enqueued a reply — the drain invariant ("no accepted request
    /// loses its reply") closes a connection only at zero.
    in_flight: usize,
    /// Holds an `active` gauge slot (decremented exactly once on close).
    admitted: bool,
    session_idx: u64,
    peer_is_loopback: bool,
    chaos_drop: Option<u64>,
    frames_read: u64,
    read_closed: bool,
    close_after_flush: bool,
    deadline: Instant,
    /// Monotonic µs when the first byte of the frame currently being
    /// assembled arrived; 0 between frames.  Feeds the traced `assemble`
    /// span (wire read → complete frame).
    read_start_us: u64,
    /// Traced `InferOk` replies queued in `write_buf` but not yet
    /// flushed: `(trace_id, enqueue_us)`.  When the buffer drains the
    /// loop records one `write_flush` span per entry and completes the
    /// trace — the span tree's true end-to-end edge.
    traced_replies: Vec<(u64, u64)>,
}

/// Bound on per-connection traced replies awaiting flush; beyond this a
/// trace completes at enqueue time (losing only its write_flush span).
const MAX_TRACED_REPLIES: usize = 32;

impl Conn {
    fn new(stream: TcpStream, peer: SocketAddr, gen: u64, idle_timeout: Duration) -> Conn {
        Conn {
            stream,
            peer,
            gen,
            state: ConnState::Sniff,
            sniff: Vec::with_capacity(6),
            assembler: FrameAssembler::new(),
            write_buf: WriteBuf::new(),
            in_flight: 0,
            admitted: false,
            session_idx: 0,
            peer_is_loopback: peer.ip().is_loopback(),
            chaos_drop: None,
            frames_read: 0,
            read_closed: false,
            close_after_flush: false,
            deadline: Instant::now() + idle_timeout,
            read_start_us: 0,
            traced_replies: Vec::new(),
        }
    }
}

/// One readiness loop: slab of connections + control channel + wakeup
/// pair + timer wheel.
struct EventLoop {
    shared: Arc<GatewayShared>,
    rx: Receiver<LoopMsg>,
    /// This loop's own address (delivery callbacks route through it).
    handle: LoopHandle,
    wake_rx: TcpStream,
    wake_timeout: Option<Duration>,
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    next_gen: u64,
    live: usize,
    wheel: TimerWheel,
    draining: bool,
    /// Admitted sessions alive when `Drain` arrived (the loop's return
    /// value, summed into the "drained N session(s)" log line).
    drained_sessions: usize,
    busy_us: Arc<Counter>,
    wakeups: Arc<Counter>,
}

/// Spawn one readiness loop thread; returns its handle and the join
/// handle (joined at gateway shutdown, yields the drained-session
/// count).
pub(crate) fn spawn_loop(
    shared: Arc<GatewayShared>,
    index: usize,
) -> Result<(LoopHandle, JoinHandle<usize>), String> {
    let (wake_tx, wake_rx) = socketpair().map_err(|e| format!("wakeup socketpair: {e}"))?;
    let (tx, rx) = mpsc::channel();
    let handle = LoopHandle { tx, wake: Arc::new(WakeHalf { stream: wake_tx }) };
    let reg = shared.handle.metric_registry();
    let label = index.to_string();
    let busy_us = reg.counter_labeled(
        "rns_gateway_loop_busy_us",
        "Readiness-loop time spent sweeping/processing (vs parked), microseconds",
        "loop",
        &label,
    );
    let wakeups = reg.counter_labeled(
        "rns_gateway_loop_wakeups_total",
        "Times the readiness loop was woken through its wakeup pipe",
        "loop",
        &label,
    );
    let epoch = Instant::now();
    let wheel = TimerWheel::new(shared.cfg.idle_timeout, epoch);
    let mut ev = EventLoop {
        shared,
        rx,
        handle: handle.clone(),
        wake_rx,
        wake_timeout: None,
        conns: Vec::new(),
        free: Vec::new(),
        next_gen: 1,
        live: 0,
        wheel,
        draining: false,
        drained_sessions: 0,
        busy_us,
        wakeups,
    };
    let join = std::thread::Builder::new()
        .name(format!("rns-gw-loop{index}"))
        .spawn(move || ev.run())
        .map_err(|e| e.to_string())?;
    Ok((handle, join))
}

impl EventLoop {
    fn run(&mut self) -> usize {
        loop {
            let t0 = Instant::now();
            let mut progress = self.drain_msgs();
            for t in 0..self.conns.len() {
                if self.conns[t].is_some() {
                    progress |= self.sweep_conn(t);
                }
            }
            progress |= self.fire_timers();
            self.busy_us.add(t0.elapsed().as_micros() as u64);
            if self.draining && self.live == 0 {
                return self.drained_sessions;
            }
            if !progress {
                self.park();
            }
        }
    }

    /// Park on the wakeup pipe; a delivery callback's wakeup byte ends
    /// the park immediately, otherwise the timeout bounds how long
    /// inbound socket data can sit unseen.
    fn park(&mut self) {
        let timeout = if self.live == 0 {
            PARK_EMPTY
        } else {
            // scale the tick with slab size: sweeping N sockets costs N
            // nonblocking reads, so huge slabs trade a little latency
            // for a lot of idle CPU
            let scaled = Duration::from_millis(1 + self.live as u64 / 256);
            scaled.clamp(PARK_MIN, PARK_MAX)
        };
        if self.wake_timeout != Some(timeout) {
            self.wake_rx.set_read_timeout(Some(timeout)).ok();
            self.wake_timeout = Some(timeout);
        }
        let mut buf = [0u8; 64];
        match self.wake_rx.read(&mut buf) {
            Ok(n) if n > 0 => self.wakeups.inc(),
            _ => {} // park timeout elapsed (or spurious) — just re-sweep
        }
    }

    fn drain_msgs(&mut self) -> bool {
        let mut progress = false;
        loop {
            match self.rx.try_recv() {
                Ok(LoopMsg::Conn(stream, peer)) => {
                    progress = true;
                    self.add_conn(stream, peer);
                }
                Ok(LoopMsg::Reply { token, gen, frame }) => {
                    progress = true;
                    self.deliver_reply(token, gen, frame);
                }
                Ok(LoopMsg::Drain) => {
                    progress = true;
                    self.begin_drain();
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        progress
    }

    fn add_conn(&mut self, mut stream: TcpStream, peer: SocketAddr) {
        if self.draining {
            // drain race: the acceptor stopped first, but this one was
            // already in the channel — refuse with the typed reject
            self.shared.rejected.inc();
            stream.set_nonblocking(false).ok();
            reject(&mut stream, HelloStatus::Draining, ErrorCode::Draining, "gateway is draining");
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        stream.set_nodelay(true).ok();
        let gen = self.next_gen;
        self.next_gen += 1;
        let conn = Conn::new(stream, peer, gen, self.shared.cfg.idle_timeout);
        let token = match self.free.pop() {
            Some(t) => {
                self.conns[t] = Some(conn);
                t
            }
            None => {
                self.conns.push(Some(conn));
                self.conns.len() - 1
            }
        };
        self.live += 1;
        let deadline = self.conns[token].as_ref().unwrap().deadline;
        self.wheel.schedule(token, gen, deadline);
    }

    fn deliver_reply(&mut self, token: usize, gen: u64, frame: Frame) {
        let Some(conn) = self.conns.get_mut(token).and_then(|c| c.as_mut()) else {
            return; // connection died before its reply — dropped, as
                    // the old writer did after a peer vanished
        };
        if conn.gen != gen {
            return; // slot reused: never cross-deliver
        }
        conn.in_flight = conn.in_flight.saturating_sub(1);
        self.shared.frames_out.inc();
        if let Frame::InferOk { trace_id, .. } = &frame {
            if *trace_id != 0 && self.shared.collector.enabled() {
                if conn.traced_replies.len() < MAX_TRACED_REPLIES {
                    conn.traced_replies.push((*trace_id, trace::now_us()));
                } else {
                    // pathological pile-up: finish the trace now rather
                    // than grow unboundedly (only write_flush is lost)
                    self.shared.collector.complete(*trace_id, trace::now_us());
                }
            }
        }
        conn.write_buf.queue(&frame.encode());
    }

    fn begin_drain(&mut self) {
        self.draining = true;
        for token in 0..self.conns.len() {
            let Some(conn) = self.conns[token].as_mut() else { continue };
            if conn.admitted {
                self.drained_sessions += 1;
                // half-close the read side: the peer sees EOF where its
                // next request would have gone, while every owed reply
                // still flows out
                conn.stream.shutdown(Shutdown::Read).ok();
                conn.read_closed = true;
                conn.close_after_flush = true;
            } else {
                // pre-handshake: nothing owed
                self.free_conn(token);
            }
        }
    }

    /// One sweep of one connection: read (unless closed/backpressured),
    /// flush writes, retire if done.  Returns whether progress was made.
    fn sweep_conn(&mut self, token: usize) -> bool {
        let mut progress = false;
        // read phase
        let (read_closed, is_sniff, backpressured) = {
            let conn = self.conns[token].as_ref().unwrap();
            (
                conn.read_closed,
                matches!(conn.state, ConnState::Sniff),
                conn.write_buf.pending() > WRITE_BACKPRESSURE,
            )
        };
        if !read_closed && !backpressured {
            progress |= if is_sniff { self.read_sniff(token) } else { self.read_active(token) };
        }
        let Some(conn) = self.conns.get_mut(token).and_then(|c| c.as_mut()) else {
            return true; // freed (or handed to HTTP) during the read phase
        };
        // write phase
        if conn.write_buf.pending() > 0 {
            match conn.write_buf.flush(&mut conn.stream) {
                Ok(wrote) => {
                    if wrote {
                        progress = true;
                        conn.deadline = Instant::now() + self.shared.cfg.idle_timeout;
                    }
                }
                Err(_) => {
                    self.free_conn(token);
                    return true;
                }
            }
        }
        // traced replies ride the write buffer: once it fully drains the
        // reply bytes reached the kernel, so stamp each write_flush span
        // and complete the trace (its true end-to-end edge)
        if conn.write_buf.pending() == 0 && !conn.traced_replies.is_empty() {
            let now = trace::now_us();
            for (id, enq) in conn.traced_replies.drain(..) {
                let dur = now.saturating_sub(enq);
                let span = Span::new(trace::SPAN_WRITE_FLUSH, trace::GATEWAY_TID, enq, dur);
                self.shared.collector.record(id, span);
                self.shared.collector.complete(id, now);
            }
            progress = true;
        }
        // retire phase: graceful close once nothing is owed
        let conn = self.conns[token].as_mut().unwrap();
        let done_reading = conn.read_closed || conn.close_after_flush;
        if done_reading && conn.in_flight == 0 && conn.write_buf.pending() == 0 {
            self.free_conn(token);
            return true;
        }
        progress
    }

    /// Sniff-state read: accumulate the first 4 bytes (HTTP vs binary),
    /// then 2 more (version), then admit/reject.  Returns progress.
    fn read_sniff(&mut self, token: usize) -> bool {
        {
            let conn = self.conns[token].as_mut().unwrap();
            let want =
                if conn.sniff.len() < 4 { 4 - conn.sniff.len() } else { 6 - conn.sniff.len() };
            let mut tmp = [0u8; 6];
            match conn.stream.read(&mut tmp[..want]) {
                Ok(0) => {
                    self.free_conn(token);
                    return true;
                }
                Ok(n) => {
                    conn.sniff.extend_from_slice(&tmp[..n]);
                    conn.deadline = Instant::now() + self.shared.cfg.idle_timeout;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return false,
                Err(e) if e.kind() == ErrorKind::Interrupted => return false,
                Err(_) => {
                    self.free_conn(token);
                    return true;
                }
            }
        }
        let conn = self.conns[token].as_mut().unwrap();
        if conn.sniff.len() == 4 {
            let first: [u8; 4] = conn.sniff[..4].try_into().unwrap();
            if &first == b"GET " || &first == b"HEAD" {
                // HTTP scrape: hand the socket to a short-lived blocking
                // responder thread (scrapes are rare, bounded, and must
                // work *especially* when the loops are saturated)
                let conn = self.conns[token].take().unwrap();
                self.live -= 1;
                self.free.push(token);
                let shared = Arc::clone(&self.shared);
                let stream = conn.stream;
                stream.set_nonblocking(false).ok();
                stream.set_read_timeout(Some(shared.cfg.idle_timeout)).ok();
                stream.set_write_timeout(Some(shared.cfg.idle_timeout)).ok();
                std::thread::Builder::new()
                    .name("rns-gw-http".into())
                    .spawn(move || serve_http(stream, &shared, &first == b"HEAD"))
                    .ok();
                return true;
            }
            if first != MAGIC {
                self.shared.protocol_errors.inc();
                self.free_conn(token);
                return true;
            }
            return true; // magic ok: wait for the 2 version bytes
        }
        if conn.sniff.len() < 6 {
            return true; // partial read; more next sweep
        }
        // full 6-byte hello: version check, then admission
        let version = u16::from_le_bytes(conn.sniff[4..6].try_into().unwrap());
        conn.state = ConnState::Active;
        if version != VERSION {
            self.shared.rejected.inc();
            self.queue_reject(
                token,
                HelloStatus::BadVersion,
                ErrorCode::Protocol,
                format!("server speaks protocol v{VERSION}, client sent v{version}"),
            );
            return true;
        }
        if self.shared.draining.load(Ordering::SeqCst) {
            self.shared.rejected.inc();
            self.queue_reject(
                token,
                HelloStatus::Draining,
                ErrorCode::Draining,
                "gateway is draining".into(),
            );
            return true;
        }
        // admission: compare-and-increment on the exported gauge itself,
        // so a connect burst cannot oversubscribe the cap
        if !self.shared.active.try_inc_below(self.shared.cfg.max_sessions as i64) {
            self.shared.rejected.inc();
            let max = self.shared.cfg.max_sessions;
            self.queue_reject(
                token,
                HelloStatus::Overloaded,
                ErrorCode::Overloaded,
                format!("gateway at capacity ({max} sessions)"),
            );
            return true;
        }
        let conn = self.conns[token].as_mut().unwrap();
        conn.admitted = true;
        // the pre-increment value is this session's 0-based admission
        // index — the `s{S}` coordinate of `drop@s{S}:f{N}` chaos events
        conn.session_idx = self.shared.accepted.inc();
        conn.chaos_drop = self.shared.cfg.chaos.session_drop(conn.session_idx);
        conn.write_buf.queue(&hello_bytes(HelloStatus::Ok));
        crate::log_debug!("gateway", "session {} open from {}", conn.session_idx, conn.peer);
        true
    }

    /// Queue a non-ok hello + one typed `Error` frame, then close once
    /// both are flushed (the refused peer reads the reason, as before).
    fn queue_reject(&mut self, token: usize, status: HelloStatus, code: ErrorCode, msg: String) {
        let conn = self.conns[token].as_mut().unwrap();
        conn.write_buf.queue(&hello_bytes(status));
        conn.write_buf.queue(&Frame::Error { id: 0, code, message: msg }.encode());
        conn.read_closed = true;
        conn.close_after_flush = true;
    }

    /// Active-state read: nonblocking read quantum → assembler → frame
    /// dispatch.  Returns progress.
    fn read_active(&mut self, token: usize) -> bool {
        let mut tmp = [0u8; 16 << 10];
        let mut total = 0;
        let mut progress = false;
        loop {
            let conn = self.conns[token].as_mut().unwrap();
            match conn.stream.read(&mut tmp) {
                Ok(0) => {
                    // clean close (or the drain-time read-shutdown):
                    // stop reading, still deliver every owed reply
                    conn.read_closed = true;
                    return true;
                }
                Ok(n) => {
                    progress = true;
                    total += n;
                    if conn.read_start_us == 0 {
                        conn.read_start_us = trace::now_us();
                    }
                    conn.assembler.push(&tmp[..n]);
                    conn.deadline = Instant::now() + self.shared.cfg.idle_timeout;
                    if !self.pump_frames(token) {
                        return true; // conn freed or closed
                    }
                    if total >= READ_QUANTUM {
                        return true; // fairness: next sweep continues
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return progress,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.free_conn(token);
                    return true;
                }
            }
        }
    }

    /// Dispatch every complete frame the assembler holds.  Returns
    /// false when the connection was freed or stopped reading.
    fn pump_frames(&mut self, token: usize) -> bool {
        loop {
            let conn = self.conns[token].as_mut().unwrap();
            let frame = match conn.assembler.next_frame() {
                Ok(Some(f)) => f,
                Ok(None) => {
                    // all buffered frames dispatched; the next read
                    // starts (or continues into) a fresh frame
                    conn.read_start_us = 0;
                    return true;
                }
                Err(msg) => {
                    // typed protocol error, then close: the frame
                    // boundary is unknown, resync is impossible
                    self.shared.protocol_errors.inc();
                    self.shared.frames_out.inc();
                    let err = Frame::Error { id: 0, code: ErrorCode::Protocol, message: msg };
                    conn.write_buf.queue(&err.encode());
                    conn.read_closed = true;
                    conn.close_after_flush = true;
                    return false;
                }
            };
            self.shared.frames_in.inc();
            conn.frames_read += 1;
            let frames_read = conn.frames_read;
            let chaos_drop = conn.chaos_drop;
            let peer_is_loopback = conn.peer_is_loopback;
            let gen = conn.gen;
            let read_start_us =
                if conn.read_start_us != 0 { conn.read_start_us } else { trace::now_us() };
            let route = ReplyRoute { handle: self.handle.clone(), token, gen };
            let mut sync = Vec::new();
            let out = handle_frame(
                frame,
                peer_is_loopback,
                &self.shared,
                &mut sync,
                &route,
                read_start_us,
            );
            let conn = self.conns[token].as_mut().unwrap();
            if out.submitted {
                conn.in_flight += 1;
            }
            for f in sync {
                self.shared.frames_out.inc();
                conn.write_buf.queue(&f.encode());
            }
            // injected connection drop: sever abruptly *after* the Nth
            // frame was accepted, exactly like a peer vanishing
            // mid-conversation (in-flight replies die with the socket)
            if chaos_drop == Some(frames_read) {
                crate::log_warn!("gateway", "chaos: dropping session after frame {frames_read}");
                self.free_conn(token);
                return false;
            }
            if !out.keep {
                let conn = self.conns[token].as_mut().unwrap();
                conn.read_closed = true;
                conn.close_after_flush = true;
                return false;
            }
        }
    }

    fn fire_timers(&mut self) -> bool {
        let now = Instant::now();
        let mut progress = false;
        for (token, gen) in self.wheel.expired(now) {
            let Some(conn) = self.conns.get_mut(token).and_then(|c| c.as_mut()) else {
                continue;
            };
            if conn.gen != gen {
                continue;
            }
            if now >= conn.deadline {
                crate::log_debug!("gateway", "session from {} timed out", conn.peer);
                self.free_conn(token);
                progress = true;
            } else {
                // activity moved the deadline since this entry was
                // scheduled: lazy wheel, re-arm at the live deadline
                let deadline = conn.deadline;
                self.wheel.schedule(token, gen, deadline);
            }
        }
        progress
    }

    /// Tear a connection down now (abrupt paths and post-flush closes
    /// both end here; the admission gauge slot is released exactly
    /// once).
    fn free_conn(&mut self, token: usize) {
        if let Some(conn) = self.conns[token].take() {
            conn.stream.shutdown(Shutdown::Both).ok();
            if !conn.traced_replies.is_empty() {
                // the socket died before the buffered reply flushed:
                // close the trace without a write_flush span
                let now = trace::now_us();
                for (id, _) in &conn.traced_replies {
                    self.shared.collector.complete(*id, now);
                }
            }
            if conn.admitted {
                self.shared.active.add(-1);
                crate::log_debug!("gateway", "session from {} closed", conn.peer);
            }
            self.live -= 1;
            self.free.push(token);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn socketpair_wakeup_roundtrip() {
        let (tx, rx) = socketpair().expect("socketpair");
        let wake = WakeHalf { stream: tx };
        rx.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        wake.wake();
        let mut buf = [0u8; 8];
        let n = (&rx).read(&mut buf).expect("wakeup byte");
        assert!(n >= 1);
        // a storm of wakeups never blocks the waker, even unread
        for _ in 0..100_000 {
            wake.wake();
        }
    }

    #[test]
    fn timer_wheel_fires_at_or_after_deadline_only() {
        let epoch = Instant::now();
        let mut wheel = TimerWheel::new(Duration::from_millis(640), epoch);
        let tick = wheel.tick;
        wheel.schedule(3, 7, epoch + 10 * tick);
        // well before the deadline: nothing fires
        assert!(wheel.expired(epoch + 5 * tick).is_empty());
        // after: the entry pops exactly once
        let fired = wheel.expired(epoch + 12 * tick);
        assert_eq!(fired, vec![(3, 7)]);
        assert!(wheel.expired(epoch + 20 * tick).is_empty());
    }

    #[test]
    fn timer_wheel_entries_beyond_one_rotation_still_fire() {
        let epoch = Instant::now();
        let mut wheel = TimerWheel::new(Duration::from_millis(640), epoch);
        let tick = wheel.tick;
        // 3 rotations out: lands in a slot that comes up early, but the
        // caller re-checks the live deadline and reschedules (lazy);
        // here we only assert it *does* surface by the deadline passing
        let far = 3 * WHEEL_SLOTS as u32 + 5;
        wheel.schedule(1, 1, epoch + far * tick);
        let fired = wheel.expired(epoch + (far + 2) * tick);
        assert!(fired.contains(&(1, 1)));
    }

    #[test]
    fn write_buf_tracks_pending_and_compacts() {
        let mut wb = WriteBuf::new();
        assert_eq!(wb.pending(), 0);
        wb.queue(&[1, 2, 3]);
        wb.queue(&[4]);
        assert_eq!(wb.pending(), 4);
    }
}
