//! The gateway wire protocol: a versioned, length-prefixed, checksummed
//! binary framing over TCP — std-only, like the rest of the crate (the
//! image vendors no serde/tokio, so the codec is hand-rolled and small).
//!
//! **Handshake.**  The client opens with `MAGIC` + `VERSION` (little
//! endian, like every integer on the wire); the server replies `MAGIC` +
//! `VERSION` + one `HelloStatus` byte.  A non-`Ok` status (overloaded,
//! version mismatch, draining) is followed by one typed `Error` frame
//! carrying the human-readable reason, then the server closes — so a
//! rejected client learns *why* without guessing from a dropped socket.
//!
//! **Frames.**  After the handshake both directions speak frames:
//!
//! ```text
//! [u32 body_len] [body: u8 kind, u64 request_id, payload...] [u32 fnv1a(body)]
//! ```
//!
//! `body_len` counts the body only (kind + id + payload) and is bounded
//! by `MAX_FRAME_LEN`; an oversized or malformed frame earns an `Error`
//! frame with `ErrorCode::Protocol` and the session closes (framing
//! cannot be resynchronized after a bad length).  The request id is
//! chosen by the client and echoed verbatim in the reply — that is the
//! whole correlation story, which is what makes per-session pipelining
//! safe.  Ids are per-session; sessions cannot see each other's frames.
//!
//! Request kinds: `Ping`, `Infer { model, deadline_ms, batch }`,
//! `LoadModel`, `UnloadModel`, `Stats`, `Shutdown` (admin: ask the
//! server to drain and exit), `Traces` (the slowest-request trace
//! block), `TraceSpans` (the sampled span-tree summary).  Reply kinds:
//! `Pong`, `InferOk { logits, faults, worker }`, `Error { code,
//! message }`, `StatsReport { text }`, `Ack { info }`, `TracesReport
//! { text }`, `TraceSpansReport { text }`.  `Traces`/`TracesReport` and
//! `TraceSpans`/`TraceSpansReport` are additive kind pairs: a v2 peer
//! that has never heard of them simply never sends them, so the version
//! stays 2.
//!
//! **Trace context.**  `Infer` and `InferOk` carry an *optional trailing*
//! `trace_id: u64`: encoded only when nonzero, decoded as 0 when the
//! body ends before it.  A pre-tracing v2 peer therefore interoperates
//! in both directions, and an unsampled request's frames are
//! byte-identical to the pre-tracing encoding.  A nonzero id asks the
//! server to record a span tree for this request and is echoed in the
//! reply so the client can join its observed latency with the
//! server-side spans (see `util::trace`).
//!
//! **Version 2** adds `deadline_ms` to `Infer` (0 = use the server
//! default) and a `token` string to the admin frames (`LoadModel`,
//! `UnloadModel`, `Shutdown`; empty = none).  When `serve.admin_token`
//! is configured the gateway requires the matching token on every admin
//! frame from any peer; when it is not, the pre-v2 loopback-only rule
//! stands.  The token is a shared secret over a trusted transport, not
//! cryptographic authentication.

use std::io::Read;

use crate::nn::models::Batch;
use crate::tensor::Nhwc;

/// Protocol magic: first bytes of every connection in either direction.
/// Four bytes on purpose — the gateway sniffs the same prefix to tell a
/// binary session from an HTTP/1.1 `GET /metrics` scrape (`b"GET "`).
pub const MAGIC: [u8; 4] = *b"RNSG";

/// Wire protocol version; bumped on any incompatible frame change.
/// v2: `Infer.deadline_ms` + admin-frame `token` (PR 6).
pub const VERSION: u16 = 2;

/// Upper bound on one frame's body (kind + id + payload).  16 MiB holds
/// a ~2000-sample MNIST batch; anything larger is a protocol error, not
/// an allocation attempt.
pub const MAX_FRAME_LEN: usize = 16 << 20;

/// Upper bound on a model-name string.
pub const MAX_NAME_LEN: usize = 256;

/// Minimum body: kind (1) + request id (8).
const MIN_FRAME_LEN: usize = 9;

/// Server hello status byte (follows MAGIC + VERSION in the reply).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HelloStatus {
    Ok,
    /// Admission control: `serve.max_sessions` live sessions already.
    Overloaded,
    /// Client and server disagree on `VERSION`.
    BadVersion,
    /// The gateway is draining for shutdown; no new sessions.
    Draining,
}

impl HelloStatus {
    pub fn to_byte(self) -> u8 {
        match self {
            HelloStatus::Ok => 0,
            HelloStatus::Overloaded => 1,
            HelloStatus::BadVersion => 2,
            HelloStatus::Draining => 3,
        }
    }

    pub fn from_byte(b: u8) -> Option<Self> {
        match b {
            0 => Some(HelloStatus::Ok),
            1 => Some(HelloStatus::Overloaded),
            2 => Some(HelloStatus::BadVersion),
            3 => Some(HelloStatus::Draining),
            _ => None,
        }
    }
}

/// Typed error codes carried by `Frame::Error`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed/oversized/checksum-failed frame; the session closes.
    Protocol,
    /// Admission reject: the session cap is reached.
    Overloaded,
    /// Model load/unload/inference failure (name unknown, load failed).
    Model,
    /// Coordinator-side failure while serving the request.
    Internal,
    /// The gateway is draining; the request was not accepted.
    Draining,
    /// Admin frame (load/unload/shutdown) without valid authorization:
    /// bad/missing token when one is configured, or a non-loopback peer
    /// under the loopback-only fallback.
    Unauthorized,
    /// The request's deadline passed before a result was produced.
    DeadlineExceeded,
    /// The request's batch crashed workers repeatedly and was
    /// quarantined; do not retry the same input.
    Poisoned,
}

impl ErrorCode {
    fn to_u16(self) -> u16 {
        match self {
            ErrorCode::Protocol => 1,
            ErrorCode::Overloaded => 2,
            ErrorCode::Model => 3,
            ErrorCode::Internal => 4,
            ErrorCode::Draining => 5,
            ErrorCode::Unauthorized => 6,
            ErrorCode::DeadlineExceeded => 7,
            ErrorCode::Poisoned => 8,
        }
    }

    fn from_u16(v: u16) -> Option<Self> {
        match v {
            1 => Some(ErrorCode::Protocol),
            2 => Some(ErrorCode::Overloaded),
            3 => Some(ErrorCode::Model),
            4 => Some(ErrorCode::Internal),
            5 => Some(ErrorCode::Draining),
            6 => Some(ErrorCode::Unauthorized),
            7 => Some(ErrorCode::DeadlineExceeded),
            8 => Some(ErrorCode::Poisoned),
            _ => None,
        }
    }

    /// Is a retry of the *same* request ever useful?  Drives the client
    /// retry policy (see the README failure-modes table): transient
    /// conditions may clear; the rest are permanent for this request.
    pub fn is_retryable(self) -> bool {
        matches!(self, ErrorCode::Overloaded | ErrorCode::Internal)
    }
}

/// A model input crossing the wire; mirrors `nn::models::Batch`.
#[derive(Clone, Debug, PartialEq)]
pub enum WireBatch {
    Images { n: u32, h: u32, w: u32, c: u32, data: Vec<f32> },
    Tokens { batch: u32, seq: u32, tokens: Vec<i64> },
}

impl WireBatch {
    pub fn from_batch(batch: &Batch) -> Self {
        match batch {
            Batch::Images(t) => WireBatch::Images {
                n: t.n as u32,
                h: t.h as u32,
                w: t.w as u32,
                c: t.c as u32,
                data: t.data.clone(),
            },
            Batch::Tokens { tokens, batch, seq } => WireBatch::Tokens {
                batch: *batch as u32,
                seq: *seq as u32,
                tokens: tokens.clone(),
            },
        }
    }

    /// Convert to a coordinator `Batch`, validating declared shapes
    /// against the payload length (a mismatch is a protocol error).
    /// Every dimension must be nonzero and the element count is computed
    /// with checked multiplication — a hostile frame must not be able to
    /// wrap the product to `data.len()` in release builds and smuggle a
    /// lying shape past this check into a worker thread.
    pub fn into_batch(self) -> Result<Batch, String> {
        match self {
            WireBatch::Images { n, h, w, c, data } => {
                if n == 0 || h == 0 || w == 0 || c == 0 {
                    return Err(format!("image batch shape {n}x{h}x{w}x{c} has a zero dimension"));
                }
                let want = (n as usize)
                    .checked_mul(h as usize)
                    .and_then(|v| v.checked_mul(w as usize))
                    .and_then(|v| v.checked_mul(c as usize))
                    .ok_or_else(|| format!("image batch shape {n}x{h}x{w}x{c} overflows"))?;
                if want != data.len() {
                    return Err(format!(
                        "image batch shape {n}x{h}x{w}x{c} wants {want} f32s, got {}",
                        data.len()
                    ));
                }
                Ok(Batch::Images(Nhwc::from_vec(n as usize, h as usize, w as usize, c as usize, data)))
            }
            WireBatch::Tokens { batch, seq, tokens } => {
                if batch == 0 || seq == 0 {
                    return Err(format!("token batch {batch}x{seq} has a zero dimension"));
                }
                let want = (batch as usize)
                    .checked_mul(seq as usize)
                    .ok_or_else(|| format!("token batch {batch}x{seq} overflows"))?;
                if want != tokens.len() {
                    return Err(format!(
                        "token batch {batch}x{seq} wants {want} tokens, got {}",
                        tokens.len()
                    ));
                }
                Ok(Batch::Tokens { tokens, batch: batch as usize, seq: seq as usize })
            }
        }
    }
}

/// One protocol frame (either direction).  `id` is the client-chosen
/// request id, echoed in the matching reply.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    // requests
    Ping { id: u64 },
    /// `deadline_ms` = this request's completion budget from gateway
    /// receipt; 0 = use the server default (which may be unlimited).
    /// `trace_id` nonzero = the client requests span sampling for this
    /// request (optional trailing field; 0 = not encoded).
    Infer { id: u64, model: String, deadline_ms: u32, input: WireBatch, trace_id: u64 },
    /// Admin frames carry a shared-secret `token` (empty = none); see
    /// the module docs for the authorization rule.
    LoadModel { id: u64, model: String, token: String },
    UnloadModel { id: u64, model: String, token: String },
    Stats { id: u64 },
    Shutdown { id: u64, token: String },
    /// The slowest-request trace block (per-stage timing breakdowns).
    Traces { id: u64 },
    /// The sampled span-tree summary (`util::trace` collector text).
    TraceSpans { id: u64 },
    // replies
    Pong { id: u64 },
    /// `trace_id` echoes the request's effective trace id (0 = this
    /// request was not sampled; optional trailing field like `Infer`'s).
    InferOk {
        id: u64,
        rows: u32,
        cols: u32,
        logits: Vec<f32>,
        faults_detected: u64,
        worker: u32,
        trace_id: u64,
    },
    Error { id: u64, code: ErrorCode, message: String },
    StatsReport { id: u64, text: String },
    Ack { id: u64, info: String },
    TracesReport { id: u64, text: String },
    TraceSpansReport { id: u64, text: String },
}

const KIND_PING: u8 = 1;
const KIND_INFER: u8 = 2;
const KIND_LOAD: u8 = 3;
const KIND_UNLOAD: u8 = 4;
const KIND_STATS: u8 = 5;
const KIND_SHUTDOWN: u8 = 6;
const KIND_TRACES: u8 = 7;
const KIND_TRACE_SPANS: u8 = 8;
const KIND_PONG: u8 = 129;
const KIND_INFER_OK: u8 = 130;
const KIND_ERROR: u8 = 131;
const KIND_STATS_REPORT: u8 = 132;
const KIND_ACK: u8 = 133;
const KIND_TRACES_REPORT: u8 = 134;
const KIND_TRACE_SPANS_REPORT: u8 = 135;

const BATCH_IMAGES: u8 = 0;
const BATCH_TOKENS: u8 = 1;

/// Wire-level failure reading a frame.
#[derive(Debug)]
pub enum WireError {
    /// Clean close at a frame boundary (EOF before any length byte).
    Eof,
    /// Socket-level failure (includes read timeouts and resets).
    Io(std::io::Error),
    /// Malformed frame: bad length, bad checksum, truncated payload,
    /// unknown kind.  The session cannot resynchronize after this.
    Protocol(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Eof => write!(f, "connection closed"),
            WireError::Io(e) => write!(f, "io error: {e}"),
            WireError::Protocol(m) => write!(f, "protocol error: {m}"),
        }
    }
}

/// FNV-1a over the frame body — cheap, dependency-free corruption check
/// (this is an integrity checksum, not an authenticator).
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

// --- encoding -------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= MAX_NAME_LEN);
    put_u16(out, s.len() as u16);
    out.extend_from_slice(s.as_bytes());
}

fn put_text(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i64s(out: &mut Vec<u8>, xs: &[i64]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_batch(out: &mut Vec<u8>, b: &WireBatch) {
    match b {
        WireBatch::Images { n, h, w, c, data } => {
            out.push(BATCH_IMAGES);
            put_u32(out, *n);
            put_u32(out, *h);
            put_u32(out, *w);
            put_u32(out, *c);
            put_f32s(out, data);
        }
        WireBatch::Tokens { batch, seq, tokens } => {
            out.push(BATCH_TOKENS);
            put_u32(out, *batch);
            put_u32(out, *seq);
            put_i64s(out, tokens);
        }
    }
}

impl Frame {
    pub fn id(&self) -> u64 {
        match self {
            Frame::Ping { id }
            | Frame::Infer { id, .. }
            | Frame::LoadModel { id, .. }
            | Frame::UnloadModel { id, .. }
            | Frame::Stats { id }
            | Frame::Shutdown { id }
            | Frame::Traces { id }
            | Frame::TraceSpans { id }
            | Frame::Pong { id }
            | Frame::InferOk { id, .. }
            | Frame::Error { id, .. }
            | Frame::StatsReport { id, .. }
            | Frame::Ack { id, .. }
            | Frame::TracesReport { id, .. }
            | Frame::TraceSpansReport { id, .. } => *id,
        }
    }

    /// Serialize to full wire bytes: length prefix + body + checksum.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::with_capacity(32);
        match self {
            Frame::Ping { id } => {
                body.push(KIND_PING);
                put_u64(&mut body, *id);
            }
            Frame::Infer { id, model, deadline_ms, input, trace_id } => {
                body.push(KIND_INFER);
                put_u64(&mut body, *id);
                put_str(&mut body, model);
                put_u32(&mut body, *deadline_ms);
                put_batch(&mut body, input);
                // optional trailing trace context: an unsampled request
                // stays byte-identical to the pre-tracing encoding
                if *trace_id != 0 {
                    put_u64(&mut body, *trace_id);
                }
            }
            Frame::LoadModel { id, model, token } => {
                body.push(KIND_LOAD);
                put_u64(&mut body, *id);
                put_str(&mut body, model);
                put_str(&mut body, token);
            }
            Frame::UnloadModel { id, model, token } => {
                body.push(KIND_UNLOAD);
                put_u64(&mut body, *id);
                put_str(&mut body, model);
                put_str(&mut body, token);
            }
            Frame::Stats { id } => {
                body.push(KIND_STATS);
                put_u64(&mut body, *id);
            }
            Frame::Traces { id } => {
                body.push(KIND_TRACES);
                put_u64(&mut body, *id);
            }
            Frame::TraceSpans { id } => {
                body.push(KIND_TRACE_SPANS);
                put_u64(&mut body, *id);
            }
            Frame::Shutdown { id, token } => {
                body.push(KIND_SHUTDOWN);
                put_u64(&mut body, *id);
                put_str(&mut body, token);
            }
            Frame::Pong { id } => {
                body.push(KIND_PONG);
                put_u64(&mut body, *id);
            }
            Frame::InferOk { id, rows, cols, logits, faults_detected, worker, trace_id } => {
                body.push(KIND_INFER_OK);
                put_u64(&mut body, *id);
                put_u32(&mut body, *rows);
                put_u32(&mut body, *cols);
                put_u64(&mut body, *faults_detected);
                put_u32(&mut body, *worker);
                put_f32s(&mut body, logits);
                if *trace_id != 0 {
                    put_u64(&mut body, *trace_id);
                }
            }
            Frame::Error { id, code, message } => {
                body.push(KIND_ERROR);
                put_u64(&mut body, *id);
                put_u16(&mut body, code.to_u16());
                put_text(&mut body, message);
            }
            Frame::StatsReport { id, text } => {
                body.push(KIND_STATS_REPORT);
                put_u64(&mut body, *id);
                put_text(&mut body, text);
            }
            Frame::Ack { id, info } => {
                body.push(KIND_ACK);
                put_u64(&mut body, *id);
                put_text(&mut body, info);
            }
            Frame::TracesReport { id, text } => {
                body.push(KIND_TRACES_REPORT);
                put_u64(&mut body, *id);
                put_text(&mut body, text);
            }
            Frame::TraceSpansReport { id, text } => {
                body.push(KIND_TRACE_SPANS_REPORT);
                put_u64(&mut body, *id);
                put_text(&mut body, text);
            }
        }
        assert!(body.len() <= MAX_FRAME_LEN, "frame exceeds MAX_FRAME_LEN");
        let mut out = Vec::with_capacity(body.len() + 8);
        put_u32(&mut out, body.len() as u32);
        let sum = checksum(&body);
        out.extend_from_slice(&body);
        put_u32(&mut out, sum);
        out
    }

    /// Read one frame from `r`.  Distinguishes a clean close at a frame
    /// boundary (`Eof`) from mid-frame truncation (`Io`) and malformed
    /// contents (`Protocol`).
    pub fn read_from(r: &mut impl Read) -> Result<Frame, WireError> {
        let mut len_buf = [0u8; 4];
        // first byte by hand so a close between frames is a clean Eof,
        // not an UnexpectedEof error
        match r.read(&mut len_buf[..1]) {
            Ok(0) => return Err(WireError::Eof),
            Ok(_) => {}
            Err(e) => return Err(WireError::Io(e)),
        }
        r.read_exact(&mut len_buf[1..]).map_err(WireError::Io)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > MAX_FRAME_LEN {
            return Err(WireError::Protocol(format!(
                "frame body {len} bytes exceeds the {MAX_FRAME_LEN}-byte bound"
            )));
        }
        if len < MIN_FRAME_LEN {
            return Err(WireError::Protocol(format!("frame body {len} bytes is too short")));
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body).map_err(WireError::Io)?;
        let mut sum_buf = [0u8; 4];
        r.read_exact(&mut sum_buf).map_err(WireError::Io)?;
        let want = u32::from_le_bytes(sum_buf);
        let got = checksum(&body);
        if want != got {
            return Err(WireError::Protocol(format!(
                "checksum mismatch (got {got:#010x}, frame says {want:#010x})"
            )));
        }
        Frame::decode_body(&body).map_err(WireError::Protocol)
    }

    fn decode_body(body: &[u8]) -> Result<Frame, String> {
        let mut cur = Cursor { buf: body, pos: 0 };
        let kind = cur.u8()?;
        let id = cur.u64()?;
        let frame = match kind {
            KIND_PING => Frame::Ping { id },
            KIND_INFER => {
                let model = cur.name()?;
                let deadline_ms = cur.u32()?;
                let input = cur.batch()?;
                let trace_id = cur.trailing_u64()?;
                Frame::Infer { id, model, deadline_ms, input, trace_id }
            }
            KIND_LOAD => Frame::LoadModel { id, model: cur.name()?, token: cur.name()? },
            KIND_UNLOAD => Frame::UnloadModel { id, model: cur.name()?, token: cur.name()? },
            KIND_STATS => Frame::Stats { id },
            KIND_SHUTDOWN => Frame::Shutdown { id, token: cur.name()? },
            KIND_TRACES => Frame::Traces { id },
            KIND_TRACE_SPANS => Frame::TraceSpans { id },
            KIND_PONG => Frame::Pong { id },
            KIND_INFER_OK => {
                let rows = cur.u32()?;
                let cols = cur.u32()?;
                let faults_detected = cur.u64()?;
                let worker = cur.u32()?;
                let logits = cur.f32s()?;
                if (rows as usize) * (cols as usize) != logits.len() {
                    return Err(format!(
                        "InferOk {rows}x{cols} wants {} f32s, got {}",
                        (rows as usize) * (cols as usize),
                        logits.len()
                    ));
                }
                let trace_id = cur.trailing_u64()?;
                Frame::InferOk { id, rows, cols, logits, faults_detected, worker, trace_id }
            }
            KIND_ERROR => {
                let code_raw = cur.u16()?;
                let code = ErrorCode::from_u16(code_raw)
                    .ok_or_else(|| format!("unknown error code {code_raw}"))?;
                let message = cur.text()?;
                Frame::Error { id, code, message }
            }
            KIND_STATS_REPORT => Frame::StatsReport { id, text: cur.text()? },
            KIND_ACK => Frame::Ack { id, info: cur.text()? },
            KIND_TRACES_REPORT => Frame::TracesReport { id, text: cur.text()? },
            KIND_TRACE_SPANS_REPORT => Frame::TraceSpansReport { id, text: cur.text()? },
            other => return Err(format!("unknown frame kind {other}")),
        };
        cur.done()?;
        Ok(frame)
    }
}

/// Bounds-checked little-endian reader over a frame body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "truncated frame: wanted {n} bytes at offset {}, body is {}",
                self.pos,
                self.buf.len()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Optional trailing `u64`: 0 when the body ends here (a frame from
    /// an encoder that predates the field), otherwise the decoded value.
    /// A partial trailer is still a truncation error via `take`.
    fn trailing_u64(&mut self) -> Result<u64, String> {
        if self.pos == self.buf.len() {
            return Ok(0);
        }
        self.u64()
    }

    fn name(&mut self) -> Result<String, String> {
        let len = self.u16()? as usize;
        if len > MAX_NAME_LEN {
            return Err(format!("name length {len} exceeds {MAX_NAME_LEN}"));
        }
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| "name is not utf-8".to_string())
    }

    fn text(&mut self) -> Result<String, String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?; // bounded by body length (<= MAX_FRAME_LEN)
        String::from_utf8(bytes.to_vec()).map_err(|_| "text is not utf-8".to_string())
    }

    fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(4).ok_or("f32 count overflow")?)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn i64s(&mut self) -> Result<Vec<i64>, String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(8).ok_or("i64 count overflow")?)?;
        Ok(bytes.chunks_exact(8).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn batch(&mut self) -> Result<WireBatch, String> {
        match self.u8()? {
            BATCH_IMAGES => {
                let n = self.u32()?;
                let h = self.u32()?;
                let w = self.u32()?;
                let c = self.u32()?;
                let data = self.f32s()?;
                Ok(WireBatch::Images { n, h, w, c, data })
            }
            BATCH_TOKENS => {
                let batch = self.u32()?;
                let seq = self.u32()?;
                let tokens = self.i64s()?;
                Ok(WireBatch::Tokens { batch, seq, tokens })
            }
            other => Err(format!("unknown batch tag {other}")),
        }
    }

    fn done(&self) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!(
                "{} trailing bytes after the frame payload",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

/// Incremental frame decoder for nonblocking reads.
///
/// `Frame::read_from` owns a blocking stream and can loop on
/// `read_exact`; a readiness loop cannot — bytes arrive in whatever
/// chunks the kernel hands over, so a frame may be split across any
/// number of reads or several frames may land coalesced in one.  The
/// assembler buffers pushed bytes and yields complete frames as they
/// become decodable, enforcing exactly the bounds and checksum rules of
/// `read_from` (same error messages, so both paths report identically).
///
/// A protocol error is terminal for the stream: the frame boundary is
/// unknown and resync is impossible, so callers must close (the same
/// rule the blocking reader applies).
#[derive(Debug, Default)]
pub struct FrameAssembler {
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (drained lazily to amortize the memmove).
    pos: usize,
}

impl FrameAssembler {
    pub fn new() -> FrameAssembler {
        FrameAssembler { buf: Vec::new(), pos: 0 }
    }

    /// Append freshly-read bytes; call `next_frame` until it returns
    /// `Ok(None)` to drain every frame they complete.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a decoded frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Decode the next complete frame, if the buffer holds one.
    /// `Ok(None)` means "need more bytes"; `Err` is a protocol error
    /// (bad length, checksum mismatch, malformed body).
    pub fn next_frame(&mut self) -> Result<Option<Frame>, String> {
        let avail = &self.buf[self.pos..];
        if avail.len() < 4 {
            self.compact();
            return Ok(None);
        }
        let len = u32::from_le_bytes(avail[..4].try_into().unwrap()) as usize;
        if len > MAX_FRAME_LEN {
            return Err(format!("frame body {len} bytes exceeds the {MAX_FRAME_LEN}-byte bound"));
        }
        if len < MIN_FRAME_LEN {
            return Err(format!("frame body {len} bytes is too short"));
        }
        if avail.len() < 4 + len + 4 {
            self.compact();
            return Ok(None);
        }
        let body = &avail[4..4 + len];
        let want = u32::from_le_bytes(avail[4 + len..4 + len + 4].try_into().unwrap());
        let got = checksum(body);
        if want != got {
            return Err(format!("checksum mismatch (got {got:#010x}, frame says {want:#010x})"));
        }
        let frame = Frame::decode_body(body)?;
        self.pos += 4 + len + 4;
        self.compact();
        Ok(Some(frame))
    }

    /// Reclaim the consumed prefix once it dominates the buffer (or the
    /// buffer is fully drained, which makes the drain free).
    fn compact(&mut self) {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > 4096 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let bytes = f.encode();
        let got = Frame::read_from(&mut &bytes[..]).expect("decode");
        assert_eq!(got, f);
    }

    #[test]
    fn every_frame_kind_roundtrips() {
        roundtrip(Frame::Ping { id: 7 });
        roundtrip(Frame::Pong { id: 7 });
        roundtrip(Frame::Stats { id: 1 });
        roundtrip(Frame::Shutdown { id: 2, token: String::new() });
        roundtrip(Frame::Shutdown { id: 2, token: "hunter2".into() });
        roundtrip(Frame::LoadModel { id: 3, model: "mlp".into(), token: String::new() });
        roundtrip(Frame::UnloadModel { id: 4, model: "bert".into(), token: "sekrit".into() });
        roundtrip(Frame::Infer {
            id: 5,
            model: "synthetic-mlp".into(),
            deadline_ms: 0,
            input: WireBatch::Images { n: 1, h: 2, w: 2, c: 1, data: vec![0.5, -1.0, 0.0, 2.5] },
            trace_id: 0,
        });
        roundtrip(Frame::Infer {
            id: 6,
            model: "bert".into(),
            deadline_ms: 1500,
            input: WireBatch::Tokens { batch: 2, seq: 3, tokens: vec![1, 2, 3, 4, 5, 6] },
            trace_id: 0xDEAD_BEEF_0101,
        });
        roundtrip(Frame::InferOk {
            id: 9,
            rows: 1,
            cols: 3,
            logits: vec![1.0, -2.0, 3.5],
            faults_detected: 11,
            worker: 2,
            trace_id: 0,
        });
        roundtrip(Frame::InferOk {
            id: 9,
            rows: 1,
            cols: 1,
            logits: vec![4.0],
            faults_detected: 0,
            worker: 0,
            trace_id: 0x1234_5678_9ABC_DEF1,
        });
        roundtrip(Frame::Error { id: 10, code: ErrorCode::Overloaded, message: "full".into() });
        roundtrip(Frame::Error { id: 13, code: ErrorCode::Unauthorized, message: "admin".into() });
        roundtrip(Frame::Error {
            id: 14,
            code: ErrorCode::DeadlineExceeded,
            message: "too late".into(),
        });
        roundtrip(Frame::Error { id: 15, code: ErrorCode::Poisoned, message: "quarantined".into() });
        roundtrip(Frame::StatsReport { id: 11, text: "requests=1\n".into() });
        roundtrip(Frame::Ack { id: 12, info: "unloaded".into() });
        roundtrip(Frame::Traces { id: 16 });
        roundtrip(Frame::TracesReport { id: 16, text: "slow traces: kept=0 cap=16".into() });
        roundtrip(Frame::TraceSpans { id: 17 });
        roundtrip(Frame::TraceSpansReport { id: 17, text: "trace spans: kept=0 cap=16".into() });
    }

    #[test]
    fn trace_id_is_an_optional_trailing_field() {
        // a zero trace id is not encoded: the wire bytes are identical
        // to the pre-tracing encoding (hand-built legacy body below)
        let infer = Frame::Infer {
            id: 5,
            model: "mlp".into(),
            deadline_ms: 250,
            input: WireBatch::Images { n: 1, h: 1, w: 2, c: 1, data: vec![0.25, 0.75] },
            trace_id: 0,
        };
        let mut legacy_body = vec![KIND_INFER];
        legacy_body.extend_from_slice(&5u64.to_le_bytes());
        legacy_body.extend_from_slice(&3u16.to_le_bytes());
        legacy_body.extend_from_slice(b"mlp");
        legacy_body.extend_from_slice(&250u32.to_le_bytes());
        legacy_body.push(BATCH_IMAGES);
        for dim in [1u32, 1, 2, 1] {
            legacy_body.extend_from_slice(&dim.to_le_bytes());
        }
        legacy_body.extend_from_slice(&2u32.to_le_bytes());
        legacy_body.extend_from_slice(&0.25f32.to_le_bytes());
        legacy_body.extend_from_slice(&0.75f32.to_le_bytes());
        let mut legacy_wire = (legacy_body.len() as u32).to_le_bytes().to_vec();
        let sum = checksum(&legacy_body);
        legacy_wire.extend_from_slice(&legacy_body);
        legacy_wire.extend_from_slice(&sum.to_le_bytes());
        assert_eq!(infer.encode(), legacy_wire, "trace_id=0 must not change the wire bytes");
        // a legacy frame (no trailing field) decodes with trace_id = 0
        assert_eq!(Frame::read_from(&mut &legacy_wire[..]).expect("legacy decode"), infer);
        // and a sampled frame costs exactly 8 more body bytes
        let sampled = Frame::Infer {
            id: 5,
            model: "mlp".into(),
            deadline_ms: 250,
            input: WireBatch::Images { n: 1, h: 1, w: 2, c: 1, data: vec![0.25, 0.75] },
            trace_id: 42,
        };
        assert_eq!(sampled.encode().len(), infer.encode().len() + 8);
        // a partial trailer is a truncation error, not a silent zero
        let mut body = legacy_body.clone();
        body.extend_from_slice(&[1, 2, 3]); // 3 of 8 trailing bytes
        assert!(Frame::decode_body(&body).unwrap_err().contains("truncated"));
    }

    #[test]
    fn retryability_follows_the_failure_modes_table() {
        assert!(ErrorCode::Overloaded.is_retryable());
        assert!(ErrorCode::Internal.is_retryable());
        for permanent in [
            ErrorCode::Protocol,
            ErrorCode::Model,
            ErrorCode::Draining,
            ErrorCode::Unauthorized,
            ErrorCode::DeadlineExceeded,
            ErrorCode::Poisoned,
        ] {
            assert!(!permanent.is_retryable(), "{permanent:?}");
        }
    }

    #[test]
    fn checksum_corruption_is_a_protocol_error() {
        let mut bytes = Frame::Ping { id: 1 }.encode();
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF; // flip checksum
        match Frame::read_from(&mut &bytes[..]) {
            Err(WireError::Protocol(m)) => assert!(m.contains("checksum"), "{m}"),
            other => panic!("expected checksum error, got {other:?}"),
        }
        let mut bytes = Frame::Ping { id: 1 }.encode();
        bytes[5] ^= 0x01; // flip a body byte, checksum untouched
        assert!(matches!(Frame::read_from(&mut &bytes[..]), Err(WireError::Protocol(_))));
    }

    #[test]
    fn oversized_and_undersized_lengths_are_rejected() {
        let mut bytes = vec![];
        bytes.extend_from_slice(&((MAX_FRAME_LEN as u32) + 1).to_le_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        assert!(matches!(Frame::read_from(&mut &bytes[..]), Err(WireError::Protocol(_))));
        let mut bytes = vec![];
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        assert!(matches!(Frame::read_from(&mut &bytes[..]), Err(WireError::Protocol(_))));
    }

    #[test]
    fn truncation_and_clean_close_are_distinguished() {
        let mut empty: &[u8] = &[];
        assert!(matches!(Frame::read_from(&mut empty), Err(WireError::Eof)));
        let bytes = Frame::Ping { id: 3 }.encode();
        let cut = &bytes[..bytes.len() - 2];
        assert!(matches!(Frame::read_from(&mut &cut[..]), Err(WireError::Io(_))));
    }

    #[test]
    fn unknown_kind_and_trailing_bytes_are_protocol_errors() {
        // hand-build a frame with kind 99
        let mut body = vec![99u8];
        body.extend_from_slice(&1u64.to_le_bytes());
        let mut bytes = vec![];
        bytes.extend_from_slice(&(body.len() as u32).to_le_bytes());
        let sum = checksum(&body);
        bytes.extend_from_slice(&body);
        bytes.extend_from_slice(&sum.to_le_bytes());
        match Frame::read_from(&mut &bytes[..]) {
            Err(WireError::Protocol(m)) => assert!(m.contains("unknown frame kind"), "{m}"),
            other => panic!("{other:?}"),
        }
        // a valid Ping with junk appended inside the body
        let mut body = vec![KIND_PING];
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(0xAA);
        let mut bytes = vec![];
        bytes.extend_from_slice(&(body.len() as u32).to_le_bytes());
        let sum = checksum(&body);
        bytes.extend_from_slice(&body);
        bytes.extend_from_slice(&sum.to_le_bytes());
        match Frame::read_from(&mut &bytes[..]) {
            Err(WireError::Protocol(m)) => assert!(m.contains("trailing"), "{m}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wire_batch_shape_validation() {
        let bad = WireBatch::Images { n: 2, h: 2, w: 2, c: 1, data: vec![0.0; 7] };
        assert!(bad.into_batch().is_err());
        let bad = WireBatch::Tokens { batch: 2, seq: 4, tokens: vec![0; 7] };
        assert!(bad.into_batch().is_err());
        // zero dimensions and wrapping products must be rejected, not
        // smuggled past the length check into a worker thread
        let bad = WireBatch::Images { n: 1, h: 0, w: 0, c: 0, data: vec![] };
        assert!(bad.into_batch().unwrap_err().contains("zero dimension"));
        let bad = WireBatch::Tokens { batch: 3, seq: 0, tokens: vec![] };
        assert!(bad.into_batch().unwrap_err().contains("zero dimension"));
        // 2^16 in every dim wraps to 0 under u32/usize-32 wrapping mul;
        // with checked math it is an overflow (or a length mismatch on
        // 64-bit, where the true product exceeds any real payload)
        let bad = WireBatch::Images { n: 65536, h: 65536, w: 65536, c: 65536, data: vec![] };
        assert!(bad.into_batch().is_err());
        let ok = WireBatch::Images { n: 1, h: 2, w: 2, c: 1, data: vec![0.0; 4] };
        match ok.into_batch().unwrap() {
            Batch::Images(t) => assert_eq!((t.n, t.h, t.w, t.c), (1, 2, 2, 1)),
            _ => panic!(),
        }
        let b = Batch::Tokens { tokens: vec![1, 2], batch: 1, seq: 2 };
        assert_eq!(WireBatch::from_batch(&b).into_batch().unwrap().len(), 1);
    }

    /// A mixed bag of frames covering every payload shape the assembler
    /// has to reslice (names, text, f32 payloads, batches).
    fn assembler_fixture() -> Vec<Frame> {
        vec![
            Frame::Ping { id: 1 },
            Frame::Infer {
                id: 2,
                model: "synthetic-mlp".into(),
                deadline_ms: 250,
                input: WireBatch::Images { n: 1, h: 2, w: 2, c: 1, data: vec![0.5; 4] },
                trace_id: 0x51,
            },
            Frame::InferOk {
                id: 2,
                rows: 1,
                cols: 3,
                logits: vec![0.1, -0.2, 0.3],
                faults_detected: 4,
                worker: 1,
                trace_id: 0,
            },
            Frame::Error { id: 3, code: ErrorCode::Overloaded, message: "busy".into() },
            Frame::StatsReport { id: 4, text: "requests=9\n".into() },
        ]
    }

    #[test]
    fn assembler_handles_one_byte_at_a_time() {
        let frames = assembler_fixture();
        let mut wire = Vec::new();
        for f in &frames {
            wire.extend_from_slice(&f.encode());
        }
        let mut asm = FrameAssembler::new();
        let mut got = Vec::new();
        for &b in &wire {
            asm.push(&[b]);
            while let Some(f) = asm.next_frame().expect("clean stream") {
                got.push(f);
            }
        }
        assert_eq!(got, frames);
        assert_eq!(asm.buffered(), 0, "nothing left over");
    }

    #[test]
    fn assembler_handles_coalesced_frames_in_one_push() {
        let frames = assembler_fixture();
        let mut wire = Vec::new();
        for f in &frames {
            wire.extend_from_slice(&f.encode());
        }
        let mut asm = FrameAssembler::new();
        asm.push(&wire);
        let mut got = Vec::new();
        while let Some(f) = asm.next_frame().expect("clean stream") {
            got.push(f);
        }
        assert_eq!(got, frames);
        assert_eq!(asm.buffered(), 0);
    }

    #[test]
    fn assembler_handles_every_split_point() {
        // two frames, cut into (prefix, suffix) at every byte boundary:
        // each half arrives as its own push, both frames must decode
        let frames = vec![Frame::Ping { id: 42 }, Frame::Pong { id: 43 }];
        let mut wire = Vec::new();
        for f in &frames {
            wire.extend_from_slice(&f.encode());
        }
        for cut in 0..=wire.len() {
            let mut asm = FrameAssembler::new();
            let mut got = Vec::new();
            for chunk in [&wire[..cut], &wire[cut..]] {
                asm.push(chunk);
                while let Some(f) = asm.next_frame().expect("clean stream") {
                    got.push(f);
                }
            }
            assert_eq!(got, frames, "split at byte {cut}");
        }
    }

    #[test]
    fn assembler_rejects_bad_lengths_and_checksums() {
        // oversized declared length
        let mut asm = FrameAssembler::new();
        asm.push(&(MAX_FRAME_LEN as u32 + 1).to_le_bytes());
        assert!(asm.next_frame().unwrap_err().contains("exceeds"));
        // undersized declared length
        let mut asm = FrameAssembler::new();
        asm.push(&3u32.to_le_bytes());
        assert!(asm.next_frame().unwrap_err().contains("too short"));
        // corrupted checksum
        let mut wire = Frame::Ping { id: 9 }.encode();
        let last = wire.len() - 1;
        wire[last] ^= 0xFF;
        let mut asm = FrameAssembler::new();
        asm.push(&wire);
        assert!(asm.next_frame().unwrap_err().contains("checksum mismatch"));
    }

    #[test]
    fn assembler_agrees_with_blocking_reader() {
        // the incremental and blocking decoders accept the same bytes
        // and yield equal frames — the loop and the client cannot drift
        let frames = assembler_fixture();
        let mut wire = Vec::new();
        for f in &frames {
            wire.extend_from_slice(&f.encode());
        }
        let mut reader = &wire[..];
        let mut asm = FrameAssembler::new();
        asm.push(&wire);
        for want in &frames {
            let blocking = Frame::read_from(&mut reader).expect("blocking decode");
            let incremental = asm.next_frame().expect("incremental decode").expect("frame ready");
            assert_eq!(&blocking, want);
            assert_eq!(&incremental, want);
        }
    }
}
