//! The network tier: a std-only TCP serving gateway over the L3
//! coordinator (versioned binary wire protocol, session admission,
//! graceful drain, and an HTTP `GET /metrics` responder), the
//! event-driven session layer behind it (`poll`: readiness loops over
//! nonblocking sockets — sessions cost slab entries, not threads), the
//! blocking reference client, and the composable load-generation
//! harness (`loadgen`: workload blends, Zipf model popularity,
//! open-loop arrivals).
//!
//! See DESIGN.md §6b for the gateway ownership diagram and §6e for the
//! readiness-loop session layer (wakeup-pipe delivery, backpressure,
//! timer wheel).

pub mod client;
pub mod gateway;
pub mod loadgen;
pub(crate) mod poll;
pub mod protocol;

pub use client::{Client, ClientError, InferReply, RetryClient, RetryPolicy};
pub use gateway::{Gateway, GatewayConfig};
pub use loadgen::{DataSet, LoadReport, LoadgenConfig, Workload, Zipf};
pub use protocol::{ErrorCode, Frame, FrameAssembler, HelloStatus, WireBatch, WireError};
