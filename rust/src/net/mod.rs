//! The network tier: a std-only TCP serving gateway over the L3
//! coordinator (versioned binary wire protocol, session admission,
//! graceful drain, and an HTTP `GET /metrics` responder) plus the
//! blocking reference client.
//!
//! See DESIGN.md §6b for the ownership diagram (who owns sessions, how
//! the drain composes with the coordinator's control plane).

pub mod client;
pub mod gateway;
pub mod protocol;

pub use client::{Client, ClientError, InferReply, RetryClient, RetryPolicy};
pub use gateway::{Gateway, GatewayConfig};
pub use protocol::{ErrorCode, Frame, HelloStatus, WireBatch, WireError};
