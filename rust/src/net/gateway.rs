//! The TCP serving gateway: the network edge in front of the
//! `Coordinator`.
//!
//! One acceptor thread owns the `TcpListener`; every connection gets a
//! session thread.  A session sniffs its first four bytes: `b"RNSG"`
//! starts the binary wire protocol (protocol.rs), `b"GET "` / `b"HEAD"`
//! is an HTTP/1.1 scrape (so the running server is scrapeable with no
//! extra port).  `GET /metrics` serves the live human-readable report;
//! `GET /metrics?format=prometheus` serves the same registry as
//! Prometheus text exposition (`text/plain; version=0.0.4`); `HEAD`
//! returns the headers alone.
//!
//! **Counters.**  The gateway's own counters (sessions, frames,
//! protocol errors, scrapes) are registered into the coordinator's
//! `MetricRegistry` at start — the `gateway:` report lines and the
//! `rns_gateway_*` exposition families read the same atomics, so the
//! two can never disagree.
//!
//! **Admission.**  Binary sessions are capped at
//! `GatewayConfig::max_sessions`: past the cap the handshake reply
//! carries `HelloStatus::Overloaded` followed by one typed
//! `Error { code: Overloaded }` frame, then the connection closes.
//! Metrics scrapes are exempt — observability must work *especially*
//! under overload.
//!
//! **Sessions.**  A session runs two threads: the reader (the session
//! thread itself) parses frames and pipelines `Infer` requests straight
//! into the coordinator via `CoordinatorHandle::submit_routed`, and a
//! writer serializes replies from a channel.  Responses correlate by the
//! client-chosen request id — the routed delivery callback carries the
//! id into the reply frame — so a client may keep many requests in
//! flight and the `DynamicBatcher` sees them all.  The writer exits when
//! every reply sender is gone: the reader's own clone (dropped at
//! reader exit) plus one clone inside each in-flight request's delivery
//! callback — which is exactly the "no accepted request loses its
//! reply" invariant.
//!
//! **Shutdown.**  `Gateway::shutdown` stops the acceptor, then calls
//! `TcpStream::shutdown(Read)` on every live session: readers see EOF
//! and stop accepting frames, writers still deliver every in-flight
//! reply, sessions close.  Only then does the coordinator drain through
//! its own `ControlMsg` path (queued batches complete before workers
//! exit).  A client can request this remotely with a `Shutdown` frame.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::chaos::ChaosSpec;
use crate::coordinator::metrics::{stage_histogram, GatewayReport};
use crate::coordinator::request::ServeErrorKind;
use crate::coordinator::server::{Coordinator, CoordinatorHandle};
use crate::net::protocol::{ErrorCode, Frame, HelloStatus, WireError, MAGIC, VERSION};
use crate::util::metrics::{Counter, Gauge, Histogram, LATENCY_BUCKETS_US};
use crate::util::stats::Reservoir;

/// Gateway knobs (config file: `[serve] listen_addr / max_sessions /
/// idle_timeout_ms / admin_token`; CLI: `serve --listen=...
/// --max-sessions=...`).
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Bind address; port 0 picks an ephemeral port (tests read it back
    /// via `Gateway::local_addr`).
    pub listen_addr: String,
    /// Admission cap on concurrent binary sessions.
    pub max_sessions: usize,
    /// Per-session read/write timeout: a session idle (or stalled
    /// mid-frame) this long is closed.
    pub idle_timeout: Duration,
    /// Shared secret for admin frames (load/unload/shutdown).  `Some`:
    /// every admin frame must carry this token, from any peer.  `None`:
    /// the loopback-only fallback — admin frames are honored only from
    /// 127.0.0.1/::1 peers (the pre-v2 rule).
    pub admin_token: Option<String>,
    /// Injected connection drops (`drop@s{S}:f{N}` events; tests / chaos
    /// smoke).  Worker-side events are the coordinator's copy of the
    /// same spec.
    pub chaos: ChaosSpec,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            listen_addr: "127.0.0.1:7070".into(),
            max_sessions: 64,
            idle_timeout: Duration::from_secs(30),
            admin_token: None,
            chaos: ChaosSpec::default(),
        }
    }
}

/// How often the (nonblocking) acceptor re-polls between connections and
/// checks the stop flag.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Bound on a scrape's request head (we only need the path).
const MAX_HTTP_HEAD: usize = 8 << 10;

/// Sample bound for the gateway's latency percentiles: the gateway
/// serves indefinitely, so an unbounded sample vector — and a full sort
/// of all-time history under the mutex that response-delivery callbacks
/// need — is not an option.  The shared `util::stats::Reservoir`
/// (Vitter's Algorithm R; the coordinator's latency metrics use the same
/// type) keeps p50/p99 tight at 4096 samples while a `/metrics` scrape
/// sorts a bounded copy.
const LATENCY_RESERVOIR: usize = 4096;

/// State shared by the acceptor, every session thread, and the owning
/// `Gateway`.
struct GatewayShared {
    handle: CoordinatorHandle,
    cfg: GatewayConfig,
    /// Live binary sessions.  Admission control and the exported
    /// `rns_gateway_active_sessions` gauge are ONE atomic: the session
    /// cap is enforced with `Gauge::try_inc_below`, so the count a
    /// scrape sees is the count admission acted on.
    active: Arc<Gauge>,
    accepted: Arc<Counter>,
    rejected: Arc<Counter>,
    frames_in: Arc<Counter>,
    frames_out: Arc<Counter>,
    protocol_errors: Arc<Counter>,
    /// Every HTTP request served (hits *and* 404s — the report's
    /// `scrapes=` key has always counted all of them).
    scrapes: Arc<Counter>,
    /// HTTP requests answered 404, separately from `scrapes`.
    not_found: Arc<Counter>,
    /// Gateway-side request latency histogram (same samples the
    /// reservoir percentiles summarize, exported with full buckets).
    request_latency: Arc<Histogram>,
    /// The `admission` stage of `rns_stage_latency_us`: frame decode →
    /// coordinator accept, observed in the Infer path.
    admission: Arc<Histogram>,
    /// Gateway-side request latency (submit → reply delivery), µs —
    /// bounded reservoir, not all-time history.  Shared as its own Arc
    /// so routed delivery callbacks don't capture the whole
    /// `GatewayShared` (which would cycle through the routes map back
    /// to itself).
    latency_us: Arc<Mutex<Reservoir>>,
    /// Set during shutdown: new sessions and new `Infer` frames are
    /// refused while in-flight replies drain.
    draining: AtomicBool,
    /// Signals `Gateway::wait_shutdown` when a client sends `Shutdown`.
    shutdown_tx: Mutex<Option<Sender<()>>>,
    /// Live session bookkeeping: a stream clone (for the drain-time
    /// read-shutdown) plus the session thread's handle.
    sessions: Mutex<Vec<SessionSlot>>,
}

struct SessionSlot {
    stream: TcpStream,
    thread: JoinHandle<()>,
}

impl GatewayShared {
    /// Is this admin frame authorized?  Token mode when a token is
    /// configured (constant rule for every peer), loopback-only mode
    /// otherwise.
    fn admin_ok(&self, peer_is_loopback: bool, token: &str) -> bool {
        match &self.cfg.admin_token {
            Some(expect) => token == expect,
            None => peer_is_loopback,
        }
    }

    fn gateway_report(&self) -> GatewayReport {
        let (latency_p50_us, latency_p99_us) = {
            let r = self.latency_us.lock().unwrap();
            (r.percentile(50.0), r.percentile(99.0))
        };
        GatewayReport {
            sessions_accepted: self.accepted.get(),
            sessions_active: self.active.get().max(0) as u64,
            sessions_rejected: self.rejected.get(),
            frames_in: self.frames_in.get(),
            frames_out: self.frames_out.get(),
            protocol_errors: self.protocol_errors.get(),
            http_scrapes: self.scrapes.get(),
            latency_p50_us,
            latency_p99_us,
        }
    }

    /// The live `ServingMetrics` report with current `gateway:` lines.
    fn report(&self) -> String {
        self.handle.set_gateway_report(self.gateway_report());
        self.handle.live_report()
    }

    /// The registry as Prometheus text exposition — the gateway's own
    /// counters are registered there, so no snapshot hand-off is needed.
    fn prometheus_report(&self) -> String {
        self.handle.prometheus_report()
    }

    fn signal_shutdown(&self) {
        if let Some(tx) = self.shutdown_tx.lock().unwrap().take() {
            tx.send(()).ok();
        }
    }
}

/// Decrements the admission gauge when a session ends, however it ends.
struct ActiveGuard(Arc<GatewayShared>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.active.add(-1);
    }
}

/// A running gateway.  Owns the `Coordinator`; `shutdown` drains the
/// network tier first, then the coordinator, and returns the final
/// report (gateway lines included).
pub struct Gateway {
    coord: Option<Coordinator>,
    shared: Arc<GatewayShared>,
    local_addr: SocketAddr,
    stop_accepting: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    shutdown_rx: Receiver<()>,
}

impl Gateway {
    pub fn start(coord: Coordinator, cfg: GatewayConfig) -> Result<Gateway, String> {
        let listener = TcpListener::bind(&cfg.listen_addr)
            .map_err(|e| format!("bind {}: {e}", cfg.listen_addr))?;
        let local_addr = listener.local_addr().map_err(|e| e.to_string())?;
        // nonblocking accept + poll keeps shutdown simple (no self-connect
        // wakeup dance); 10 ms accept latency is noise against a forward
        listener.set_nonblocking(true).map_err(|e| e.to_string())?;
        let (shutdown_tx, shutdown_rx) = mpsc::channel();
        let handle = coord.handle();
        // the gateway's counters live in the coordinator's registry:
        // report lines and exposition families read the same atomics
        let reg = handle.metric_registry();
        let shared = Arc::new(GatewayShared {
            cfg,
            active: reg.gauge("rns_gateway_active_sessions", "Live binary sessions"),
            accepted: reg.counter("rns_gateway_sessions_total", "Binary sessions admitted"),
            rejected: reg.counter(
                "rns_gateway_sessions_rejected_total",
                "Sessions refused (overload, version, draining)",
            ),
            frames_in: reg.counter("rns_gateway_frames_in_total", "Request frames received"),
            frames_out: reg.counter("rns_gateway_frames_out_total", "Reply frames written"),
            protocol_errors: reg
                .counter("rns_gateway_protocol_errors_total", "Malformed frames and batches"),
            scrapes: reg.counter("rns_gateway_http_requests_total", "HTTP requests (hits + 404s)"),
            not_found: reg.counter("rns_gateway_http_not_found_total", "HTTP requests answered 404"),
            request_latency: reg.histogram(
                "rns_gateway_request_latency_us",
                "Gateway-side request latency in microseconds",
                &LATENCY_BUCKETS_US,
            ),
            admission: stage_histogram(&reg, "admission"),
            handle,
            latency_us: Arc::new(Mutex::new(Reservoir::new(LATENCY_RESERVOIR, 0x6A7E_11A7))),
            draining: AtomicBool::new(false),
            shutdown_tx: Mutex::new(Some(shutdown_tx)),
            sessions: Mutex::new(Vec::new()),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let acceptor = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("rns-gw-acceptor".into())
                .spawn(move || acceptor_loop(listener, shared, stop))
                .map_err(|e| e.to_string())?
        };
        crate::log_info!(
            "gateway",
            "listening on {local_addr} (max {} sessions)",
            shared.cfg.max_sessions
        );
        Ok(Gateway {
            coord: Some(coord),
            shared,
            local_addr,
            stop_accepting: stop,
            acceptor: Some(acceptor),
            shutdown_rx,
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Block until a client requests shutdown via a `Shutdown` frame, or
    /// `timeout` elapses (`None` waits indefinitely).  Returns whether a
    /// shutdown was requested.
    pub fn wait_shutdown(&self, timeout: Option<Duration>) -> bool {
        match timeout {
            Some(d) => self.shutdown_rx.recv_timeout(d).is_ok(),
            None => self.shutdown_rx.recv().is_ok(),
        }
    }

    /// Graceful drain: stop accepting, stop reading new frames, deliver
    /// every in-flight reply, close sessions, then drain the coordinator
    /// through its control plane.  Returns the final report.
    pub fn shutdown(mut self) -> String {
        self.stop_accepting.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            a.join().ok();
        }
        self.shared.draining.store(true, Ordering::SeqCst);
        // half-close every live session's read side: its reader sees EOF
        // and stops accepting frames, while its writer still delivers
        // every reply already owed — zero accepted requests are lost
        let slots: Vec<SessionSlot> = self.shared.sessions.lock().unwrap().drain(..).collect();
        for s in &slots {
            s.stream.shutdown(Shutdown::Read).ok();
        }
        let n_sessions = slots.len();
        for s in slots {
            s.thread.join().ok();
        }
        crate::log_info!("gateway", "drained {n_sessions} session(s); stopping coordinator");
        let coord = self.coord.take().expect("gateway owns the coordinator");
        self.shared.handle.set_gateway_report(self.shared.gateway_report());
        coord.shutdown()
    }
}

fn acceptor_loop(listener: TcpListener, shared: Arc<GatewayShared>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                let slot_stream = match stream.try_clone() {
                    Ok(c) => c,
                    Err(_) => continue,
                };
                let sshared = Arc::clone(&shared);
                let spawned = std::thread::Builder::new()
                    .name("rns-gw-session".into())
                    .spawn(move || session_entry(stream, peer, sshared));
                if let Ok(thread) = spawned {
                    let mut sessions = shared.sessions.lock().unwrap();
                    // reap finished sessions so the slot list tracks live
                    // connections, not connection history
                    sessions.retain(|s| !s.thread.is_finished());
                    sessions.push(SessionSlot { stream: slot_stream, thread });
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Write the 7-byte server hello: MAGIC + VERSION + status.
fn write_hello(stream: &mut TcpStream, status: HelloStatus) -> std::io::Result<()> {
    let mut hello = Vec::with_capacity(7);
    hello.extend_from_slice(&MAGIC);
    hello.extend_from_slice(&VERSION.to_le_bytes());
    hello.push(status.to_byte());
    stream.write_all(&hello)
}

/// Refuse a session: non-ok hello status, one typed `Error` frame with
/// the reason, close.
fn reject(stream: &mut TcpStream, status: HelloStatus, code: ErrorCode, msg: &str) {
    if write_hello(stream, status).is_ok() {
        let frame = Frame::Error { id: 0, code, message: msg.to_string() };
        stream.write_all(&frame.encode()).ok();
    }
    stream.shutdown(Shutdown::Both).ok();
}

fn session_entry(mut stream: TcpStream, peer: SocketAddr, shared: Arc<GatewayShared>) {
    // the listener is nonblocking for the acceptor's stop-flag poll; the
    // session itself is blocking I/O (inheritance is platform-dependent)
    stream.set_nonblocking(false).ok();
    stream.set_read_timeout(Some(shared.cfg.idle_timeout)).ok();
    stream.set_write_timeout(Some(shared.cfg.idle_timeout)).ok();
    stream.set_nodelay(true).ok();
    let mut first = [0u8; 4];
    if stream.read_exact(&mut first).is_err() {
        return;
    }
    if &first == b"GET " || &first == b"HEAD" {
        serve_http(stream, &shared, &first == b"HEAD");
        return;
    }
    if first != MAGIC {
        shared.protocol_errors.inc();
        stream.shutdown(Shutdown::Both).ok();
        return;
    }
    let mut ver = [0u8; 2];
    if stream.read_exact(&mut ver).is_err() {
        return;
    }
    let version = u16::from_le_bytes(ver);
    if version != VERSION {
        shared.rejected.inc();
        reject(
            &mut stream,
            HelloStatus::BadVersion,
            ErrorCode::Protocol,
            &format!("server speaks protocol v{VERSION}, client sent v{version}"),
        );
        return;
    }
    if shared.draining.load(Ordering::SeqCst) {
        shared.rejected.inc();
        reject(&mut stream, HelloStatus::Draining, ErrorCode::Draining, "gateway is draining");
        return;
    }
    // admission: reserve a live-session slot or refuse with the typed
    // overload frame.  The compare-and-increment runs on the exported
    // gauge itself, so a burst of connects cannot oversubscribe the cap
    // and a scrape can never see a count admission didn't act on.
    let admitted = shared.active.try_inc_below(shared.cfg.max_sessions as i64);
    if !admitted {
        shared.rejected.inc();
        reject(
            &mut stream,
            HelloStatus::Overloaded,
            ErrorCode::Overloaded,
            &format!("gateway at capacity ({} sessions)", shared.cfg.max_sessions),
        );
        return;
    }
    let _guard = ActiveGuard(Arc::clone(&shared));
    // the pre-increment value is this session's 0-based admission index —
    // the `s{S}` coordinate of `drop@s{S}:f{N}` chaos events
    let session_idx = shared.accepted.inc();
    if write_hello(&mut stream, HelloStatus::Ok).is_err() {
        return;
    }
    // admin frames (load/unload/shutdown) need authorization: a matching
    // shared-secret token when one is configured, else loopback-only —
    // a non-loopback bind must not hand every peer the power to drop
    // models or drain the server
    let peer_is_loopback = peer.ip().is_loopback();
    let chaos_drop = shared.cfg.chaos.session_drop(session_idx);
    crate::log_debug!("gateway", "session {session_idx} open from {peer}");
    run_session(stream, peer_is_loopback, chaos_drop, &shared);
    crate::log_debug!("gateway", "session from {peer} closed");
}

fn run_session(
    stream: TcpStream,
    peer_is_loopback: bool,
    chaos_drop: Option<u64>,
    shared: &Arc<GatewayShared>,
) {
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (reply_tx, reply_rx) = mpsc::channel::<Frame>();
    let wshared = Arc::clone(shared);
    let writer = match std::thread::Builder::new()
        .name("rns-gw-writer".into())
        .spawn(move || writer_loop(write_half, reply_rx, wshared))
    {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = stream;
    let mut frames_read: u64 = 0;
    loop {
        match Frame::read_from(&mut reader) {
            Ok(frame) => {
                shared.frames_in.inc();
                frames_read += 1;
                let keep = handle_frame(frame, peer_is_loopback, shared, &reply_tx);
                // injected connection drop: sever abruptly *after* the
                // Nth frame was accepted, exactly like a peer vanishing
                // mid-conversation — the client's reconnect/retry path
                // must recover (in-flight replies die with the socket)
                if chaos_drop == Some(frames_read) {
                    crate::log_warn!(
                        "gateway",
                        "chaos: dropping session after frame {frames_read}"
                    );
                    reader.shutdown(Shutdown::Both).ok();
                    break;
                }
                if !keep {
                    break;
                }
            }
            // clean close, idle timeout, or the drain-time read-shutdown
            Err(WireError::Eof) | Err(WireError::Io(_)) => break,
            Err(WireError::Protocol(msg)) => {
                // reply with the typed protocol error, then close: the
                // frame boundary is unknown, resync is impossible
                shared.protocol_errors.inc();
                reply_tx.send(Frame::Error { id: 0, code: ErrorCode::Protocol, message: msg }).ok();
                break;
            }
        }
    }
    // reader done: once every in-flight request's delivery callback has
    // fired (each holds a reply sender), the writer's channel closes and
    // it exits having written every owed reply
    drop(reply_tx);
    writer.join().ok();
}

/// Reply to an unauthorized admin frame with the reason that applies.
fn deny_admin(id: u64, token_mode: bool, reply_tx: &Sender<Frame>) {
    let message = if token_mode {
        "admin frames (load/unload/shutdown) require the configured admin token".to_string()
    } else {
        "admin frames (load/unload/shutdown) are loopback-only".to_string()
    };
    reply_tx.send(Frame::Error { id, code: ErrorCode::Unauthorized, message }).ok();
}

/// The wire error code for a typed coordinator failure.
fn wire_code(kind: ServeErrorKind) -> ErrorCode {
    match kind {
        ServeErrorKind::Model => ErrorCode::Model,
        ServeErrorKind::Internal => ErrorCode::Internal,
        ServeErrorKind::DeadlineExceeded => ErrorCode::DeadlineExceeded,
        ServeErrorKind::Poisoned => ErrorCode::Poisoned,
    }
}

/// Handle one request frame; returns whether the session continues.
fn handle_frame(
    frame: Frame,
    peer_is_loopback: bool,
    shared: &Arc<GatewayShared>,
    reply_tx: &Sender<Frame>,
) -> bool {
    let token_mode = shared.cfg.admin_token.is_some();
    match frame {
        Frame::Ping { id } => {
            reply_tx.send(Frame::Pong { id }).ok();
        }
        Frame::Stats { id } => {
            let text = shared.report();
            reply_tx.send(Frame::StatsReport { id, text }).ok();
        }
        Frame::Traces { id } => {
            let text = shared.handle.traces_report();
            reply_tx.send(Frame::TracesReport { id, text }).ok();
        }
        Frame::LoadModel { id, model, token } => {
            if !shared.admin_ok(peer_is_loopback, &token) {
                deny_admin(id, token_mode, reply_tx);
                return true;
            }
            match shared.handle.load_model(&model) {
                Ok(()) => {
                    reply_tx.send(Frame::Ack { id, info: format!("loaded `{model}`") }).ok();
                }
                Err(e) => {
                    reply_tx.send(Frame::Error { id, code: ErrorCode::Model, message: e }).ok();
                }
            }
        }
        Frame::UnloadModel { id, model, token } => {
            if !shared.admin_ok(peer_is_loopback, &token) {
                deny_admin(id, token_mode, reply_tx);
                return true;
            }
            let evicted = shared.handle.unload_model(&model);
            let info = format!("unloaded `{model}`: {evicted} plans evicted");
            reply_tx.send(Frame::Ack { id, info }).ok();
        }
        Frame::Shutdown { id, token } => {
            if !shared.admin_ok(peer_is_loopback, &token) {
                deny_admin(id, token_mode, reply_tx);
                return true;
            }
            reply_tx.send(Frame::Ack { id, info: "draining".into() }).ok();
            shared.signal_shutdown();
        }
        Frame::Infer { id, model, deadline_ms, input } => {
            if shared.draining.load(Ordering::SeqCst) {
                let message = "gateway is draining".to_string();
                reply_tx.send(Frame::Error { id, code: ErrorCode::Draining, message }).ok();
                return true;
            }
            let batch = match input.into_batch() {
                Ok(b) => b,
                Err(e) => {
                    // declared-shape mismatch: framing is intact, so the
                    // session survives — reply typed and keep reading
                    shared.protocol_errors.inc();
                    reply_tx.send(Frame::Error { id, code: ErrorCode::Protocol, message: e }).ok();
                    return true;
                }
            };
            let tx = reply_tx.clone();
            let latency = Arc::clone(&shared.latency_us);
            let latency_hist = Arc::clone(&shared.request_latency);
            let t0 = Instant::now();
            // 0 = no per-request deadline (the server default applies)
            let deadline =
                (deadline_ms > 0).then(|| Duration::from_millis(u64::from(deadline_ms)));
            let submitted =
                shared.handle.submit_routed_with_deadline(&model, batch, deadline, move |resp| {
                    latency.lock().unwrap().add(t0.elapsed().as_secs_f64() * 1e6);
                    latency_hist.observe(t0.elapsed().as_micros() as u64);
                    let frame = match resp.result {
                        Ok(logits) => Frame::InferOk {
                            id,
                            rows: logits.rows as u32,
                            cols: logits.cols as u32,
                            logits: logits.data,
                            faults_detected: resp.faults_detected,
                            worker: resp.worker as u32,
                        },
                        Err(e) => {
                            Frame::Error { id, code: wire_code(e.kind), message: e.message }
                        }
                    };
                    tx.send(frame).ok();
                });
            match submitted {
                // the `admission` pipeline stage: batch validation through
                // coordinator accept (queueing starts after this); rejected
                // submissions don't count as admitted
                Ok(_) => shared.admission.observe(t0.elapsed().as_micros() as u64),
                Err(e) => {
                    reply_tx.send(Frame::Error { id, code: ErrorCode::Internal, message: e }).ok();
                }
            }
        }
        // a reply kind arriving at the server is a client bug
        other => {
            shared.protocol_errors.inc();
            let message = "reply frame sent to server".to_string();
            reply_tx
                .send(Frame::Error { id: other.id(), code: ErrorCode::Protocol, message })
                .ok();
            return false;
        }
    }
    true
}

fn writer_loop(mut stream: TcpStream, reply_rx: Receiver<Frame>, shared: Arc<GatewayShared>) {
    while let Ok(frame) = reply_rx.recv() {
        if stream.write_all(&frame.encode()).is_err() {
            // peer gone: kick the reader out of its blocking read, then
            // drain silently so routed deliveries never block on us
            stream.shutdown(Shutdown::Both).ok();
            while reply_rx.recv().is_ok() {}
            return;
        }
        shared.frames_out.inc();
    }
}

/// Minimal HTTP/1.1 responder for metrics scrapes.  The 4-byte method
/// sniff (`b"GET "` / `b"HEAD"`) has already been consumed; everything
/// up to the blank line is read (bounded) and only the request target
/// matters.  `HEAD` writes the status line + headers and no body.
fn serve_http(mut stream: TcpStream, shared: &Arc<GatewayShared>, is_head: bool) {
    // every HTTP request counts as a scrape, hit or miss, GET or HEAD
    shared.scrapes.inc();
    let mut head = Vec::new();
    let mut tmp = [0u8; 512];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") {
        if head.len() > MAX_HTTP_HEAD {
            return;
        }
        match stream.read(&mut tmp) {
            Ok(0) | Err(_) => break,
            Ok(n) => head.extend_from_slice(&tmp[..n]),
        }
    }
    // the 4-byte method sniff already consumed "GET " / "HEAD", so the
    // remaining head starts at (or just before) the request target
    let text = String::from_utf8_lossy(&head);
    let target = text.split_whitespace().next().unwrap_or("");
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let (status, content_type, body) = if path == "/metrics" {
        if query.split('&').any(|kv| kv == "format=prometheus") {
            // Prometheus text exposition format 0.0.4
            ("200 OK", "text/plain; version=0.0.4", shared.prometheus_report())
        } else {
            ("200 OK", "text/plain; charset=utf-8", format!("{}\n", shared.report()))
        }
    } else {
        shared.not_found.inc();
        (
            "404 Not Found",
            "text/plain; charset=utf-8",
            format!("no such path `{path}` (try /metrics)\n"),
        )
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(resp.as_bytes()).ok();
    if !is_head {
        stream.write_all(body.as_bytes()).ok();
    }
    stream.shutdown(Shutdown::Both).ok();
}
