//! The TCP serving gateway: the network edge in front of the
//! `Coordinator`.
//!
//! One acceptor thread owns the `TcpListener` and hands every accepted
//! connection to one of `GatewayConfig::loop_threads` **readiness
//! loops** (`net/poll.rs`, round-robin) — sessions cost loop slab
//! entries, not OS threads, so the thread count is flat in session
//! count.  A connection's first four bytes are sniffed on the loop:
//! `b"RNSG"` starts the binary wire protocol (protocol.rs), `b"GET "` /
//! `b"HEAD"` is an HTTP/1.1 scrape handed to a short-lived responder
//! thread (so the running server is scrapeable with no extra port).
//! `GET /metrics` serves the live human-readable report;
//! `GET /metrics?format=prometheus` serves the same registry as
//! Prometheus text exposition (`text/plain; version=0.0.4`); `HEAD`
//! returns the headers alone.
//!
//! **Counters.**  The gateway's own counters (sessions, frames,
//! protocol errors, scrapes, per-loop busy time) are registered into
//! the coordinator's `MetricRegistry` at start — the `gateway:` report
//! lines and the `rns_gateway_*` exposition families read the same
//! atomics, so the two can never disagree.
//!
//! **Admission.**  Binary sessions are capped at
//! `GatewayConfig::max_sessions`: past the cap the handshake reply
//! carries `HelloStatus::Overloaded` followed by one typed
//! `Error { code: Overloaded }` frame, then the connection closes.
//! Metrics scrapes are exempt — observability must work *especially*
//! under overload.
//!
//! **Sessions.**  A session lives entirely on its readiness loop:
//! incremental frame reassembly (`FrameAssembler`) turns nonblocking
//! reads into frames, `Infer` requests pipeline straight into the
//! coordinator via `CoordinatorHandle::submit_routed_with_deadline`,
//! and the routed delivery callback enqueues the reply back to the loop
//! through its wakeup pipe (generation-fenced token, so a reused slot
//! never receives a dead session's reply).  Responses correlate by the
//! client-chosen request id, so a client may keep many requests in
//! flight and the `DynamicBatcher` sees them all.
//!
//! **Shutdown.**  `Gateway::shutdown` stops the acceptor, then sends
//! every loop a drain message: loops half-close each session's read
//! side (peers see EOF, no new frames) and keep flushing until every
//! in-flight reply has been delivered — the "no accepted request loses
//! its reply" invariant, now tracked as a per-connection in-flight
//! count.  Only then does the coordinator drain through its own
//! `ControlMsg` path (queued batches complete before workers exit).  A
//! client can request all this remotely with a `Shutdown` frame.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::chaos::ChaosSpec;
use crate::coordinator::metrics::{stage_histogram, GatewayReport};
use crate::coordinator::request::ServeErrorKind;
use crate::coordinator::server::{Coordinator, CoordinatorHandle};
use crate::net::poll::{spawn_loop, LoopHandle, LoopMsg, ReplyRoute};
use crate::net::protocol::{ErrorCode, Frame, HelloStatus, MAGIC, VERSION};
use crate::util::logging::{emit_fields, FieldValue, Level};
use crate::util::metrics::{Counter, Gauge, Histogram, LATENCY_BUCKETS_US};
use crate::util::stats::Reservoir;
use crate::util::trace::{self, Span, TraceCollector};

/// Gateway knobs (config file: `[serve] listen_addr / max_sessions /
/// idle_timeout_ms / loop_threads / admin_token`; CLI: `serve
/// --listen=... --max-sessions=... --loop-threads=...`).
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Bind address; port 0 picks an ephemeral port (tests read it back
    /// via `Gateway::local_addr`).
    pub listen_addr: String,
    /// Admission cap on concurrent binary sessions.
    pub max_sessions: usize,
    /// Per-session idle timeout: a session with no read or write
    /// progress this long is closed.
    pub idle_timeout: Duration,
    /// Readiness loops serving sessions (sessions hash round-robin at
    /// accept).  One loop drives hundreds of sessions; more loops help
    /// once frame decode/dispatch itself saturates a core.
    pub loop_threads: usize,
    /// Shared secret for admin frames (load/unload/shutdown).  `Some`:
    /// every admin frame must carry this token, from any peer.  `None`:
    /// the loopback-only fallback — admin frames are honored only from
    /// 127.0.0.1/::1 peers (the pre-v2 rule).
    pub admin_token: Option<String>,
    /// Injected connection drops (`drop@s{S}:f{N}` events; tests / chaos
    /// smoke).  Worker-side events are the coordinator's copy of the
    /// same spec.
    pub chaos: ChaosSpec,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            listen_addr: "127.0.0.1:7070".into(),
            max_sessions: 64,
            idle_timeout: Duration::from_secs(30),
            loop_threads: 1,
            admin_token: None,
            chaos: ChaosSpec::default(),
        }
    }
}

/// How often the (nonblocking) acceptor re-polls between connections and
/// checks the stop flag.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Bound on a scrape's request head (we only need the path).
const MAX_HTTP_HEAD: usize = 8 << 10;

/// Sample bound for the gateway's latency percentiles: the gateway
/// serves indefinitely, so an unbounded sample vector — and a full sort
/// of all-time history under the mutex that response-delivery callbacks
/// need — is not an option.  The shared `util::stats::Reservoir`
/// (Vitter's Algorithm R; the coordinator's latency metrics use the same
/// type) keeps p50/p99 tight at 4096 samples while a `/metrics` scrape
/// sorts a bounded copy.
const LATENCY_RESERVOIR: usize = 4096;

/// State shared by the acceptor, the readiness loops, scrape threads,
/// and the owning `Gateway`.
pub(crate) struct GatewayShared {
    pub(crate) handle: CoordinatorHandle,
    pub(crate) cfg: GatewayConfig,
    /// Live binary sessions.  Admission control and the exported
    /// `rns_gateway_active_sessions` gauge are ONE atomic: the session
    /// cap is enforced with `Gauge::try_inc_below`, so the count a
    /// scrape sees is the count admission acted on.
    pub(crate) active: Arc<Gauge>,
    pub(crate) accepted: Arc<Counter>,
    pub(crate) rejected: Arc<Counter>,
    pub(crate) frames_in: Arc<Counter>,
    pub(crate) frames_out: Arc<Counter>,
    pub(crate) protocol_errors: Arc<Counter>,
    /// Every HTTP request served (hits *and* 404s — the report's
    /// `scrapes=` key has always counted all of them).
    pub(crate) scrapes: Arc<Counter>,
    /// HTTP requests answered 404, separately from `scrapes`.
    pub(crate) not_found: Arc<Counter>,
    /// Gateway-side request latency histogram (same samples the
    /// reservoir percentiles summarize, exported with full buckets).
    pub(crate) request_latency: Arc<Histogram>,
    /// The `admission` stage of `rns_stage_latency_us`: frame decode →
    /// coordinator accept, observed in the Infer path.
    pub(crate) admission: Arc<Histogram>,
    /// Gateway-side request latency (submit → reply delivery), µs —
    /// bounded reservoir, not all-time history.  Shared as its own Arc
    /// so routed delivery callbacks don't capture the whole
    /// `GatewayShared`.
    pub(crate) latency_us: Arc<Mutex<Reservoir>>,
    /// Set during shutdown: new sessions and new `Infer` frames are
    /// refused while in-flight replies drain (`/readyz` reads it too).
    pub(crate) draining: AtomicBool,
    /// Signals `Gateway::wait_shutdown` when a client sends `Shutdown`.
    pub(crate) shutdown_tx: Mutex<Option<Sender<()>>>,
    /// End-to-end span traces: sampling decisions, span recording, and
    /// the `/trace` endpoint all go through the coordinator's collector.
    pub(crate) collector: Arc<TraceCollector>,
}

impl GatewayShared {
    /// Is this admin frame authorized?  Token mode when a token is
    /// configured (constant rule for every peer), loopback-only mode
    /// otherwise.
    fn admin_ok(&self, peer_is_loopback: bool, token: &str) -> bool {
        match &self.cfg.admin_token {
            Some(expect) => token == expect,
            None => peer_is_loopback,
        }
    }

    fn gateway_report(&self) -> GatewayReport {
        let (latency_p50_us, latency_p99_us) = {
            let r = self.latency_us.lock().unwrap();
            (r.percentile(50.0), r.percentile(99.0))
        };
        GatewayReport {
            sessions_accepted: self.accepted.get(),
            sessions_active: self.active.get().max(0) as u64,
            sessions_rejected: self.rejected.get(),
            frames_in: self.frames_in.get(),
            frames_out: self.frames_out.get(),
            protocol_errors: self.protocol_errors.get(),
            http_scrapes: self.scrapes.get(),
            latency_p50_us,
            latency_p99_us,
        }
    }

    /// The live `ServingMetrics` report with current `gateway:` lines.
    fn report(&self) -> String {
        self.handle.set_gateway_report(self.gateway_report());
        self.handle.live_report()
    }

    /// The registry as Prometheus text exposition — the gateway's own
    /// counters are registered there, so no snapshot hand-off is needed.
    fn prometheus_report(&self) -> String {
        self.handle.prometheus_report()
    }

    fn signal_shutdown(&self) {
        if let Some(tx) = self.shutdown_tx.lock().unwrap().take() {
            tx.send(()).ok();
        }
    }
}

/// A running gateway.  Owns the `Coordinator`; `shutdown` drains the
/// network tier first, then the coordinator, and returns the final
/// report (gateway lines included).
pub struct Gateway {
    coord: Option<Coordinator>,
    shared: Arc<GatewayShared>,
    local_addr: SocketAddr,
    stop_accepting: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    loops: Vec<LoopHandle>,
    loop_joins: Vec<JoinHandle<usize>>,
    shutdown_rx: Receiver<()>,
}

impl Gateway {
    pub fn start(coord: Coordinator, cfg: GatewayConfig) -> Result<Gateway, String> {
        let listener = TcpListener::bind(&cfg.listen_addr)
            .map_err(|e| format!("bind {}: {e}", cfg.listen_addr))?;
        let local_addr = listener.local_addr().map_err(|e| e.to_string())?;
        // nonblocking accept + poll keeps shutdown simple (no self-connect
        // wakeup dance); 10 ms accept latency is noise against a forward
        listener.set_nonblocking(true).map_err(|e| e.to_string())?;
        let (shutdown_tx, shutdown_rx) = mpsc::channel();
        let handle = coord.handle();
        // the gateway's counters live in the coordinator's registry:
        // report lines and exposition families read the same atomics
        let reg = handle.metric_registry();
        let loop_threads = cfg.loop_threads.max(1);
        let shared = Arc::new(GatewayShared {
            cfg,
            active: reg.gauge("rns_gateway_active_sessions", "Live binary sessions"),
            accepted: reg.counter("rns_gateway_sessions_total", "Binary sessions admitted"),
            rejected: reg.counter(
                "rns_gateway_sessions_rejected_total",
                "Sessions refused (overload, version, draining)",
            ),
            frames_in: reg.counter("rns_gateway_frames_in_total", "Request frames received"),
            frames_out: reg.counter("rns_gateway_frames_out_total", "Reply frames written"),
            protocol_errors: reg
                .counter("rns_gateway_protocol_errors_total", "Malformed frames and batches"),
            scrapes: reg.counter("rns_gateway_http_requests_total", "HTTP requests (hits + 404s)"),
            not_found: reg.counter("rns_gateway_http_not_found_total", "HTTP requests answered 404"),
            request_latency: reg.histogram(
                "rns_gateway_request_latency_us",
                "Gateway-side request latency in microseconds",
                &LATENCY_BUCKETS_US,
            ),
            admission: stage_histogram(&reg, "admission"),
            collector: handle.trace_collector(),
            handle,
            latency_us: Arc::new(Mutex::new(Reservoir::new(LATENCY_RESERVOIR, 0x6A7E_11A7))),
            draining: AtomicBool::new(false),
            shutdown_tx: Mutex::new(Some(shutdown_tx)),
        });
        // session threads are gone: the thread budget is the acceptor +
        // this fixed loop pool, independent of session count
        reg.gauge("rns_gateway_loop_threads", "Readiness-loop threads serving binary sessions")
            .set(loop_threads as i64);
        let mut loops = Vec::with_capacity(loop_threads);
        let mut loop_joins = Vec::with_capacity(loop_threads);
        for i in 0..loop_threads {
            let (h, j) = spawn_loop(Arc::clone(&shared), i)?;
            loops.push(h);
            loop_joins.push(j);
        }
        let stop = Arc::new(AtomicBool::new(false));
        let acceptor = {
            let loops = loops.clone();
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("rns-gw-acceptor".into())
                .spawn(move || acceptor_loop(listener, loops, stop))
                .map_err(|e| e.to_string())?
        };
        crate::log_info!(
            "gateway",
            "listening on {local_addr} (max {} sessions, {loop_threads} loop thread(s))",
            shared.cfg.max_sessions
        );
        Ok(Gateway {
            coord: Some(coord),
            shared,
            local_addr,
            stop_accepting: stop,
            acceptor: Some(acceptor),
            loops,
            loop_joins,
            shutdown_rx,
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Block until a client requests shutdown via a `Shutdown` frame, or
    /// `timeout` elapses (`None` waits indefinitely).  Returns whether a
    /// shutdown was requested.
    pub fn wait_shutdown(&self, timeout: Option<Duration>) -> bool {
        match timeout {
            Some(d) => self.shutdown_rx.recv_timeout(d).is_ok(),
            None => self.shutdown_rx.recv().is_ok(),
        }
    }

    /// Graceful drain: stop accepting, stop reading new frames, deliver
    /// every in-flight reply, close sessions, then drain the coordinator
    /// through its control plane.  Returns the final report.
    pub fn shutdown(mut self) -> String {
        self.stop_accepting.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            a.join().ok();
        }
        self.shared.draining.store(true, Ordering::SeqCst);
        // each loop half-closes its sessions' read sides (peers see EOF,
        // no new frames) and exits once every owed reply is flushed —
        // zero accepted requests are lost
        for l in &self.loops {
            l.send(LoopMsg::Drain);
        }
        let mut n_sessions = 0usize;
        for j in self.loop_joins.drain(..) {
            n_sessions += j.join().unwrap_or(0);
        }
        crate::log_info!("gateway", "drained {n_sessions} session(s); stopping coordinator");
        let coord = self.coord.take().expect("gateway owns the coordinator");
        self.shared.handle.set_gateway_report(self.shared.gateway_report());
        coord.shutdown()
    }
}

fn acceptor_loop(listener: TcpListener, loops: Vec<LoopHandle>, stop: Arc<AtomicBool>) {
    let mut next = 0usize;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                // round-robin across the loop pool; the loop does the
                // sniff/handshake/admission work on its own thread
                loops[next % loops.len()].send(LoopMsg::Conn(stream, peer));
                next = next.wrapping_add(1);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// The 7-byte server hello: MAGIC + VERSION + status.
pub(crate) fn hello_bytes(status: HelloStatus) -> [u8; 7] {
    let mut hello = [0u8; 7];
    hello[..4].copy_from_slice(&MAGIC);
    hello[4..6].copy_from_slice(&VERSION.to_le_bytes());
    hello[6] = status.to_byte();
    hello
}

/// Refuse a session on a blocking stream: non-ok hello status, one typed
/// `Error` frame with the reason, close.  (The readiness loops queue the
/// same byte sequence through their write buffers instead.)
pub(crate) fn reject(stream: &mut TcpStream, status: HelloStatus, code: ErrorCode, msg: &str) {
    if stream.write_all(&hello_bytes(status)).is_ok() {
        let frame = Frame::Error { id: 0, code, message: msg.to_string() };
        stream.write_all(&frame.encode()).ok();
    }
    stream.shutdown(Shutdown::Both).ok();
}

/// Reply to an unauthorized admin frame with the reason that applies.
fn deny_admin(id: u64, token_mode: bool, sync: &mut Vec<Frame>) {
    let message = if token_mode {
        "admin frames (load/unload/shutdown) require the configured admin token".to_string()
    } else {
        "admin frames (load/unload/shutdown) are loopback-only".to_string()
    };
    sync.push(Frame::Error { id, code: ErrorCode::Unauthorized, message });
}

/// The wire error code for a typed coordinator failure.
fn wire_code(kind: ServeErrorKind) -> ErrorCode {
    match kind {
        ServeErrorKind::Model => ErrorCode::Model,
        ServeErrorKind::Internal => ErrorCode::Internal,
        ServeErrorKind::DeadlineExceeded => ErrorCode::DeadlineExceeded,
        ServeErrorKind::Poisoned => ErrorCode::Poisoned,
    }
}

/// What one dispatched frame did to its session.
pub(crate) struct FrameOutcome {
    /// Keep reading from this session (false: protocol violation, close
    /// after the queued replies flush).
    pub(crate) keep: bool,
    /// An `Infer` was accepted by the coordinator: a routed delivery
    /// callback now owes the session exactly one reply frame.
    pub(crate) submitted: bool,
}

/// Handle one request frame.  Synchronous replies are pushed onto
/// `sync` (the loop queues them on the connection's write buffer);
/// `Infer` replies arrive later through `route` when the coordinator
/// delivers.  `read_start_us` is when this frame's read burst began
/// (epoch µs) — the start of a sampled request's `assemble` span.
pub(crate) fn handle_frame(
    frame: Frame,
    peer_is_loopback: bool,
    shared: &Arc<GatewayShared>,
    sync: &mut Vec<Frame>,
    route: &ReplyRoute,
    read_start_us: u64,
) -> FrameOutcome {
    let token_mode = shared.cfg.admin_token.is_some();
    match frame {
        Frame::Ping { id } => {
            sync.push(Frame::Pong { id });
        }
        Frame::Stats { id } => {
            let text = shared.report();
            sync.push(Frame::StatsReport { id, text });
        }
        Frame::Traces { id } => {
            let text = shared.handle.traces_report();
            sync.push(Frame::TracesReport { id, text });
        }
        Frame::TraceSpans { id } => {
            let text = shared.handle.trace_spans_report();
            sync.push(Frame::TraceSpansReport { id, text });
        }
        Frame::LoadModel { id, model, token } => {
            if !shared.admin_ok(peer_is_loopback, &token) {
                deny_admin(id, token_mode, sync);
                return FrameOutcome { keep: true, submitted: false };
            }
            match shared.handle.load_model(&model) {
                Ok(()) => sync.push(Frame::Ack { id, info: format!("loaded `{model}`") }),
                Err(e) => sync.push(Frame::Error { id, code: ErrorCode::Model, message: e }),
            }
        }
        Frame::UnloadModel { id, model, token } => {
            if !shared.admin_ok(peer_is_loopback, &token) {
                deny_admin(id, token_mode, sync);
                return FrameOutcome { keep: true, submitted: false };
            }
            let evicted = shared.handle.unload_model(&model);
            let info = format!("unloaded `{model}`: {evicted} plans evicted");
            sync.push(Frame::Ack { id, info });
        }
        Frame::Shutdown { id, token } => {
            if !shared.admin_ok(peer_is_loopback, &token) {
                deny_admin(id, token_mode, sync);
                return FrameOutcome { keep: true, submitted: false };
            }
            sync.push(Frame::Ack { id, info: "draining".into() });
            // flip readiness immediately: `/readyz` reports 503 from the
            // moment the drain was requested, not from when the owning
            // process gets around to calling `Gateway::shutdown`
            shared.draining.store(true, Ordering::SeqCst);
            shared.signal_shutdown();
        }
        Frame::Infer { id, model, deadline_ms, input, trace_id } => {
            if shared.draining.load(Ordering::SeqCst) {
                let message = "gateway is draining".to_string();
                sync.push(Frame::Error { id, code: ErrorCode::Draining, message });
                return FrameOutcome { keep: true, submitted: false };
            }
            let batch = match input.into_batch() {
                Ok(b) => b,
                Err(e) => {
                    // declared-shape mismatch: framing is intact, so the
                    // session survives — reply typed and keep reading
                    shared.protocol_errors.inc();
                    sync.push(Frame::Error { id, code: ErrorCode::Protocol, message: e });
                    return FrameOutcome { keep: true, submitted: false };
                }
            };
            // trace resolution: a client-chosen wire id wins, otherwise
            // the seeded sampler decides.  A nonzero trace opens the
            // pending tree here, with the read/assemble work as its
            // first span (ending at this dispatch).
            let trace = if trace_id != 0 { trace_id } else { shared.collector.sample() };
            let t0 = Instant::now();
            if trace != 0 {
                let t0_us = trace::us_since_epoch(t0);
                let start = read_start_us.min(t0_us);
                shared.collector.begin(trace, &model, start);
                shared.collector.record(
                    trace,
                    Span::new(trace::SPAN_ASSEMBLE, trace::GATEWAY_TID, start, t0_us - start),
                );
            }
            let route = route.clone();
            let latency = Arc::clone(&shared.latency_us);
            let latency_hist = Arc::clone(&shared.request_latency);
            let collector = Arc::clone(&shared.collector);
            // 0 = no per-request deadline (the server default applies)
            let deadline =
                (deadline_ms > 0).then(|| Duration::from_millis(u64::from(deadline_ms)));
            let submitted =
                shared.handle.submit_routed_traced(&model, batch, deadline, trace, move |resp| {
                    latency.lock().unwrap().add(t0.elapsed().as_secs_f64() * 1e6);
                    latency_hist.observe(t0.elapsed().as_micros() as u64);
                    let frame = match resp.result {
                        Ok(logits) => Frame::InferOk {
                            id,
                            rows: logits.rows as u32,
                            cols: logits.cols as u32,
                            logits: logits.data,
                            faults_detected: resp.faults_detected,
                            worker: resp.worker as u32,
                            trace_id: trace,
                        },
                        Err(e) => {
                            // deadline/poison failures were force-completed
                            // server-side; other errors close the trace
                            // here (no reply flush will)
                            if trace != 0
                                && !matches!(
                                    e.kind,
                                    ServeErrorKind::DeadlineExceeded | ServeErrorKind::Poisoned
                                )
                            {
                                collector.complete(trace, trace::now_us());
                            }
                            Frame::Error { id, code: wire_code(e.kind), message: e.message }
                        }
                    };
                    route.deliver(frame);
                });
            match submitted {
                // the `admission` pipeline stage: batch validation through
                // coordinator accept (queueing starts after this); rejected
                // submissions don't count as admitted.  The admission span
                // is recorded from the very value the histogram observes.
                Ok(_) => {
                    let admission_us = t0.elapsed().as_micros() as u64;
                    shared.admission.observe(admission_us);
                    if trace != 0 {
                        shared.collector.record(
                            trace,
                            Span::new(
                                trace::SPAN_ADMISSION,
                                trace::GATEWAY_TID,
                                trace::us_since_epoch(t0),
                                admission_us,
                            ),
                        );
                    }
                    return FrameOutcome { keep: true, submitted: true };
                }
                Err(e) => {
                    if trace != 0 {
                        shared.collector.complete(trace, trace::now_us());
                    }
                    sync.push(Frame::Error { id, code: ErrorCode::Internal, message: e });
                }
            }
        }
        // a reply kind arriving at the server is a client bug
        other => {
            shared.protocol_errors.inc();
            let message = "reply frame sent to server".to_string();
            sync.push(Frame::Error { id: other.id(), code: ErrorCode::Protocol, message });
            return FrameOutcome { keep: false, submitted: false };
        }
    }
    FrameOutcome { keep: true, submitted: false }
}

/// Minimal HTTP/1.1 responder for metrics scrapes and health probes.
/// The 4-byte method sniff (`b"GET "` / `b"HEAD"`) has already been
/// consumed; everything up to the blank line is read (bounded) and only
/// the request target matters.  `HEAD` writes the status line + headers
/// and no body.  Every request emits one structured access-log line
/// (path, status, bytes, micros — JSON-native under
/// `RNS_LOG_FORMAT=json`).
///
/// Paths (all admission-exempt — observability must work *especially*
/// under overload):
///   * `/metrics` — live report; `?format=prometheus` for exposition
///   * `/healthz` — liveness: 200 while the process serves HTTP at all
///   * `/readyz` — readiness: 503 while draining or after coordinator
///     shutdown, 200 otherwise
///   * `/trace` — span-trace summary; `?format=chrome` for Chrome
///     trace-event JSON (load in Perfetto / `chrome://tracing`)
pub(crate) fn serve_http(mut stream: TcpStream, shared: &Arc<GatewayShared>, is_head: bool) {
    // every HTTP request counts as a scrape, hit or miss, GET or HEAD
    shared.scrapes.inc();
    let t0 = Instant::now();
    let mut head = Vec::new();
    let mut tmp = [0u8; 512];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") {
        if head.len() > MAX_HTTP_HEAD {
            return;
        }
        match stream.read(&mut tmp) {
            Ok(0) | Err(_) => break,
            Ok(n) => head.extend_from_slice(&tmp[..n]),
        }
    }
    // the 4-byte method sniff already consumed "GET " / "HEAD", so the
    // remaining head starts at (or just before) the request target
    let text = String::from_utf8_lossy(&head);
    let target = text.split_whitespace().next().unwrap_or("");
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let chrome = query.split('&').any(|kv| kv == "format=chrome");
    let (status, content_type, body) = match path {
        "/metrics" => {
            if query.split('&').any(|kv| kv == "format=prometheus") {
                // Prometheus text exposition format 0.0.4
                (200, "text/plain; version=0.0.4", shared.prometheus_report())
            } else {
                (200, "text/plain; charset=utf-8", format!("{}\n", shared.report()))
            }
        }
        "/healthz" => (200, "text/plain; charset=utf-8", "ok\n".to_string()),
        "/readyz" => {
            if shared.draining.load(Ordering::SeqCst) || !shared.handle.is_serving() {
                (503, "text/plain; charset=utf-8", "draining\n".to_string())
            } else {
                (200, "text/plain; charset=utf-8", "ready\n".to_string())
            }
        }
        "/trace" if chrome => (200, "application/json", shared.collector.chrome_json()),
        "/trace" => (200, "text/plain; charset=utf-8", shared.collector.summary()),
        _ => {
            shared.not_found.inc();
            (
                404,
                "text/plain; charset=utf-8",
                format!("no such path `{path}` (try /metrics, /healthz, /readyz, /trace)\n"),
            )
        }
    };
    let reason = match status {
        200 => "200 OK",
        503 => "503 Service Unavailable",
        _ => "404 Not Found",
    };
    let resp = format!(
        "HTTP/1.1 {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(resp.as_bytes()).ok();
    if !is_head {
        stream.write_all(body.as_bytes()).ok();
    }
    stream.shutdown(Shutdown::Both).ok();
    emit_fields(
        Level::Info,
        "gateway",
        "http",
        &[
            ("path", FieldValue::Text(path.to_string())),
            ("status", FieldValue::Num(status)),
            ("bytes", FieldValue::Num(body.len() as u64)),
            ("micros", FieldValue::Num(t0.elapsed().as_micros() as u64)),
        ],
    );
}
