//! Execution engines for the per-channel modular matmul — the compute
//! hot-spot the paper puts on analog hardware.
//!
//! Two interchangeable backends sit behind `ModularGemmEngine`:
//!   * `NativeEngine` — exact i64 + Barrett modular GEMM in rust.  Used by
//!     the large accuracy sweeps (fast, no shape constraints).
//!   * `PjrtEngine` (pjrt.rs) — loads the AOT-compiled pallas kernel from
//!     `artifacts/rns_mvm_b*.hlo.txt` and executes it on the PJRT CPU
//!     client.  Proves the three-layer composition end-to-end.
//!
//! The two are bit-identical by construction (the pallas kernel's blocked
//! f32 accumulation is exact below 2^24 — see DESIGN.md §7), which the
//! integration tests assert.

use crate::tensor::gemm::gemm_mod;
use crate::tensor::MatI;

/// Batched per-channel modular matmul: for each channel i,
/// `out[i] = (x_res[i] @ w_res[i]) mod moduli[i]`.
/// NOTE: not `Send` — the PJRT client wraps thread-local FFI state, so
/// engines must be constructed inside the thread that uses them (the
/// coordinator's worker threads each build their own engine).
pub trait ModularGemmEngine {
    /// `x_res[i]`: (B, K) residues; `w_res[i]`: (K, N) residues.
    fn matmul_mod(&mut self, x_res: &[MatI], w_res: &[MatI], moduli: &[u64]) -> Vec<MatI>;

    /// Human-readable backend name (for reports/metrics).
    fn name(&self) -> &'static str;
}

/// Pure-rust exact modular GEMM engine.
#[derive(Default)]
pub struct NativeEngine;

impl ModularGemmEngine for NativeEngine {
    fn matmul_mod(&mut self, x_res: &[MatI], w_res: &[MatI], moduli: &[u64]) -> Vec<MatI> {
        assert_eq!(x_res.len(), moduli.len());
        assert_eq!(w_res.len(), moduli.len());
        moduli
            .iter()
            .zip(x_res.iter().zip(w_res))
            .map(|(&m, (x, w))| gemm_mod(x, w, m))
            .collect()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::RnsContext;
    use crate::util::rng::Rng;

    #[test]
    fn native_engine_matches_crt_exactness() {
        let ctx = RnsContext::new(&[63, 62, 61, 59]).unwrap();
        let mut rng = Rng::seed_from(1);
        let (b, k, n) = (3usize, 64usize, 5usize);
        let x = MatI::from_vec(b, k, (0..b * k).map(|_| rng.gen_range_i64(-31, 31)).collect());
        let w = MatI::from_vec(k, n, (0..k * n).map(|_| rng.gen_range_i64(-31, 31)).collect());
        let xr: Vec<MatI> =
            ctx.moduli.iter().map(|&m| x.map(|v| v.rem_euclid(m as i64))).collect();
        let wr: Vec<MatI> =
            ctx.moduli.iter().map(|&m| w.map(|v| v.rem_euclid(m as i64))).collect();
        let mut eng = NativeEngine;
        let out = eng.matmul_mod(&xr, &wr, &ctx.moduli);
        // CRT across channels == exact integer matmul
        let exact = crate::tensor::gemm::gemm_i64(&x, &w);
        for r in 0..b {
            for c in 0..n {
                let res: Vec<u64> = out.iter().map(|ch| ch.at(r, c) as u64).collect();
                assert_eq!(ctx.crt_signed(&res), exact.at(r, c) as i128);
            }
        }
    }
}
