//! Execution engines for the per-channel modular matmul — the compute
//! hot-spot the paper puts on analog hardware.
//!
//! Two interchangeable backends sit behind `ModularGemmEngine`:
//!   * `NativeEngine` — exact i64 + Barrett modular GEMM in rust,
//!     parallelized across residue channels × batch-row blocks.  Shards
//!     run on a persistent `WorkerPool` (pool.rs) by default — threads
//!     spawned once and parked between calls — with the original per-call
//!     `std::thread::scope` fan-out kept as `SpawnMode::Scoped` for the
//!     bench baseline (the crate is dependency-free — no rayon).  Under
//!     the coordinator, engines instead borrow the process-wide shared
//!     pool through a `FabricHandle` (fabric.rs) so W workers share one
//!     set of fan-out threads under per-worker budgets.
//!     Used by the large accuracy sweeps (fast, no shape constraints).
//!   * `PjrtEngine` (pjrt.rs) — loads the AOT-compiled pallas kernel from
//!     `artifacts/rns_mvm_b*.hlo.txt` and executes it on the PJRT CPU
//!     client.  Proves the three-layer composition end-to-end.
//!
//! The two are bit-identical by construction (the pallas kernel's blocked
//! f32 accumulation is exact below 2^24 — see DESIGN.md §7), which the
//! integration tests assert.  Parallelism cannot change results either:
//! every channel/row-block task is exact modular arithmetic, so the output
//! is independent of scheduling — noise/ADC capture stays on the serial
//! side (`RnsCore`), keeping seeded runs deterministic.
//!
//! The same contract carries the two-tier RRNS decode that consumes these
//! engine outputs: whatever engine (or parallel schedule) produced the
//! per-channel tiles, `RnsCore` captures them serially and the batched
//! consistency pre-check + voting fallback sees one deterministic residue
//! stream — so prepared plans, parallel fan-out, and the decode fast path
//! compose without any cross-layer ordering assumptions.

use crate::runtime::fabric::FabricHandle;
use crate::runtime::plan::PreparedWeights;
use crate::runtime::pool::WorkerPool;
use crate::tensor::gemm::{gemm_mod, gemm_mod_staged};
use crate::tensor::MatI;

/// Batched per-channel modular matmul: for each channel i,
/// `out[i] = (x_res[i] @ w_res[i]) mod moduli[i]`.
/// NOTE: not `Send` — the PJRT client wraps thread-local FFI state, so
/// engines must be constructed inside the thread that uses them (the
/// coordinator's worker threads each build their own engine).
pub trait ModularGemmEngine {
    /// `x_res[i]`: (B, K) residues; `w_res[i]`: (K, N) residues.
    fn matmul_mod(&mut self, x_res: &[MatI], w_res: &[MatI], moduli: &[u64]) -> Vec<MatI>;

    /// Per-channel modular matmul against weights prepared once per layer
    /// (`RnsPlan` tile).  Default implementation falls back to the
    /// unprepared path through the plan's plain residue matrices, so
    /// engines like `PjrtEngine` keep working without a prepared kernel.
    fn matmul_mod_prepared(&mut self, x_res: &[MatI], w: &PreparedWeights) -> Vec<MatI> {
        self.matmul_mod(x_res, &w.res, &w.moduli)
    }

    /// Human-readable backend name (for reports/metrics).
    fn name(&self) -> &'static str;
}

/// Don't pay thread-spawn latency on tiles too small to amortize it
/// (~tens of µs per spawn vs ~1 MAC/ns serial throughput).
const PARALLEL_MAC_THRESHOLD: usize = 1 << 18;

/// Minimum MACs of work per spawned worker: the worker count shrinks on
/// small tiles so spawn cost stays a fraction of the compute it buys.
const MIN_MACS_PER_WORKER: usize = 1 << 17;

/// Scoped-spawn reference fan-out (`SpawnMode::Scoped`): `n_tasks` indexed
/// tasks on at most `workers` scoped threads pulling from a shared atomic
/// counter, spawned fresh per call.  Results come back in task order;
/// exactness of the tasks makes scheduling invisible.  The persistent
/// `WorkerPool` replaces this on the serving path; this stays as the
/// baseline the CI pool-vs-scoped no-regression gate compares against.
fn run_indexed<T, F>(workers: usize, n_tasks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n_tasks).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers.min(n_tasks).max(1))
            .map(|_| {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n_tasks {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("gemm worker panicked") {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|v| v.expect("every task ran")).collect()
}

/// How the native engine fans parallel work out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpawnMode {
    /// Persistent `WorkerPool` (default): threads spawned once, parked
    /// between calls — no spawn latency on the serving hot path.
    Pool,
    /// Per-call `std::thread::scope` spawns (the PR-1 behavior).  Kept as
    /// the bench baseline the CI no-regression gate compares against.
    Scoped,
}

/// Pure-rust exact modular GEMM engine.
pub struct NativeEngine {
    /// Worker-thread cap: 0 = auto (`RNS_NATIVE_THREADS` env var, else
    /// `available_parallelism`); 1 = force the serial reference path.
    /// Ignored when a fabric handle is attached (the handle's budget is
    /// the cap).
    pub threads: usize,
    mode: SpawnMode,
    /// Lazily created on the first parallel-eligible call, so serial
    /// engines and sub-threshold workloads never spawn a thread.  Never
    /// created when `fabric` is set — the fabric owns the threads.
    pool: Option<WorkerPool>,
    /// Shared process-wide fabric (the coordinator path): fan-outs go to
    /// the one shared pool under this worker's helper budget instead of
    /// a private per-engine pool.
    fabric: Option<FabricHandle>,
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine::with_spawn_mode(0, SpawnMode::Pool)
    }
}

impl NativeEngine {
    /// Serial reference engine (single-threaded, bit-identical to the
    /// parallel default — used by determinism tests and bench baselines).
    pub fn serial() -> Self {
        NativeEngine::with_spawn_mode(1, SpawnMode::Pool)
    }

    pub fn with_threads(threads: usize) -> Self {
        NativeEngine::with_spawn_mode(threads, SpawnMode::Pool)
    }

    /// Per-call scoped-spawn engine (auto thread count): the pre-pool
    /// execution model, for baselines and the CI regression pair.
    pub fn scoped() -> Self {
        NativeEngine::with_spawn_mode(0, SpawnMode::Scoped)
    }

    pub fn with_spawn_mode(threads: usize, mode: SpawnMode) -> Self {
        NativeEngine { threads, mode, pool: None, fabric: None }
    }

    /// Engine executing on the shared process-wide fabric: no private
    /// pool is ever created; fan-outs are submitted to the fabric's one
    /// `WorkerPool` under this worker's helper budget.  The coordinator
    /// builds one fabric at startup and hands every worker's engine a
    /// handle, so total fan-out threads stay bounded by cores − 1
    /// however many workers are configured.
    pub fn with_fabric(handle: FabricHandle) -> Self {
        NativeEngine { threads: 0, mode: SpawnMode::Pool, pool: None, fabric: Some(handle) }
    }

    /// Fan `n_tasks` out according to the spawn mode.  `threads` is the
    /// caller's already-resolved `effective_threads()` (the entry points
    /// also ran `reconcile_pool` with it).  `workers` caps the scoped
    /// path's spawns and the pool path's helper wake-ups (waking the
    /// whole pool for a small job is a thundering herd on many-core
    /// hosts; the atomic claim queue load-balances whoever shows up).
    fn run_tasks<T, F>(&mut self, threads: usize, workers: usize, n_tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        // shared-fabric path first: the fabric owns the threads, and the
        // handle's budget (already reflected in `threads` via
        // effective_threads) caps this job's helpers
        if let Some(handle) = self.fabric.clone() {
            return handle.run_collect(workers, n_tasks, f);
        }
        match self.mode {
            SpawnMode::Scoped => run_indexed(workers, n_tasks, f),
            SpawnMode::Pool => {
                let pool = self.pool.get_or_insert_with(|| WorkerPool::new(threads));
                // `workers` carries the MIN_MACS_PER_WORKER granularity:
                // admit only that many helpers, not the whole pool
                pool.run_collect_capped(workers, n_tasks, f)
            }
        }
    }

    /// Tear down a pool whose width no longer matches the configured
    /// thread cap (`threads` field edited or RNS_NATIVE_THREADS re-set
    /// after the pool was created): dropping joins the old helpers, so
    /// reconfiguration never leaks threads.  Called from every engine
    /// entry point — including the serial short-circuit, so shrinking
    /// the cap to 1 releases a previously-built multi-helper pool.
    fn reconcile_pool(&mut self, threads: usize) {
        if self.fabric.is_some() {
            return; // fabric engines never own a pool to reconcile
        }
        if self.pool.as_ref().is_some_and(|p| p.helper_threads() + 1 != threads) {
            self.pool = None;
        }
    }

    fn effective_threads(&self) -> usize {
        if let Some(handle) = &self.fabric {
            // this worker's slice of the shared fabric: budget helpers
            // plus the submitting thread
            return handle.concurrency();
        }
        if self.threads > 0 {
            return self.threads;
        }
        crate::runtime::fabric::default_total_threads()
    }
}

impl ModularGemmEngine for NativeEngine {
    fn matmul_mod(&mut self, x_res: &[MatI], w_res: &[MatI], moduli: &[u64]) -> Vec<MatI> {
        assert_eq!(x_res.len(), moduli.len());
        assert_eq!(w_res.len(), moduli.len());
        let threads = self.effective_threads();
        self.reconcile_pool(threads);
        let macs: usize =
            x_res.iter().zip(w_res).map(|(x, w)| x.rows * x.cols * w.cols).sum();
        if threads <= 1 || moduli.len() <= 1 || macs < PARALLEL_MAC_THRESHOLD {
            return moduli
                .iter()
                .zip(x_res.iter().zip(w_res))
                .map(|(&m, (x, w))| gemm_mod(x, w, m))
                .collect();
        }
        // channel-level parallelism: each task stages + runs one channel
        let workers = threads.min(macs / MIN_MACS_PER_WORKER).min(moduli.len()).max(2);
        self.run_tasks(threads, workers, moduli.len(), |ch| {
            gemm_mod(&x_res[ch], &w_res[ch], moduli[ch])
        })
    }

    fn matmul_mod_prepared(&mut self, x_res: &[MatI], w: &PreparedWeights) -> Vec<MatI> {
        let n_ch = w.moduli.len();
        assert_eq!(x_res.len(), n_ch);
        let b = x_res[0].rows;
        debug_assert!(x_res.iter().all(|x| x.rows == b && x.cols == w.rows));
        let threads = self.effective_threads();
        self.reconcile_pool(threads);
        let macs = b * w.rows * w.cols * n_ch;
        if threads <= 1 || macs < PARALLEL_MAC_THRESHOLD || b == 0 {
            return (0..n_ch)
                .map(|ch| gemm_mod_staged(&x_res[ch], &w.staged[ch], w.cols, w.moduli[ch]))
                .collect();
        }
        // worker count scaled to the work, never above the configured cap
        let workers = threads.min(macs / MIN_MACS_PER_WORKER).max(2);
        // channels × batch-row blocks, ~2 tasks per worker for balance
        let blocks = ((2 * workers + n_ch - 1) / n_ch).clamp(1, b);
        let rows_per = (b + blocks - 1) / blocks;
        let mut tasks: Vec<(usize, usize, usize)> = Vec::with_capacity(n_ch * blocks);
        for ch in 0..n_ch {
            let mut r0 = 0;
            while r0 < b {
                let r1 = (r0 + rows_per).min(b);
                tasks.push((ch, r0, r1));
                r0 = r1;
            }
        }
        let parts: Vec<(usize, usize, MatI)> = self.run_tasks(threads, workers, tasks.len(), |t| {
            let (ch, r0, r1) = tasks[t];
            let xt = x_res[ch].slice_rows(r0, r1);
            (ch, r0, gemm_mod_staged(&xt, &w.staged[ch], w.cols, w.moduli[ch]))
        });
        let mut out: Vec<MatI> = (0..n_ch).map(|_| MatI::zeros(b, w.cols)).collect();
        for (ch, r0, part) in parts {
            let dst = &mut out[ch].data[r0 * w.cols..r0 * w.cols + part.data.len()];
            dst.copy_from_slice(&part.data);
        }
        out
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::RnsContext;
    use crate::runtime::plan::PreparedWeights;
    use crate::util::rng::Rng;

    fn rand_residues(rng: &mut Rng, moduli: &[u64], rows: usize, cols: usize) -> Vec<MatI> {
        moduli
            .iter()
            .map(|&m| {
                MatI::from_vec(
                    rows,
                    cols,
                    (0..rows * cols).map(|_| rng.gen_range(m) as i64).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn native_engine_matches_crt_exactness() {
        let ctx = RnsContext::new(&[63, 62, 61, 59]).unwrap();
        let mut rng = Rng::seed_from(1);
        let (b, k, n) = (3usize, 64usize, 5usize);
        let x = MatI::from_vec(b, k, (0..b * k).map(|_| rng.gen_range_i64(-31, 31)).collect());
        let w = MatI::from_vec(k, n, (0..k * n).map(|_| rng.gen_range_i64(-31, 31)).collect());
        let xr: Vec<MatI> =
            ctx.moduli.iter().map(|&m| x.map(|v| v.rem_euclid(m as i64))).collect();
        let wr: Vec<MatI> =
            ctx.moduli.iter().map(|&m| w.map(|v| v.rem_euclid(m as i64))).collect();
        let mut eng = NativeEngine::default();
        let out = eng.matmul_mod(&xr, &wr, &ctx.moduli);
        // CRT across channels == exact integer matmul
        let exact = crate::tensor::gemm::gemm_i64(&x, &w);
        for r in 0..b {
            for c in 0..n {
                let res: Vec<u64> = out.iter().map(|ch| ch.at(r, c) as u64).collect();
                assert_eq!(ctx.crt_signed(&res), exact.at(r, c) as i128);
            }
        }
    }

    #[test]
    fn parallel_matches_serial_unprepared() {
        let moduli = [63u64, 62, 61, 59];
        let mut rng = Rng::seed_from(2);
        // large enough to clear PARALLEL_MAC_THRESHOLD
        let xr = rand_residues(&mut rng, &moduli, 16, 96);
        let wr = rand_residues(&mut rng, &moduli, 96, 64);
        let want = NativeEngine::serial().matmul_mod(&xr, &wr, &moduli);
        let got = NativeEngine::with_threads(4).matmul_mod(&xr, &wr, &moduli);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.data, w.data);
        }
    }

    #[test]
    fn prepared_matches_unprepared_all_engines_paths() {
        let moduli = [255u64, 254, 253];
        let mut rng = Rng::seed_from(3);
        for (b, k, n) in [(1usize, 17usize, 5usize), (16, 128, 96), (7, 64, 300)] {
            let xr = rand_residues(&mut rng, &moduli, b, k);
            let wr = rand_residues(&mut rng, &moduli, k, n);
            let prepared = PreparedWeights::new(wr.clone(), &moduli);
            let want = NativeEngine::serial().matmul_mod(&xr, &wr, &moduli);
            let serial = NativeEngine::serial().matmul_mod_prepared(&xr, &prepared);
            let parallel = NativeEngine::with_threads(4).matmul_mod_prepared(&xr, &prepared);
            for ((g, p), w) in serial.iter().zip(&parallel).zip(&want) {
                assert_eq!(g.data, w.data, "serial prepared ({b},{k},{n})");
                assert_eq!(p.data, w.data, "parallel prepared ({b},{k},{n})");
            }
        }
    }

    #[test]
    fn pool_and_scoped_spawn_modes_are_bit_identical() {
        let moduli = [255u64, 254, 253, 251];
        let mut rng = Rng::seed_from(5);
        // large enough to clear PARALLEL_MAC_THRESHOLD in both paths
        let xr = rand_residues(&mut rng, &moduli, 16, 128);
        let wr = rand_residues(&mut rng, &moduli, 128, 64);
        let prepared = PreparedWeights::new(wr.clone(), &moduli);
        let want = NativeEngine::serial().matmul_mod_prepared(&xr, &prepared);
        let mut pooled = NativeEngine::with_spawn_mode(4, SpawnMode::Pool);
        let mut scoped = NativeEngine::with_spawn_mode(4, SpawnMode::Scoped);
        // repeated calls exercise pool reuse (parked threads re-woken)
        for round in 0..3 {
            let p = pooled.matmul_mod_prepared(&xr, &prepared);
            let s = scoped.matmul_mod_prepared(&xr, &prepared);
            for ((p, s), w) in p.iter().zip(&s).zip(&want) {
                assert_eq!(p.data, w.data, "pool round {round}");
                assert_eq!(s.data, w.data, "scoped round {round}");
            }
        }
        // the unprepared path shares the same fan-out
        let pu = pooled.matmul_mod(&xr, &wr, &moduli);
        let wu = NativeEngine::serial().matmul_mod(&xr, &wr, &moduli);
        for (p, w) in pu.iter().zip(&wu) {
            assert_eq!(p.data, w.data);
        }
    }

    #[test]
    fn pool_resizes_when_thread_cap_changes() {
        let moduli = [255u64, 254, 253, 251];
        let mut rng = Rng::seed_from(6);
        let xr = rand_residues(&mut rng, &moduli, 16, 128);
        let wr = rand_residues(&mut rng, &moduli, 128, 64);
        let prepared = PreparedWeights::new(wr.clone(), &moduli);
        let want = NativeEngine::serial().matmul_mod_prepared(&xr, &prepared);
        let mut eng = NativeEngine::with_threads(4);
        let a = eng.matmul_mod_prepared(&xr, &prepared);
        assert_eq!(eng.pool.as_ref().unwrap().helper_threads(), 3);
        // reconfigure after the pool exists: the next call must rebuild
        // it at the new width instead of silently keeping the old one
        eng.threads = 2;
        let b = eng.matmul_mod_prepared(&xr, &prepared);
        assert_eq!(eng.pool.as_ref().unwrap().helper_threads(), 1);
        // shrinking to the serial path must release the pool's helpers
        // too, even though the serial branch never reaches run_tasks
        eng.threads = 1;
        let c = eng.matmul_mod_prepared(&xr, &prepared);
        assert!(eng.pool.is_none(), "serial cap must tear the pool down");
        for (((a, b), c), w) in a.iter().zip(&b).zip(&c).zip(&want) {
            assert_eq!(a.data, w.data);
            assert_eq!(b.data, w.data);
            assert_eq!(c.data, w.data);
        }
    }

    #[test]
    fn fabric_engine_matches_serial_and_owns_no_pool() {
        use crate::runtime::fabric::ExecutionFabric;
        use std::sync::Arc;
        let moduli = [255u64, 254, 253, 251];
        let mut rng = Rng::seed_from(7);
        let xr = rand_residues(&mut rng, &moduli, 16, 128);
        let wr = rand_residues(&mut rng, &moduli, 128, 64);
        let prepared = PreparedWeights::new(wr.clone(), &moduli);
        let want = NativeEngine::serial().matmul_mod_prepared(&xr, &prepared);
        let fabric = Arc::new(ExecutionFabric::with_threads(4, 2));
        let mut eng = NativeEngine::with_fabric(fabric.handle());
        for round in 0..3 {
            let got = eng.matmul_mod_prepared(&xr, &prepared);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.data, w.data, "fabric round {round}");
            }
        }
        let gu = eng.matmul_mod(&xr, &wr, &moduli);
        let wu = NativeEngine::serial().matmul_mod(&xr, &wr, &moduli);
        for (g, w) in gu.iter().zip(&wu) {
            assert_eq!(g.data, w.data);
        }
        // the fabric owns the threads: the engine never built a private
        // pool, and the fabric saw this engine's fan-outs
        assert!(eng.pool.is_none(), "fabric engine must not own a pool");
        assert!(fabric.stats().jobs > 0, "fan-outs must route through the fabric");
    }

    #[test]
    fn prepared_single_row_batch() {
        // b=1 cannot be split into row blocks; must still be correct
        let moduli = [63u64, 62];
        let mut rng = Rng::seed_from(4);
        let xr = rand_residues(&mut rng, &moduli, 1, 512);
        let wr = rand_residues(&mut rng, &moduli, 512, 512);
        let prepared = PreparedWeights::new(wr.clone(), &moduli);
        let want = NativeEngine::serial().matmul_mod(&xr, &wr, &moduli);
        let got = NativeEngine::with_threads(8).matmul_mod_prepared(&xr, &prepared);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.data, w.data);
        }
    }
}
