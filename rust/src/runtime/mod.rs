//! Runtime layer: the pluggable modular-GEMM engines (native rust and the
//! PJRT-loaded AOT pallas kernel), the persistent worker pool behind the
//! native engine, the process-wide execution fabric that shares one pool
//! across coordinator workers, prepared-layer execution plans, and the
//! artifact manifest loader.

pub mod engine;
pub mod fabric;
pub mod manifest;
pub mod pjrt;
pub mod plan;
pub mod pool;

pub use engine::{ModularGemmEngine, NativeEngine, SpawnMode};
pub use fabric::{ExecutionFabric, FabricHandle, FabricStats};
pub use manifest::Manifest;
pub use pjrt::{F32Input, PjrtEngine, PjrtExecutable, PjrtRuntime};
pub use plan::{PlanTile, PreparedWeights, RnsPlan};
pub use pool::WorkerPool;

/// Default artifacts directory (relative to the workspace root).
pub fn default_artifacts_dir() -> String {
    std::env::var("RNS_ARTIFACTS_DIR").unwrap_or_else(|_| {
        // when run via cargo, resolve relative to the manifest dir
        let manifest = env!("CARGO_MANIFEST_DIR");
        format!("{manifest}/artifacts")
    })
}
