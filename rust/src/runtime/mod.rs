//! Runtime layer: the pluggable modular-GEMM engines (native rust and the
//! PJRT-loaded AOT pallas kernel), prepared-layer execution plans, and the
//! artifact manifest loader.

pub mod engine;
pub mod manifest;
pub mod pjrt;
pub mod plan;

pub use engine::{ModularGemmEngine, NativeEngine};
pub use manifest::Manifest;
pub use pjrt::{F32Input, PjrtEngine, PjrtExecutable, PjrtRuntime};
pub use plan::{PlanTile, PreparedWeights, RnsPlan};

/// Default artifacts directory (relative to the workspace root).
pub fn default_artifacts_dir() -> String {
    std::env::var("RNS_ARTIFACTS_DIR").unwrap_or_else(|_| {
        // when run via cargo, resolve relative to the manifest dir
        let manifest = env!("CARGO_MANIFEST_DIR");
        format!("{manifest}/artifacts")
    })
}
