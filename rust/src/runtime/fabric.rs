//! Process-wide execution fabric: one shared `WorkerPool` for every
//! coordinator worker, with per-worker helper budgets.
//!
//! Before PR 4 each worker's `NativeEngine` lazily built a private
//! `WorkerPool`, so a coordinator with W workers parked
//! W × (threads − 1) helper threads machine-wide — harmless while
//! parked, but an oversubscription the moment several workers fan out at
//! once, and a thread-count footprint that grew with W instead of with
//! the machine.  The fabric inverts the ownership: the `Coordinator`
//! builds **one** `ExecutionFabric` at startup (pool width =
//! `RNS_NATIVE_THREADS` or `available_parallelism`, so parked helpers
//! are bounded by cores − 1 regardless of W) and hands every worker a
//! `FabricHandle`.
//!
//! Fairness comes from the *budget*: each handle caps how many helpers
//! any single GEMM job may claim (`ceil(helpers / W)`), so W concurrent
//! jobs interleave on the shared claim queue instead of the first
//! submitter grabbing the whole pool.  Deadlock cannot happen: the
//! submitting worker always participates in its own job's claim loop
//! (see `pool.rs`), so a job never waits on helpers that never come —
//! worst case it runs serial on its own thread.
//!
//! The fabric also keeps utilization counters (jobs/tasks routed through
//! it) that the coordinator surfaces in the shutdown report.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::runtime::pool::WorkerPool;

/// Snapshot of a fabric's shape and traffic (serving report / tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FabricStats {
    /// Helper threads the shared pool spawned (≤ total_threads − 1, the
    /// process-wide bound the oversubscription test asserts).
    pub helper_threads: usize,
    /// Configured total concurrency (helpers + one submitter slot).
    pub total_threads: usize,
    /// Worker count the budget was derived for.
    pub workers: usize,
    /// Helpers any single job may claim (per-worker budget).
    pub budget: usize,
    /// Jobs routed through the fabric (one per parallel-eligible GEMM
    /// fan-out).
    pub jobs: u64,
    /// Indexed tasks those jobs carried.
    pub tasks: u64,
}

/// The shared state behind a fabric and all of its handles.
struct FabricInner {
    pool: WorkerPool,
    total_threads: usize,
    workers: usize,
    budget: usize,
    jobs: AtomicU64,
    tasks: AtomicU64,
}

impl FabricInner {
    fn stats(&self) -> FabricStats {
        FabricStats {
            helper_threads: self.pool.helper_threads(),
            total_threads: self.total_threads,
            workers: self.workers,
            budget: self.budget,
            jobs: self.jobs.load(Ordering::Relaxed),
            tasks: self.tasks.load(Ordering::Relaxed),
        }
    }
}

/// One shared pool + the budget math, built once per process (by the
/// coordinator) and handed out as cheap `FabricHandle` clones.  The
/// fabric itself is an `Arc` shell, so `handle(&self)` works behind any
/// ownership (plain value, `Arc<ExecutionFabric>`, borrowed field).
pub struct ExecutionFabric {
    inner: Arc<FabricInner>,
}

impl ExecutionFabric {
    /// Fabric for `workers` concurrent submitters at the machine-derived
    /// width: `RNS_NATIVE_THREADS` if set (the process-wide thread
    /// budget — no longer per worker), else `available_parallelism`.
    pub fn for_workers(workers: usize) -> Self {
        Self::with_threads(default_total_threads(), workers)
    }

    /// Fabric with an explicit total concurrency (tests, benches).
    /// Spawns the pool's `total_threads − 1` helpers eagerly — the
    /// fabric exists to own the process's fan-out threads, so its
    /// footprint is visible (and assertable) from construction.
    pub fn with_threads(total_threads: usize, workers: usize) -> Self {
        let total = total_threads.max(1);
        let workers = workers.max(1);
        let helpers = total - 1;
        // each worker's slice of the helpers, rounded up so small pools
        // still parallelize: W concurrent jobs may transiently claim up
        // to W * budget >= helpers, which the pool resolves by admission
        // order — the bound that matters (spawned threads) stays helpers
        let budget = if helpers == 0 { 0 } else { helpers.div_ceil(workers) };
        ExecutionFabric {
            inner: Arc::new(FabricInner {
                pool: WorkerPool::new(total),
                total_threads: total,
                workers,
                budget,
                jobs: AtomicU64::new(0),
                tasks: AtomicU64::new(0),
            }),
        }
    }

    /// A handle for one worker's engine (cheap `Arc` clone).
    pub fn handle(&self) -> FabricHandle {
        FabricHandle { fabric: Arc::clone(&self.inner) }
    }

    /// Helper threads the shared pool actually spawned.
    pub fn helper_threads(&self) -> usize {
        self.inner.pool.helper_threads()
    }

    pub fn stats(&self) -> FabricStats {
        self.inner.stats()
    }
}

/// One worker's view of the shared fabric: the pool plus that worker's
/// helper budget.  Handed to `NativeEngine::with_fabric`.
#[derive(Clone)]
pub struct FabricHandle {
    fabric: Arc<FabricInner>,
}

impl FabricHandle {
    /// Concurrency one job sees: this worker's helper budget plus the
    /// submitting thread itself.  The engine uses this where a private
    /// engine would use its thread cap (parallel thresholds, task
    /// granularity).
    pub fn concurrency(&self) -> usize {
        self.fabric.budget + 1
    }

    pub fn stats(&self) -> FabricStats {
        self.fabric.stats()
    }

    /// Fan `n_tasks` out on the shared pool under this worker's budget.
    /// `cap` is the caller's own concurrency bound (task granularity);
    /// the effective helper budget is the smaller of the two.
    pub fn run_collect<T, F>(&self, cap: usize, n_tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.fabric.jobs.fetch_add(1, Ordering::Relaxed);
        self.fabric.tasks.fetch_add(n_tasks as u64, Ordering::Relaxed);
        self.fabric.pool.run_collect_capped(cap.min(self.concurrency()), n_tasks, f)
    }
}

/// Process-wide thread budget: `RNS_NATIVE_THREADS` (total, not per
/// worker) if set and positive, else the machine's core count.  The one
/// definition shared by the fabric, the private-pool engine's auto
/// sizing, and the oversubscription test.
pub fn default_total_threads() -> usize {
    if let Ok(v) = std::env::var("RNS_NATIVE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_math_splits_helpers_across_workers() {
        // 9 total threads = 8 helpers; 4 workers get ceil(8/4) = 2 each
        let f = ExecutionFabric::with_threads(9, 4);
        let s = f.stats();
        assert_eq!(s.helper_threads, 8);
        assert_eq!(s.budget, 2);
        assert_eq!(s.workers, 4);
        // more workers than helpers: everyone still gets one helper slot
        let f = ExecutionFabric::with_threads(3, 8);
        assert_eq!(f.stats().budget, 1);
        // serial fabric: no helpers, budget zero, handles run inline
        let f = Arc::new(ExecutionFabric::with_threads(1, 4));
        assert_eq!(f.stats().helper_threads, 0);
        assert_eq!(f.handle().concurrency(), 1);
        assert_eq!(f.handle().run_collect(4, 5, |i| i * 3), vec![0, 3, 6, 9, 12]);
    }

    #[test]
    fn handles_share_one_pool_and_count_traffic() {
        let f = Arc::new(ExecutionFabric::with_threads(4, 2));
        let a = f.handle();
        let b = f.handle();
        assert_eq!(a.concurrency(), 3); // ceil(3 helpers / 2 workers) + self
        let ra = a.run_collect(8, 10, |i| i + 1);
        let rb = b.run_collect(8, 6, |i| i * 2);
        assert_eq!(ra, (1..=10).collect::<Vec<_>>());
        assert_eq!(rb, (0..6).map(|i| i * 2).collect::<Vec<_>>());
        let s = f.stats();
        assert_eq!(s.jobs, 2);
        assert_eq!(s.tasks, 16);
        assert_eq!(s.helper_threads, 3, "one pool, not one per handle");
    }

    #[test]
    fn concurrent_handles_interleave_without_deadlock() {
        let f = Arc::new(ExecutionFabric::with_threads(4, 4)); // budget 1 each
        std::thread::scope(|s| {
            for t in 0..4usize {
                let h = f.handle();
                s.spawn(move || {
                    for round in 0..40usize {
                        let n = 1 + (t + round) % 7;
                        let out = h.run_collect(h.concurrency(), n, |i| i + 10 * t);
                        for (i, v) in out.iter().enumerate() {
                            assert_eq!(*v, i + 10 * t, "worker {t} round {round}");
                        }
                    }
                });
            }
        });
        assert_eq!(f.stats().jobs, 160);
    }
}
