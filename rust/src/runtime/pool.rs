//! Persistent worker pool for the native modular-GEMM engine.
//!
//! The PR-1 parallel engine fanned every prepared GEMM out with
//! `std::thread::scope`, paying thread-spawn latency (tens of µs per
//! worker) on every call — acceptable for sweep workloads, dominant for
//! small-batch serving where a whole MLP layer is only a few hundred µs.
//! `WorkerPool` keeps the fan-out threads alive across calls: workers
//! park on a condvar between jobs and are unparked when a new job
//! generation is published, so steady-state dispatch cost is one
//! lock + notify instead of N spawns.
//!
//! A job is an indexed task set `f(0..n_tasks)` claimed from a shared
//! atomic counter (the same lock-free claim discipline the scoped path
//! uses); the submitting thread participates in the claim loop, then
//! blocks until every claimed task has completed.  Because the submitter
//! cannot return before `completed == n_tasks`, tasks may safely borrow
//! the submitter's stack (activations, prepared weights) even though the
//! pool threads are long-lived — that is the single safety invariant the
//! one `unsafe` lifetime erasure below relies on.
//!
//! Determinism: the pool schedules *which thread* runs a task, never what
//! the task computes — engine tasks are exact modular arithmetic keyed by
//! task index, so outputs are bit-identical to the serial and scoped
//! paths (asserted by `tests/integration_store.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Lifetime-erased `&(dyn Fn(usize) + Sync)`.
///
/// Safety contract: the pointee outlives every dereference because
/// `WorkerPool::run` blocks until `completed == n_tasks`, and a worker
/// only dereferences after claiming an index `< n_tasks` — each such
/// claim completes (and is counted) before `run` can return.
struct TaskRef(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

/// One published fan-out: the erased task plus claim/completion counters.
struct Job {
    task: TaskRef,
    n_tasks: usize,
    next: AtomicUsize,
    completed: AtomicUsize,
}

impl Job {
    /// Claim and run tasks until the queue is exhausted.  The last
    /// completer wakes the submitter.
    fn run_tasks(&self, shared: &PoolShared) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_tasks {
                return;
            }
            // SAFETY: i < n_tasks, so the submitter is still blocked in
            // `run` and the borrow behind the pointer is alive (see
            // `TaskRef`).
            let f = unsafe { &*self.task.0 };
            f(i);
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.n_tasks {
                // lock before notify so the submitter cannot check the
                // counter and sleep between our increment and our wake
                let _guard = shared.state.lock().unwrap();
                shared.done.notify_all();
            }
        }
    }
}

struct PoolState {
    shutdown: bool,
    /// Bumped once per published job; workers use it to tell a fresh job
    /// from the one they already drained.
    generation: u64,
    job: Option<Arc<Job>>,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The submitter parks here until the job completes.
    done: Condvar,
}

/// Long-lived fan-out threads with a parked-idle loop.  Owned by
/// `NativeEngine`; dropped (and joined) with it.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Serializes submitters: one job in flight at a time.
    submit: Mutex<()>,
    threads: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// A pool sized for `threads` total concurrency: `threads - 1` parked
    /// helper threads plus the submitting thread, which always
    /// participates in the claim loop.  `threads <= 1` spawns nothing and
    /// `run` degenerates to an inline serial loop.
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { shutdown: false, generation: 0, job: None }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..threads.saturating_sub(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rns-pool-{i}"))
                    .spawn(move || pool_worker(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, submit: Mutex::new(()), threads: handles }
    }

    /// Helper threads kept parked between jobs (total concurrency is one
    /// more: the submitter works too).
    pub fn helper_threads(&self) -> usize {
        self.threads.len()
    }

    /// Run `n_tasks` indexed tasks across the pool and block until all
    /// complete.  The closure may borrow the caller's stack.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        if self.threads.is_empty() {
            for i in 0..n_tasks {
                f(i);
            }
            return;
        }
        let _submit = self.submit.lock().unwrap();
        let job = Arc::new(Job {
            task: TaskRef(f as *const (dyn Fn(usize) + Sync)),
            n_tasks,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.generation = st.generation.wrapping_add(1);
            st.job = Some(Arc::clone(&job));
            self.shared.work.notify_all();
        }
        // the submitter is also a worker — a 1-task job never even needs
        // a helper wakeup to have finished by the wait below
        job.run_tasks(&self.shared);
        let mut st = self.shared.state.lock().unwrap();
        while job.completed.load(Ordering::Acquire) < n_tasks {
            st = self.shared.done.wait(st).unwrap();
        }
        // drop the erased pointer before `f`'s borrow can end; helpers
        // holding stale `Arc<Job>` clones only see an exhausted counter
        st.job = None;
    }

    /// Run tasks that each produce a value; results come back in task
    /// order.  Per-slot mutexes are uncontended (each task owns its
    /// slot) — they exist to keep the fan-out free of `unsafe` beyond
    /// the one lifetime erasure in `run`.
    pub fn run_collect<T, F>(&self, n_tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let slots: Vec<Mutex<Option<T>>> = (0..n_tasks).map(|_| Mutex::new(None)).collect();
        self.run(n_tasks, &|i| {
            *slots[i].lock().unwrap() = Some(f(i));
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("every task ran"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for t in self.threads.drain(..) {
            t.join().ok();
        }
    }
}

fn pool_worker(shared: Arc<PoolShared>) {
    let mut last_gen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != last_gen {
                    if let Some(job) = &st.job {
                        last_gen = st.generation;
                        break Arc::clone(job);
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        job.run_tasks(&shared);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        let n = 37;
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.run(n, &|i| {
            counts[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "task {i}");
        }
    }

    #[test]
    fn collect_returns_results_in_task_order() {
        let pool = WorkerPool::new(3);
        let out = pool.run_collect(25, |i| i * i);
        assert_eq!(out, (0..25).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn reused_across_many_jobs() {
        // many small jobs through one pool: exercises the generation
        // handshake (a stale worker must never re-run or miss a job)
        let pool = WorkerPool::new(4);
        for round in 0..200usize {
            let sum = AtomicU64::new(0);
            let n = 1 + round % 7;
            pool.run(n, &|i| {
                sum.fetch_add((round + i) as u64, Ordering::Relaxed);
            });
            let want: u64 = (0..n).map(|i| (round + i) as u64).sum();
            assert_eq!(sum.load(Ordering::SeqCst), want, "round {round}");
        }
    }

    #[test]
    fn tasks_borrow_caller_stack() {
        let pool = WorkerPool::new(4);
        let input: Vec<u64> = (0..64).collect();
        let out = pool.run_collect(input.len(), |i| input[i] * 2);
        assert_eq!(out[63], 126);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.helper_threads(), 0);
        assert_eq!(pool.run_collect(5, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_tasks_is_a_no_op() {
        let pool = WorkerPool::new(2);
        pool.run(0, &|_| panic!("no task should run"));
        let empty: Vec<usize> = pool.run_collect(0, |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(8);
        pool.run(16, &|_| {});
        drop(pool); // must not hang or leak parked threads
    }
}
