//! Persistent worker pool for the native modular-GEMM engine.
//!
//! The PR-1 parallel engine fanned every prepared GEMM out with
//! `std::thread::scope`, paying thread-spawn latency (tens of µs per
//! worker) on every call — acceptable for sweep workloads, dominant for
//! small-batch serving where a whole MLP layer is only a few hundred µs.
//! `WorkerPool` keeps the fan-out threads alive across calls: workers
//! park on a condvar between jobs and are unparked when a job is
//! published, so steady-state dispatch cost is one lock + notify instead
//! of N spawns.
//!
//! Since PR 4 the pool is **multi-tenant**: several submitters may have
//! jobs in flight at once (the process-wide execution fabric hands one
//! pool to every coordinator worker — see `runtime/fabric.rs`).  The
//! shared state holds a list of active jobs; parked helpers scan it for
//! a job with both unclaimed tasks and helper *budget* remaining
//! (`helper_cap`, the per-job claim limit that keeps one worker's GEMM
//! from monopolizing the pool), claim indexed tasks from its atomic
//! counter, and go back to scanning when it drains.  The submitting
//! thread always participates in its own job's claim loop — which is the
//! deadlock-freedom argument: a job can never wait on helpers that never
//! come, because even with every helper busy elsewhere the submitter
//! drains its own queue and only then blocks on the completion count.
//!
//! A job is an indexed task set `f(0..n_tasks)` claimed from a shared
//! atomic counter (the same lock-free claim discipline the scoped path
//! uses); the submitter blocks until every claimed task has completed.
//! Because the submitter cannot return before `completed == n_tasks`,
//! tasks may safely borrow the submitter's stack (activations, prepared
//! weights) even though the pool threads are long-lived — that is the
//! single safety invariant the one `unsafe` lifetime erasure below
//! relies on.
//!
//! Panics do not weaken that invariant: every task runs under
//! `catch_unwind`, so a panicking task still counts toward `completed`
//! (no helper dies mid-job, no submitter waits forever), and `run` holds
//! a drop guard that waits for the full completion count even while
//! unwinding, so the erased borrow can never dangle.  The first panic
//! payload is re-thrown on the submitting thread once the job has fully
//! drained — the same observable behavior as `std::thread::scope`.
//!
//! Determinism: the pool schedules *which thread* runs a task, never what
//! the task computes — engine tasks are exact modular arithmetic keyed by
//! task index, so outputs are bit-identical to the serial and scoped
//! paths (asserted by `tests/integration_store.rs` and
//! `tests/integration_fabric.rs`).

use std::any::Any;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Poison-tolerant lock: the pool's mutexes guard plain state whose
/// invariants are re-established under the lock, and task panics are
/// re-thrown on submitter threads that may hold these locks — treating
/// poison as fatal would turn one propagated panic into a dead pool.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Lifetime-erased `&(dyn Fn(usize) + Sync)`.
///
/// Safety contract: the pointee outlives every dereference because
/// `WorkerPool::run` blocks until `completed == n_tasks` — on the normal
/// path and, via a drop guard, while unwinding — and a worker only
/// dereferences after claiming an index `< n_tasks`; each such claim
/// completes (and is counted, panic or not) before `run` can return.
struct TaskRef(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

/// One published fan-out: the erased task, claim/completion counters, and
/// the helper budget that bounds how many pool threads may work on it.
struct Job {
    task: TaskRef,
    n_tasks: usize,
    next: AtomicUsize,
    completed: AtomicUsize,
    /// Helpers allowed to claim from this job concurrently (the
    /// submitter participates on top of this, so total claimants are
    /// bounded by `helper_cap + 1`).  This is the per-worker budget of
    /// the shared fabric: one worker's GEMM cannot starve the others.
    helper_cap: usize,
    /// Helpers currently claiming from this job; admission (the
    /// increment) happens under the pool state lock.
    helpers_active: AtomicUsize,
    /// First panic payload from any task; re-thrown on the submitter
    /// after the job fully drains.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Job {
    /// Claim and run tasks until the queue is exhausted.  The last
    /// completer wakes the submitters parked on `done`.
    fn run_tasks(&self, shared: &PoolShared) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_tasks {
                return;
            }
            // SAFETY: i < n_tasks, so the submitter is still blocked in
            // `run` and the borrow behind the pointer is alive (see
            // `TaskRef`).
            let f = unsafe { &*self.task.0 };
            // A panicking task must still count as completed: a helper
            // that unwound out of here would die before incrementing
            // `completed`, leaving the submitter waiting forever; a
            // submitter that unwound would drop the borrow while helpers
            // still execute through the erased pointer.
            if let Err(payload) = panic::catch_unwind(AssertUnwindSafe(|| f(i))) {
                let mut slot = lock_ignore_poison(&self.panic);
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.n_tasks {
                // lock before notify so a submitter cannot check the
                // counter and sleep between our increment and our wake
                let _guard = lock_ignore_poison(&shared.state);
                shared.done.notify_all();
            }
        }
    }

    /// Whether unclaimed task indices remain (helper eligibility check;
    /// approximate outside the state lock, exact enough because a false
    /// positive only costs one wasted claim attempt).
    fn has_unclaimed(&self) -> bool {
        self.next.load(Ordering::Relaxed) < self.n_tasks
    }
}

/// Blocks in `drop` until the job's completion count reaches `n_tasks`,
/// then unpublishes it from the active-job list.  Held by `run` across
/// the claim loop so that no unwind path can end the borrow behind
/// `TaskRef` while a helper might still dereference it.
struct CompletionGuard<'a> {
    job: &'a Arc<Job>,
    shared: &'a PoolShared,
}

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        let mut st = lock_ignore_poison(&self.shared.state);
        while self.job.completed.load(Ordering::Acquire) < self.job.n_tasks {
            st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        // drop the erased pointer before `f`'s borrow can end; helpers
        // holding stale `Arc<Job>` clones only see an exhausted counter
        st.jobs.retain(|j| !Arc::ptr_eq(j, self.job));
    }
}

struct PoolState {
    shutdown: bool,
    /// Active jobs in submission order.  Helpers scan for the first job
    /// with unclaimed tasks and helper budget, so earlier submitters get
    /// helpers first while later jobs still make progress through their
    /// own submitters (and pick up helpers as earlier jobs drain).
    jobs: Vec<Arc<Job>>,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// Submitters park here until their job completes.
    done: Condvar,
}

/// Long-lived fan-out threads with a parked-idle loop and a multi-job
/// claim queue.  Owned by a `NativeEngine` (private pool) or shared
/// process-wide through `runtime/fabric.rs`; dropped (and joined) with
/// its owner.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    threads: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// A pool sized for `threads` total concurrency: `threads - 1` parked
    /// helper threads plus a submitting thread, which always participates
    /// in its own job's claim loop.  `threads <= 1` spawns nothing and
    /// `run` degenerates to an inline serial loop.
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { shutdown: false, jobs: Vec::new() }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..threads.saturating_sub(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rns-pool-{i}"))
                    .spawn(move || pool_worker(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, threads: handles }
    }

    /// Helper threads kept parked between jobs (total concurrency is one
    /// more per submitter: submitters work too).
    pub fn helper_threads(&self) -> usize {
        self.threads.len()
    }

    /// Run `n_tasks` indexed tasks across the pool and block until all
    /// complete.  The closure may borrow the caller's stack.  A panicking
    /// task does not tear the pool down: the job still drains fully and
    /// the first panic is re-thrown here, on the submitting thread.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        self.run_capped(usize::MAX, n_tasks, f);
    }

    /// `run` with a concurrency budget: at most `cap - 1` helpers may
    /// claim tasks from this job (the submitter is the cap's remaining
    /// slot).  On a shared pool this is what keeps W submitters fair —
    /// each job wakes and admits only its budget, so concurrent jobs
    /// interleave instead of the first one grabbing every helper.  The
    /// budget never blocks completion: however few helpers show up, the
    /// submitter participates and the job always drains.
    pub fn run_capped(&self, cap: usize, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        let helper_cap = cap
            .max(1)
            .saturating_sub(1)
            .min(n_tasks.saturating_sub(1))
            .min(self.threads.len());
        if helper_cap == 0 {
            // no helpers to use (serial pool, single task, or a budget of
            // one): run inline without touching the shared queue
            for i in 0..n_tasks {
                f(i);
            }
            return;
        }
        let job = Arc::new(Job {
            task: TaskRef(f as *const (dyn Fn(usize) + Sync)),
            n_tasks,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            helper_cap,
            helpers_active: AtomicUsize::new(0),
            panic: Mutex::new(None),
        });
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.jobs.push(Arc::clone(&job));
            // wake only as many parked helpers as the budget admits —
            // waking the whole pool for a small job would thundering-herd
            // every helper through the state mutex just to find either
            // the claim counter exhausted or the budget spent
            if helper_cap >= self.threads.len() {
                self.shared.work.notify_all();
            } else {
                for _ in 0..helper_cap {
                    self.shared.work.notify_one();
                }
            }
        }
        // from publication until the completion count reaches n_tasks,
        // helpers may dereference the erased borrow of `f`; the guard
        // waits that out on every exit path, including unwinding
        let guard = CompletionGuard { job: &job, shared: &self.shared };
        // the submitter is also a worker — a job never depends on a
        // helper wakeup to finish
        job.run_tasks(&self.shared);
        drop(guard);
        if let Some(payload) = lock_ignore_poison(&job.panic).take() {
            panic::resume_unwind(payload);
        }
    }

    /// Run tasks that each produce a value; results come back in task
    /// order.  Per-slot mutexes are uncontended (each task owns its
    /// slot) — they exist to keep the fan-out free of `unsafe` beyond
    /// the one lifetime erasure in `run`.
    pub fn run_collect<T, F>(&self, n_tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.run_collect_capped(usize::MAX, n_tasks, f)
    }

    /// `run_collect` with the `run_capped` helper budget.
    pub fn run_collect_capped<T, F>(&self, cap: usize, n_tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let slots: Vec<Mutex<Option<T>>> = (0..n_tasks).map(|_| Mutex::new(None)).collect();
        self.run_capped(cap, n_tasks, &|i| {
            *slots[i].lock().unwrap() = Some(f(i));
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("every task ran"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for t in self.threads.drain(..) {
            t.join().ok();
        }
    }
}

fn pool_worker(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut st = lock_ignore_poison(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                // first job with unclaimed tasks and budget; admission is
                // under the state lock, so a job never exceeds its
                // helper_cap concurrent helpers
                let eligible = st.jobs.iter().find(|j| {
                    j.has_unclaimed() && j.helpers_active.load(Ordering::Relaxed) < j.helper_cap
                });
                if let Some(j) = eligible {
                    let j = Arc::clone(j);
                    j.helpers_active.fetch_add(1, Ordering::Relaxed);
                    break j;
                }
                st = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        job.run_tasks(&shared);
        job.helpers_active.fetch_sub(1, Ordering::Relaxed);
        // loop back and rescan: another submitter's job may be waiting
        // for a helper slot that just freed up
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        let n = 37;
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.run(n, &|i| {
            counts[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "task {i}");
        }
    }

    #[test]
    fn collect_returns_results_in_task_order() {
        let pool = WorkerPool::new(3);
        let out = pool.run_collect(25, |i| i * i);
        assert_eq!(out, (0..25).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn reused_across_many_jobs() {
        // many small jobs through one pool: a stale worker must never
        // re-run or miss a job across publish/drain cycles
        let pool = WorkerPool::new(4);
        for round in 0..200usize {
            let sum = AtomicU64::new(0);
            let n = 1 + round % 7;
            pool.run(n, &|i| {
                sum.fetch_add((round + i) as u64, Ordering::Relaxed);
            });
            let want: u64 = (0..n).map(|i| (round + i) as u64).sum();
            assert_eq!(sum.load(Ordering::SeqCst), want, "round {round}");
        }
    }

    #[test]
    fn tasks_borrow_caller_stack() {
        let pool = WorkerPool::new(4);
        let input: Vec<u64> = (0..64).collect();
        let out = pool.run_collect(input.len(), |i| input[i] * 2);
        assert_eq!(out[63], 126);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.helper_threads(), 0);
        assert_eq!(pool.run_collect(5, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_tasks_is_a_no_op() {
        let pool = WorkerPool::new(2);
        pool.run(0, &|_| panic!("no task should run"));
        let empty: Vec<usize> = pool.run_collect(0, |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(8);
        pool.run(16, &|_| {});
        drop(pool); // must not hang or leak parked threads
    }

    #[test]
    fn capped_run_completes_all_tasks() {
        // the budget limits concurrent helpers, never completion: every
        // task must run exactly once whatever mix of submitter/helpers
        // claims them
        let pool = WorkerPool::new(8);
        for cap in [1usize, 2, 3, 100] {
            let n = 23;
            let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run_capped(cap, n, &|i| {
                counts[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::SeqCst), 1, "cap {cap} task {i}");
            }
            let out = pool.run_collect_capped(cap, 9, |i| i + 1);
            assert_eq!(out, (1..=9).collect::<Vec<_>>(), "cap {cap}");
        }
    }

    #[test]
    fn helper_budget_is_enforced() {
        // cap 2 = submitter + at most 1 helper: the peak number of
        // concurrent claimants must never exceed the budget (admission
        // happens under the state lock, so this is exact, not racy)
        let pool = WorkerPool::new(8);
        let active = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        pool.run_capped(2, 64, &|_| {
            let now = active.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            for _ in 0..500 {
                std::hint::spin_loop();
            }
            active.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "peak {} > budget", peak.load(Ordering::SeqCst));
    }

    #[test]
    fn concurrent_submitters_interleave_on_one_pool() {
        // the multi-tenant contract: several submitters with jobs in
        // flight at once, none deadlocks (each submitter participates in
        // its own claim loop), every job's results are correct
        let pool = Arc::new(WorkerPool::new(4));
        std::thread::scope(|s| {
            for t in 0..4usize {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    for round in 0..50usize {
                        let n = 1 + (t + round) % 9;
                        let out = pool.run_collect_capped(2, n, |i| i * 2 + t);
                        for (i, v) in out.iter().enumerate() {
                            assert_eq!(*v, i * 2 + t, "submitter {t} round {round}");
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn panicking_task_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let ran: Vec<AtomicU64> = (0..32).map(|_| AtomicU64::new(0)).collect();
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(32, &|i| {
                ran[i].fetch_add(1, Ordering::SeqCst);
                if i % 5 == 0 {
                    panic!("task {i} failed");
                }
            });
        }));
        assert!(result.is_err(), "a task panic must reach the submitter");
        // the job drained fully before the panic was re-thrown: every
        // task ran exactly once (no helper died mid-queue, no hang)
        for (i, c) in ran.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "task {i}");
        }
        // helpers caught the panic and are still parked: later jobs work
        let out = pool.run_collect(8, |i| i * 3);
        assert_eq!(out, (0..8).map(|i| i * 3).collect::<Vec<_>>());
        drop(pool); // joins cleanly — no dead or wedged helpers
    }

    #[test]
    fn helper_thread_panic_does_not_hang_submitter() {
        // force panics onto helper threads: the submitter task blocks
        // until every other task (all panicking) has been claimed, so
        // helpers must survive their panics and count completions or the
        // submitter would wait on `done` forever
        let pool = WorkerPool::new(4);
        let claimed = AtomicU64::new(0);
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &|i| {
                claimed.fetch_add(1, Ordering::SeqCst);
                if i > 0 {
                    panic!("helper task {i}");
                }
                while claimed.load(Ordering::SeqCst) < 4 {
                    std::thread::yield_now();
                }
            });
        }));
        assert!(result.is_err());
        assert_eq!(pool.run_collect(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn panic_payload_is_first_come_and_preserved() {
        let pool = WorkerPool::new(2);
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            // 2 tasks so the job reaches the shared queue (1 task with a
            // budget of one runs inline, which also propagates, but here
            // the queue path is the one under test)
            pool.run(2, &|i| {
                if i == 0 {
                    panic!("boom-payload");
                }
            });
        }));
        let payload = result.expect_err("must re-throw");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "boom-payload");
    }
}
