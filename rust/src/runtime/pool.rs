//! Persistent worker pool for the native modular-GEMM engine.
//!
//! The PR-1 parallel engine fanned every prepared GEMM out with
//! `std::thread::scope`, paying thread-spawn latency (tens of µs per
//! worker) on every call — acceptable for sweep workloads, dominant for
//! small-batch serving where a whole MLP layer is only a few hundred µs.
//! `WorkerPool` keeps the fan-out threads alive across calls: workers
//! park on a condvar between jobs and are unparked when a new job
//! generation is published, so steady-state dispatch cost is one
//! lock + notify instead of N spawns.
//!
//! A job is an indexed task set `f(0..n_tasks)` claimed from a shared
//! atomic counter (the same lock-free claim discipline the scoped path
//! uses); the submitting thread participates in the claim loop, then
//! blocks until every claimed task has completed.  Because the submitter
//! cannot return before `completed == n_tasks`, tasks may safely borrow
//! the submitter's stack (activations, prepared weights) even though the
//! pool threads are long-lived — that is the single safety invariant the
//! one `unsafe` lifetime erasure below relies on.
//!
//! Panics do not weaken that invariant: every task runs under
//! `catch_unwind`, so a panicking task still counts toward `completed`
//! (no helper dies mid-job, no submitter waits forever), and `run` holds
//! a drop guard that waits for the full completion count even while
//! unwinding, so the erased borrow can never dangle.  The first panic
//! payload is re-thrown on the submitting thread once the job has fully
//! drained — the same observable behavior as `std::thread::scope`.
//!
//! Determinism: the pool schedules *which thread* runs a task, never what
//! the task computes — engine tasks are exact modular arithmetic keyed by
//! task index, so outputs are bit-identical to the serial and scoped
//! paths (asserted by `tests/integration_store.rs`).

use std::any::Any;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Poison-tolerant lock: the pool's mutexes guard plain state whose
/// invariants are re-established under the lock, and task panics are
/// re-thrown on submitter threads that may hold these locks — treating
/// poison as fatal would turn one propagated panic into a dead pool.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Lifetime-erased `&(dyn Fn(usize) + Sync)`.
///
/// Safety contract: the pointee outlives every dereference because
/// `WorkerPool::run` blocks until `completed == n_tasks` — on the normal
/// path and, via a drop guard, while unwinding — and a worker only
/// dereferences after claiming an index `< n_tasks`; each such claim
/// completes (and is counted, panic or not) before `run` can return.
struct TaskRef(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

/// One published fan-out: the erased task plus claim/completion counters.
struct Job {
    task: TaskRef,
    n_tasks: usize,
    next: AtomicUsize,
    completed: AtomicUsize,
    /// First panic payload from any task; re-thrown on the submitter
    /// after the job fully drains.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Job {
    /// Claim and run tasks until the queue is exhausted.  The last
    /// completer wakes the submitter.
    fn run_tasks(&self, shared: &PoolShared) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_tasks {
                return;
            }
            // SAFETY: i < n_tasks, so the submitter is still blocked in
            // `run` and the borrow behind the pointer is alive (see
            // `TaskRef`).
            let f = unsafe { &*self.task.0 };
            // A panicking task must still count as completed: a helper
            // that unwound out of here would die before incrementing
            // `completed`, leaving the submitter waiting forever; a
            // submitter that unwound would drop the borrow while helpers
            // still execute through the erased pointer.
            if let Err(payload) = panic::catch_unwind(AssertUnwindSafe(|| f(i))) {
                let mut slot = lock_ignore_poison(&self.panic);
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.n_tasks {
                // lock before notify so the submitter cannot check the
                // counter and sleep between our increment and our wake
                let _guard = lock_ignore_poison(&shared.state);
                shared.done.notify_all();
            }
        }
    }
}

/// Blocks in `drop` until the job's completion count reaches `n_tasks`,
/// then unpublishes it.  Held by `run` across the claim loop so that no
/// unwind path can end the borrow behind `TaskRef` while a helper might
/// still dereference it.
struct CompletionGuard<'a> {
    job: &'a Job,
    shared: &'a PoolShared,
}

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        let mut st = lock_ignore_poison(&self.shared.state);
        while self.job.completed.load(Ordering::Acquire) < self.job.n_tasks {
            st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        // drop the erased pointer before `f`'s borrow can end; helpers
        // holding stale `Arc<Job>` clones only see an exhausted counter
        st.job = None;
    }
}

struct PoolState {
    shutdown: bool,
    /// Bumped once per published job; workers use it to tell a fresh job
    /// from the one they already drained.
    generation: u64,
    job: Option<Arc<Job>>,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The submitter parks here until the job completes.
    done: Condvar,
}

/// Long-lived fan-out threads with a parked-idle loop.  Owned by
/// `NativeEngine`; dropped (and joined) with it.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Serializes submitters: one job in flight at a time.
    submit: Mutex<()>,
    threads: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// A pool sized for `threads` total concurrency: `threads - 1` parked
    /// helper threads plus the submitting thread, which always
    /// participates in the claim loop.  `threads <= 1` spawns nothing and
    /// `run` degenerates to an inline serial loop.
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { shutdown: false, generation: 0, job: None }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..threads.saturating_sub(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rns-pool-{i}"))
                    .spawn(move || pool_worker(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, submit: Mutex::new(()), threads: handles }
    }

    /// Helper threads kept parked between jobs (total concurrency is one
    /// more: the submitter works too).
    pub fn helper_threads(&self) -> usize {
        self.threads.len()
    }

    /// Run `n_tasks` indexed tasks across the pool and block until all
    /// complete.  The closure may borrow the caller's stack.  A panicking
    /// task does not tear the pool down: the job still drains fully and
    /// the first panic is re-thrown here, on the submitting thread.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        self.run_capped(usize::MAX, n_tasks, f);
    }

    /// `run` with a concurrency hint: wake at most `cap - 1` parked
    /// helpers (the submitter is the cap's remaining slot) instead of the
    /// whole pool.  On a many-core host a small job would otherwise
    /// thundering-herd every parked helper through the state mutex just
    /// to find the claim counter exhausted.  The cap is a wake hint, not
    /// a limit on correctness: however many helpers show up, the
    /// submitter participates and the job always drains.
    pub fn run_capped(&self, cap: usize, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        if self.threads.is_empty() {
            for i in 0..n_tasks {
                f(i);
            }
            return;
        }
        let _submit = lock_ignore_poison(&self.submit);
        let job = Arc::new(Job {
            task: TaskRef(f as *const (dyn Fn(usize) + Sync)),
            n_tasks,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            panic: Mutex::new(None),
        });
        // helpers the job can actually use: one per task beyond the
        // submitter's, bounded by the cap and the pool width
        let wake = cap
            .max(1)
            .saturating_sub(1)
            .min(n_tasks.saturating_sub(1))
            .min(self.threads.len());
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.generation = st.generation.wrapping_add(1);
            st.job = Some(Arc::clone(&job));
            if wake >= self.threads.len() {
                self.shared.work.notify_all();
            } else {
                for _ in 0..wake {
                    self.shared.work.notify_one();
                }
            }
        }
        // from publication until the completion count reaches n_tasks,
        // helpers may dereference the erased borrow of `f`; the guard
        // waits that out on every exit path, including unwinding
        let guard = CompletionGuard { job: &job, shared: &self.shared };
        // the submitter is also a worker — a 1-task job never even needs
        // a helper wakeup to have finished by the guard's wait
        job.run_tasks(&self.shared);
        drop(guard);
        if let Some(payload) = lock_ignore_poison(&job.panic).take() {
            panic::resume_unwind(payload);
        }
    }

    /// Run tasks that each produce a value; results come back in task
    /// order.  Per-slot mutexes are uncontended (each task owns its
    /// slot) — they exist to keep the fan-out free of `unsafe` beyond
    /// the one lifetime erasure in `run`.
    pub fn run_collect<T, F>(&self, n_tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.run_collect_capped(usize::MAX, n_tasks, f)
    }

    /// `run_collect` with the `run_capped` wake hint.
    pub fn run_collect_capped<T, F>(&self, cap: usize, n_tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let slots: Vec<Mutex<Option<T>>> = (0..n_tasks).map(|_| Mutex::new(None)).collect();
        self.run_capped(cap, n_tasks, &|i| {
            *slots[i].lock().unwrap() = Some(f(i));
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("every task ran"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for t in self.threads.drain(..) {
            t.join().ok();
        }
    }
}

fn pool_worker(shared: Arc<PoolShared>) {
    let mut last_gen = 0u64;
    loop {
        let job = {
            let mut st = lock_ignore_poison(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != last_gen {
                    if let Some(job) = &st.job {
                        last_gen = st.generation;
                        break Arc::clone(job);
                    }
                }
                st = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        job.run_tasks(&shared);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        let n = 37;
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.run(n, &|i| {
            counts[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "task {i}");
        }
    }

    #[test]
    fn collect_returns_results_in_task_order() {
        let pool = WorkerPool::new(3);
        let out = pool.run_collect(25, |i| i * i);
        assert_eq!(out, (0..25).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn reused_across_many_jobs() {
        // many small jobs through one pool: exercises the generation
        // handshake (a stale worker must never re-run or miss a job)
        let pool = WorkerPool::new(4);
        for round in 0..200usize {
            let sum = AtomicU64::new(0);
            let n = 1 + round % 7;
            pool.run(n, &|i| {
                sum.fetch_add((round + i) as u64, Ordering::Relaxed);
            });
            let want: u64 = (0..n).map(|i| (round + i) as u64).sum();
            assert_eq!(sum.load(Ordering::SeqCst), want, "round {round}");
        }
    }

    #[test]
    fn tasks_borrow_caller_stack() {
        let pool = WorkerPool::new(4);
        let input: Vec<u64> = (0..64).collect();
        let out = pool.run_collect(input.len(), |i| input[i] * 2);
        assert_eq!(out[63], 126);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.helper_threads(), 0);
        assert_eq!(pool.run_collect(5, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_tasks_is_a_no_op() {
        let pool = WorkerPool::new(2);
        pool.run(0, &|_| panic!("no task should run"));
        let empty: Vec<usize> = pool.run_collect(0, |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(8);
        pool.run(16, &|_| {});
        drop(pool); // must not hang or leak parked threads
    }

    #[test]
    fn capped_run_completes_all_tasks() {
        // the cap limits wake-ups, never completion: every task must run
        // exactly once whatever mix of submitter/helpers claims them
        let pool = WorkerPool::new(8);
        for cap in [1usize, 2, 3, 100] {
            let n = 23;
            let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run_capped(cap, n, &|i| {
                counts[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::SeqCst), 1, "cap {cap} task {i}");
            }
            let out = pool.run_collect_capped(cap, 9, |i| i + 1);
            assert_eq!(out, (1..=9).collect::<Vec<_>>(), "cap {cap}");
        }
    }

    #[test]
    fn panicking_task_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let ran: Vec<AtomicU64> = (0..32).map(|_| AtomicU64::new(0)).collect();
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(32, &|i| {
                ran[i].fetch_add(1, Ordering::SeqCst);
                if i % 5 == 0 {
                    panic!("task {i} failed");
                }
            });
        }));
        assert!(result.is_err(), "a task panic must reach the submitter");
        // the job drained fully before the panic was re-thrown: every
        // task ran exactly once (no helper died mid-queue, no hang)
        for (i, c) in ran.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "task {i}");
        }
        // helpers caught the panic and are still parked: later jobs work
        let out = pool.run_collect(8, |i| i * 3);
        assert_eq!(out, (0..8).map(|i| i * 3).collect::<Vec<_>>());
        drop(pool); // joins cleanly — no dead or wedged helpers
    }

    #[test]
    fn helper_thread_panic_does_not_hang_submitter() {
        // force panics onto helper threads: the submitter task blocks
        // until every other task (all panicking) has been claimed, so
        // helpers must survive their panics and count completions or the
        // submitter would wait on `done` forever
        let pool = WorkerPool::new(4);
        let claimed = AtomicU64::new(0);
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &|i| {
                claimed.fetch_add(1, Ordering::SeqCst);
                if i > 0 {
                    panic!("helper task {i}");
                }
                while claimed.load(Ordering::SeqCst) < 4 {
                    std::thread::yield_now();
                }
            });
        }));
        assert!(result.is_err());
        assert_eq!(pool.run_collect(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn panic_payload_is_first_come_and_preserved() {
        let pool = WorkerPool::new(2);
        let result = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(1, &|_| panic!("boom-payload"));
        }));
        let payload = result.expect_err("must re-throw");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "boom-payload");
    }
}
