//! Prepared-layer execution plans: the weight-stationary half of the RNS
//! dataflow, done once per layer instead of once per GEMM call.
//!
//! The paper's Fig. 2 pipeline has a static half (quantize the weights,
//! forward-convert them into every residue channel, load them into the
//! analog arrays) and a dynamic half (everything that depends on the
//! activations).  The seed implementation redid the static half on every
//! `gemm_quantized` call — and `gemm_mod` additionally re-staged the
//! weight residues as packed `u32` on every invocation.  An `RnsPlan`
//! hoists all of it: one plan per (weight matrix, core config), holding
//! per-K-tile, per-channel residue matrices plus their `u32` staging, so
//! the hot path touches only activations.
//!
//! Plans are engine-agnostic: `PreparedWeights` keeps both the plain
//! residue matrices (any `ModularGemmEngine` can fall back to its
//! unprepared `matmul_mod`) and the packed staging the native
//! cache-blocked kernel consumes directly.

use crate::quant::{qmax, quantize_weights, QuantWeights};
use crate::rns::BarrettReducer;
use crate::tensor::gemm::stage_weights_u32;
use crate::tensor::{MatF, MatI};

/// Forward conversion of a quantized (signed) tile into residues `[0, m)`.
///
/// Perf (§Perf log, DESIGN.md): `rem_euclid` by a runtime modulus compiles
/// to a hardware divide per element; Barrett reduction of the
/// offset-shifted value halves the whole-core GEMM time.  `offset` is a
/// multiple of `m` making every quantized input non-negative
/// (`|v| <= qmax <= offset`).  Shared by the plan builder (weights, once
/// per layer) and the core's per-call activation conversion so the two
/// paths are bit-identical by construction.
pub fn forward_residues(mat: &MatI, m: u64, bits: u32) -> MatI {
    let red = BarrettReducer::new(m);
    let qm = qmax(bits).unsigned_abs();
    let offset = (qm / m + 1) * m;
    debug_assert!(mat.data.iter().all(|&v| v.unsigned_abs() <= qm));
    mat.map(|v| red.reduce((v + offset as i64) as u64) as i64)
}

/// Zero-skipping variant of [`forward_residues`] for sparse capture.
///
/// `offset` is a multiple of `m`, so a quantized 0 reduces to residue 0
/// in every channel — the short-circuit is bit-identical to the dense
/// conversion, it just skips the Barrett math (the digital analogue of
/// not firing the DAC for a zero activation).
pub fn forward_residues_sparse(mat: &MatI, m: u64, bits: u32) -> MatI {
    let red = BarrettReducer::new(m);
    let qm = qmax(bits).unsigned_abs();
    let offset = (qm / m + 1) * m;
    debug_assert!(mat.data.iter().all(|&v| v.unsigned_abs() <= qm));
    mat.map(|v| if v == 0 { 0 } else { red.reduce((v + offset as i64) as u64) as i64 })
}

/// One K-tile of weights, forward-converted and staged for every channel.
pub struct PreparedWeights {
    /// Tile height (dot-product length of this tile).
    pub rows: usize,
    /// Output columns.
    pub cols: usize,
    pub moduli: Vec<u64>,
    /// Per-channel residues as signed matrices (fallback engines).
    pub res: Vec<MatI>,
    /// Per-channel packed `u32` staging (native cache-blocked kernel),
    /// row-major `rows x cols`.
    pub staged: Vec<Vec<u32>>,
}

impl PreparedWeights {
    /// From per-channel residue matrices (already reduced into `[0, m)`).
    pub fn new(res: Vec<MatI>, moduli: &[u64]) -> Self {
        assert!(!res.is_empty(), "prepared weights need at least one channel");
        assert_eq!(res.len(), moduli.len());
        let (rows, cols) = (res[0].rows, res[0].cols);
        assert!(res.iter().all(|r| r.rows == rows && r.cols == cols));
        let staged = res.iter().zip(moduli).map(|(r, &m)| stage_weights_u32(r, m)).collect();
        PreparedWeights { rows, cols, moduli: moduli.to_vec(), res, staged }
    }

    /// Forward-convert one quantized weight tile into every channel + stage.
    pub fn from_quantized_tile(wt: &MatI, moduli: &[u64], bits: u32) -> Self {
        let res: Vec<MatI> = moduli.iter().map(|&m| forward_residues(wt, m, bits)).collect();
        Self::new(res, moduli)
    }

    /// Heap bytes held by this tile (residues + staging), for the plan
    /// store's memory gauge.
    pub fn mem_bytes(&self) -> u64 {
        let res: usize = self.res.iter().map(|m| m.data.len() * std::mem::size_of::<i64>()).sum();
        let staged: usize = self.staged.iter().map(|s| s.len() * std::mem::size_of::<u32>()).sum();
        (res + staged + self.moduli.len() * std::mem::size_of::<u64>()) as u64
    }
}

/// One K-tile of the plan: `[k0, k1)` rows of the quantized weight matrix.
pub struct PlanTile {
    pub k0: usize,
    pub k1: usize,
    pub weights: PreparedWeights,
}

/// A per-layer execution plan: quantized weights, their per-channel
/// residues for every K-tile, and the dequantization scales — everything
/// that does not depend on the activations.
pub struct RnsPlan {
    pub bits: u32,
    /// Analog array height the plan was tiled for.
    pub h: usize,
    /// Total K (weight rows) and N (weight cols).
    pub k: usize,
    pub n: usize,
    pub moduli: Vec<u64>,
    /// Quantized weights (kept for the dequantize scales).
    pub qw: QuantWeights,
    pub tiles: Vec<PlanTile>,
}

impl RnsPlan {
    /// Quantize + convert + stage a float weight matrix.
    pub fn build(w: &MatF, bits: u32, h: usize, moduli: &[u64]) -> Self {
        Self::from_quantized(quantize_weights(w, bits), bits, h, moduli)
    }

    pub fn from_quantized(qw: QuantWeights, bits: u32, h: usize, moduli: &[u64]) -> Self {
        assert!(h > 0, "tile height must be positive");
        let (k, n) = (qw.q.rows, qw.q.cols);
        let mut tiles = Vec::new();
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + h).min(k);
            let wt = qw.q.slice_rows(k0, k1);
            tiles
                .push(PlanTile { k0, k1, weights: PreparedWeights::from_quantized_tile(&wt, moduli, bits) });
            k0 = k1;
        }
        RnsPlan { bits, h, k, n, moduli: moduli.to_vec(), qw, tiles }
    }

    /// Total weight elements (per channel) — the once-per-layer DAC count.
    pub fn weight_elems(&self) -> u64 {
        (self.k * self.n) as u64
    }

    /// Approximate heap bytes held by this plan (tiles + quantized
    /// weights + scales) — what the shared `PlanStore` accounts per
    /// resident plan.
    pub fn mem_bytes(&self) -> u64 {
        let tiles: u64 = self.tiles.iter().map(|t| t.weights.mem_bytes()).sum();
        let qw = self.qw.q.data.len() * std::mem::size_of::<i64>()
            + self.qw.scales.len() * std::mem::size_of::<f32>();
        tiles + qw as u64 + (self.moduli.len() * std::mem::size_of::<u64>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::paper_table1;
    use crate::util::rng::Rng;

    #[test]
    fn forward_residues_matches_rem_euclid() {
        let mut rng = Rng::seed_from(1);
        let bits = 6u32;
        let qm = qmax(bits);
        let mat =
            MatI::from_vec(4, 9, (0..36).map(|_| rng.gen_range_i64(-qm, qm)).collect());
        for &m in paper_table1(bits).unwrap() {
            let got = forward_residues(&mat, m, bits);
            let want = mat.map(|v| v.rem_euclid(m as i64));
            assert_eq!(got.data, want.data, "m={m}");
        }
    }

    #[test]
    fn sparse_forward_matches_dense_with_zeros() {
        let mut rng = Rng::seed_from(3);
        let bits = 8u32;
        let qm = qmax(bits);
        // ~half the entries zeroed, ReLU-style
        let mat = MatI::from_vec(
            5,
            11,
            (0..55).map(|_| rng.gen_range_i64(-qm, qm).max(0)).collect(),
        );
        for &m in paper_table1(bits).unwrap() {
            let dense = forward_residues(&mat, m, bits);
            let sparse = forward_residues_sparse(&mat, m, bits);
            assert_eq!(dense.data, sparse.data, "m={m}");
        }
    }

    #[test]
    fn plan_tiles_cover_k_and_stage_all_channels() {
        let mut rng = Rng::seed_from(2);
        let (k, n, h) = (300usize, 7usize, 128usize);
        let w =
            MatF::from_vec(k, n, (0..k * n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        let moduli = paper_table1(6).unwrap();
        let plan = RnsPlan::build(&w, 6, h, moduli);
        assert_eq!(plan.tiles.len(), 3); // 128 + 128 + 44
        assert_eq!(plan.tiles.last().unwrap().k1, k);
        let mut covered = 0;
        for t in &plan.tiles {
            assert_eq!(t.k0, covered);
            covered = t.k1;
            assert_eq!(t.weights.rows, t.k1 - t.k0);
            assert_eq!(t.weights.cols, n);
            assert_eq!(t.weights.res.len(), moduli.len());
            for (ch, (&m, staged)) in
                t.weights.moduli.iter().zip(&t.weights.staged).enumerate()
            {
                assert_eq!(staged.len(), (t.k1 - t.k0) * n);
                let res = &t.weights.res[ch];
                for (&r, &s) in res.data.iter().zip(staged) {
                    assert!((0..m as i64).contains(&r));
                    assert_eq!(r as u32, s);
                }
            }
        }
        assert_eq!(covered, k);
        assert_eq!(plan.weight_elems(), (k * n) as u64);
    }

    #[test]
    fn plan_residues_match_quantized_weights() {
        let mut rng = Rng::seed_from(3);
        let (k, n) = (40usize, 5usize);
        let w =
            MatF::from_vec(k, n, (0..k * n).map(|_| rng.uniform_f32(-0.7, 0.7)).collect());
        let moduli = paper_table1(4).unwrap();
        let plan = RnsPlan::build(&w, 4, 16, moduli);
        let qw = quantize_weights(&w, 4);
        for t in &plan.tiles {
            for (ch, &m) in moduli.iter().enumerate() {
                for r in 0..t.weights.rows {
                    for c in 0..n {
                        let want = qw.q.at(t.k0 + r, c).rem_euclid(m as i64);
                        assert_eq!(t.weights.res[ch].at(r, c), want);
                    }
                }
            }
        }
    }
}
