//! `artifacts/manifest.txt` parser — key=value metadata written by aot.py
//! (shapes + per-bit-width moduli) so the rust loader can validate what
//! was baked into each HLO artifact without a serde dependency.
//!
//! Errors are plain `String`s: the crate is dependency-free by default
//! (see Cargo.toml), so no `anyhow` here.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub h: usize,
    /// bits -> Table-I moduli baked into `rns_mvm_b{bits}.hlo.txt`.
    pub moduli: BTreeMap<u32, Vec<u64>>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut batch = None;
        let mut h = None;
        let mut moduli = BTreeMap::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("manifest line {}: `{line}`", i + 1))?;
            match k {
                "batch" => {
                    batch = Some(v.parse::<usize>().map_err(|e| format!("batch: {e}"))?)
                }
                "h" => h = Some(v.parse::<usize>().map_err(|e| format!("h: {e}"))?),
                _ if k.starts_with("moduli_b") => {
                    let bits: u32 = k["moduli_b".len()..]
                        .parse()
                        .map_err(|e| format!("bits suffix: {e}"))?;
                    let mods = v
                        .split(',')
                        .map(|s| s.trim().parse::<u64>())
                        .collect::<Result<Vec<_>, _>>()
                        .map_err(|e| format!("moduli list for b={bits}: {e}"))?;
                    moduli.insert(bits, mods);
                }
                other => return Err(format!("manifest: unknown key `{other}`")),
            }
        }
        Ok(Manifest {
            batch: batch.ok_or("manifest missing `batch`")?,
            h: h.ok_or("manifest missing `h`")?,
            moduli,
        })
    }

    pub fn load(artifacts_dir: &str) -> Result<Self, String> {
        let path = format!("{artifacts_dir}/manifest.txt");
        let text =
            std::fs::read_to_string(&path).map_err(|e| format!("read {path}: {e}"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse("batch=8\nh=128\nmoduli_b6=63,62,61,59\nmoduli_b8=255,254,253\n")
            .unwrap();
        assert_eq!(m.batch, 8);
        assert_eq!(m.h, 128);
        assert_eq!(m.moduli[&6], vec![63, 62, 61, 59]);
        assert_eq!(m.moduli[&8], vec![255, 254, 253]);
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("h=128").is_err());
        assert!(Manifest::parse("batch=8\nh=128\nnonsense=1").is_err());
        assert!(Manifest::parse("batch=8\nh=128\nmoduli_b6=63,abc").is_err());
    }

    #[test]
    fn real_manifest_matches_table1() {
        let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
        if std::path::Path::new(&format!("{dir}/manifest.txt")).exists() {
            let m = Manifest::load(&dir).unwrap();
            for bits in 4..=8u32 {
                assert_eq!(
                    m.moduli[&bits].as_slice(),
                    crate::rns::paper_table1(bits).unwrap(),
                    "b={bits}"
                );
            }
        }
    }
}
