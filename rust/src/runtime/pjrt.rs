//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the PJRT CPU client via the
//! `xla` crate.  This is the production hot path of the three-layer
//! architecture — python never runs at serving time.
//!
//! The real implementation needs the `xla` + `anyhow` crates and is gated
//! behind the `pjrt` cargo feature (the default build is dependency-free —
//! see Cargo.toml).  Without the feature, a stub with the identical public
//! surface is compiled instead; every entry point fails cleanly at
//! construction time, so callers (CLI, coordinator, benches, tests) degrade
//! gracefully rather than failing to link.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

#[cfg(feature = "pjrt")]
mod real {
    use anyhow::{anyhow, Context, Result};

    use crate::runtime::engine::ModularGemmEngine;
    use crate::tensor::MatI;

    /// A PJRT CPU client (one per process; compile artifacts against it).
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(PjrtRuntime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one HLO-text artifact.
        pub fn load(&self, path: &str) -> Result<PjrtExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compile {path}"))?;
            Ok(PjrtExecutable { exe, path: path.to_string() })
        }
    }

    /// One compiled executable (jax-lowered with `return_tuple=True`, so the
    /// output is always a 1-tuple).
    pub struct PjrtExecutable {
        exe: xla::PjRtLoadedExecutable,
        path: String,
    }

    /// A typed input buffer: f32 data + dims.
    pub struct F32Input<'a> {
        pub data: &'a [f32],
        pub dims: Vec<i64>,
    }

    impl PjrtExecutable {
        /// Execute with f32 inputs; returns the flattened f32 output of the
        /// single tuple element.
        pub fn run_f32(&self, inputs: &[F32Input]) -> Result<Vec<f32>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for inp in inputs {
                let expect: i64 = inp.dims.iter().product();
                if expect as usize != inp.data.len() {
                    return Err(anyhow!(
                        "{}: input dims {:?} need {} values, got {}",
                        self.path,
                        inp.dims,
                        expect,
                        inp.data.len()
                    ));
                }
                literals.push(
                    xla::Literal::vec1(inp.data)
                        .reshape(&inp.dims)
                        .with_context(|| format!("reshape input to {:?}", inp.dims))?,
                );
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("execute {}", self.path))?;
            let lit = result[0][0].to_literal_sync().context("fetch output literal")?;
            let out = lit.to_tuple1().context("unwrap 1-tuple output")?;
            out.to_vec::<f32>().context("output to f32 vec")
        }
    }

    /// `ModularGemmEngine` backed by the AOT pallas kernel artifact
    /// `rns_mvm_b{bits}.hlo.txt`: shapes fixed at AOT time to
    /// (n, BATCH, H) x (n, H, H); larger problems are tiled and modularly
    /// accumulated in rust, smaller ones zero-padded (padding residues with 0
    /// is exact — zero rows/cols contribute nothing to the dot products).
    pub struct PjrtEngine {
        exec: PjrtExecutable,
        pub moduli: Vec<u64>,
        pub batch: usize,
        pub h: usize,
    }

    impl PjrtEngine {
        /// Load the engine for a bit-width from the artifacts directory,
        /// cross-checking the baked moduli against `manifest.txt`.
        pub fn load(runtime: &PjrtRuntime, artifacts_dir: &str, bits: u32) -> Result<Self> {
            let manifest =
                super::super::manifest::Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
            let moduli = manifest
                .moduli
                .get(&bits)
                .ok_or_else(|| anyhow!("manifest has no moduli for b={bits}"))?
                .clone();
            let path = format!("{artifacts_dir}/rns_mvm_b{bits}.hlo.txt");
            let exec = runtime.load(&path)?;
            Ok(PjrtEngine { exec, moduli, batch: manifest.batch, h: manifest.h })
        }

        /// One fixed-shape execution: channels padded to (n, batch, h)x(n, h, h).
        fn run_tile(&self, x_res: &[MatI], w_res: &[MatI]) -> Result<Vec<MatI>> {
            let n = self.moduli.len();
            let (b, k) = (x_res[0].rows, x_res[0].cols);
            let nn = w_res[0].cols;
            assert!(b <= self.batch && k <= self.h && nn <= self.h, "tile exceeds artifact shape");
            let mut x_buf = vec![0.0f32; n * self.batch * self.h];
            let mut w_buf = vec![0.0f32; n * self.h * self.h];
            for (ch, x) in x_res.iter().enumerate() {
                for r in 0..b {
                    for c in 0..k {
                        x_buf[(ch * self.batch + r) * self.h + c] = x.at(r, c) as f32;
                    }
                }
            }
            for (ch, w) in w_res.iter().enumerate() {
                for r in 0..k {
                    for c in 0..nn {
                        w_buf[(ch * self.h + r) * self.h + c] = w.at(r, c) as f32;
                    }
                }
            }
            let out = self.exec.run_f32(&[
                F32Input { data: &x_buf, dims: vec![n as i64, self.batch as i64, self.h as i64] },
                F32Input { data: &w_buf, dims: vec![n as i64, self.h as i64, self.h as i64] },
            ])?;
            let mut res = Vec::with_capacity(n);
            for ch in 0..n {
                let mut m = MatI::zeros(b, nn);
                for r in 0..b {
                    for c in 0..nn {
                        m.set(r, c, out[(ch * self.batch + r) * self.h + c] as i64);
                    }
                }
                res.push(m);
            }
            Ok(res)
        }

        fn matmul_mod_impl(
            &mut self,
            x_res: &[MatI],
            w_res: &[MatI],
            moduli: &[u64],
        ) -> Result<Vec<MatI>> {
            if moduli != self.moduli.as_slice() {
                return Err(anyhow!(
                    "moduli mismatch: engine baked {:?}, caller asked {:?}",
                    self.moduli,
                    moduli
                ));
            }
            let (b, k) = (x_res[0].rows, x_res[0].cols);
            let nn = w_res[0].cols;
            let n = moduli.len();
            let mut out: Vec<MatI> = (0..n).map(|_| MatI::zeros(b, nn)).collect();
            // tile over batch rows, K, and N; modular accumulation across K tiles
            let mut b0 = 0;
            while b0 < b {
                let b1 = (b0 + self.batch).min(b);
                let mut n0 = 0;
                while n0 < nn {
                    let n1 = (n0 + self.h).min(nn);
                    let mut k0 = 0;
                    while k0 < k {
                        let k1 = (k0 + self.h).min(k);
                        let xt: Vec<MatI> =
                            x_res.iter().map(|x| x.slice_rows(b0, b1).slice_cols(k0, k1)).collect();
                        let wt: Vec<MatI> =
                            w_res.iter().map(|w| w.slice_rows(k0, k1).slice_cols(n0, n1)).collect();
                        let part = self.run_tile(&xt, &wt)?;
                        for (ch, p) in part.iter().enumerate() {
                            let m = moduli[ch] as i64;
                            for r in 0..p.rows {
                                for c in 0..p.cols {
                                    let cur = out[ch].at(b0 + r, n0 + c);
                                    out[ch].set(b0 + r, n0 + c, (cur + p.at(r, c)) % m);
                                }
                            }
                        }
                        k0 = k1;
                    }
                    n0 = n1;
                }
                b0 = b1;
            }
            Ok(out)
        }
    }

    impl ModularGemmEngine for PjrtEngine {
        fn matmul_mod(&mut self, x_res: &[MatI], w_res: &[MatI], moduli: &[u64]) -> Vec<MatI> {
            self.matmul_mod_impl(x_res, w_res, moduli)
                .unwrap_or_else(|e| panic!("PJRT modular matmul failed: {e:#}"))
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::{F32Input, PjrtEngine, PjrtExecutable, PjrtRuntime};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::fmt;

    use crate::runtime::engine::ModularGemmEngine;
    use crate::tensor::MatI;

    /// Error returned by every stub entry point.
    #[derive(Clone, Copy, Debug)]
    pub struct PjrtUnavailable;

    impl fmt::Display for PjrtUnavailable {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(
                f,
                "PJRT support not compiled in (rebuild with `--features pjrt` \
                 and the vendored `xla`/`anyhow` crates)"
            )
        }
    }

    impl std::error::Error for PjrtUnavailable {}

    /// Stub PJRT client: construction always fails, so no downstream state
    /// (executables, engines) can ever exist in a stub build.
    pub struct PjrtRuntime {
        _priv: (),
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<Self, PjrtUnavailable> {
            Err(PjrtUnavailable)
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn load(&self, _path: &str) -> Result<PjrtExecutable, PjrtUnavailable> {
            Err(PjrtUnavailable)
        }
    }

    pub struct PjrtExecutable {
        _priv: (),
    }

    impl PjrtExecutable {
        pub fn run_f32(&self, _inputs: &[F32Input]) -> Result<Vec<f32>, PjrtUnavailable> {
            Err(PjrtUnavailable)
        }
    }

    /// A typed input buffer: f32 data + dims (same shape as the real one so
    /// call sites compile unchanged).
    pub struct F32Input<'a> {
        pub data: &'a [f32],
        pub dims: Vec<i64>,
    }

    pub struct PjrtEngine {
        pub moduli: Vec<u64>,
        pub batch: usize,
        pub h: usize,
        _priv: (),
    }

    impl PjrtEngine {
        pub fn load(
            _runtime: &PjrtRuntime,
            _artifacts_dir: &str,
            _bits: u32,
        ) -> Result<Self, PjrtUnavailable> {
            Err(PjrtUnavailable)
        }
    }

    impl ModularGemmEngine for PjrtEngine {
        fn matmul_mod(&mut self, _x: &[MatI], _w: &[MatI], _moduli: &[u64]) -> Vec<MatI> {
            unreachable!("stub PjrtEngine cannot be constructed")
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{F32Input, PjrtEngine, PjrtExecutable, PjrtRuntime};
