//! The baseline: a regular fixed-point analog core with `b_ADC < b_out`
//! (paper Table I right half, Fig. 1, and the "fixed-point" series in
//! Figs. 3-4).
//!
//! GEMMs with K > h are tiled into K/h column chunks (the paper's
//! "standard tiling methods"); each tile's partial output is captured by
//! the truncating ADC *before* being accumulated digitally — exactly the
//! mechanism that loses `b_out - b_ADC` LSBs per partial and degrades
//! accuracy.

use crate::analog::energy::EnergyMeter;
use crate::analog::mvm_unit::FixedPointMvmUnit;
use crate::analog::noise::NoiseModel;
use crate::analog::GemmBackend;
use crate::quant::{dequantize, quantize_activations, quantize_weights};
use crate::tensor::{MatF, MatI};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct FixedPointCore {
    pub bits: u32,
    /// Analog array height (dot-product length per tile).
    pub h: usize,
    unit: FixedPointMvmUnit,
    pub meter: EnergyMeter,
    rng: Rng,
}

impl FixedPointCore {
    pub fn new(bits: u32, h: usize, noise: NoiseModel, seed: u64) -> Self {
        assert!(h > 0);
        FixedPointCore {
            bits,
            h,
            unit: FixedPointMvmUnit::new(bits, bits, h, noise),
            meter: EnergyMeter::default(),
            rng: Rng::seed_from(seed),
        }
    }

    /// Full quantized GEMM through the simulated core.
    pub fn gemm_quantized(&mut self, x: &MatF, w: &MatF) -> MatF {
        assert_eq!(x.cols, w.rows, "gemm shape mismatch");
        let qa = quantize_activations(x, self.bits);
        let qw = quantize_weights(w, self.bits);
        let mut acc = MatI::zeros(x.rows, w.cols);
        let k = x.cols;
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + self.h).min(k);
            let xt = qa.q.slice_cols(k0, k1);
            let wt = qw.q.slice_rows(k0, k1);
            let part = self.unit.execute(&xt, &wt, &mut self.rng, &mut self.meter);
            for (a, &p) in acc.data.iter_mut().zip(&part.data) {
                *a += p; // digital accumulation of truncated partials
            }
            k0 = k1;
        }
        dequantize(&acc, &qa, &qw)
    }
}

impl GemmBackend for FixedPointCore {
    fn gemm(&mut self, x: &MatF, w: &MatF) -> MatF {
        self.gemm_quantized(x, w)
    }
    fn name(&self) -> String {
        format!("fixed-point-b{}", self.bits)
    }
    fn meter(&self) -> Option<EnergyMeter> {
        Some(self.meter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gemm::gemm_f32;
    use crate::util::rng::Rng;

    fn rand_mat(seed: u64, rows: usize, cols: usize, scale: f32) -> MatF {
        let mut rng = Rng::seed_from(seed);
        MatF::from_vec(rows, cols, (0..rows * cols).map(|_| rng.uniform_f32(-scale, scale)).collect())
    }

    #[test]
    fn error_grows_as_bits_shrink() {
        let x = rand_mat(1, 4, 128, 1.0);
        let w = rand_mat(2, 128, 16, 0.5);
        let want = gemm_f32(&x, &w);
        let mut errs = Vec::new();
        for bits in [8u32, 6, 4] {
            let mut core = FixedPointCore::new(bits, 128, NoiseModel::None, 0);
            let got = core.gemm_quantized(&x, &w);
            let err: f32 = got
                .data
                .iter()
                .zip(&want.data)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / want.data.len() as f32;
            errs.push(err);
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "errors {errs:?}");
    }

    #[test]
    fn smaller_array_drops_fewer_bits() {
        // the ADC range is sized for the array height h (Eq. 4): a 64-tall
        // array loses one fewer LSB than a 128-tall one on the same K=64
        // GEMM, so its error is no larger.
        let x = rand_mat(3, 2, 64, 1.0);
        let w = rand_mat(4, 64, 4, 1.0);
        let want = gemm_f32(&x, &w);
        let err = |m: &MatF| {
            m.data.iter().zip(&want.data).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
        };
        let mut small = FixedPointCore::new(6, 64, NoiseModel::None, 0);
        let mut large = FixedPointCore::new(6, 128, NoiseModel::None, 0);
        let e_small = err(&small.gemm_quantized(&x, &w));
        let e_large = err(&large.gemm_quantized(&x, &w));
        assert!(e_small <= e_large, "h=64 err {e_small} vs h=128 err {e_large}");
    }

    #[test]
    fn energy_accounting_per_tile() {
        let x = rand_mat(5, 2, 256, 1.0);
        let w = rand_mat(6, 256, 3, 1.0);
        let mut core = FixedPointCore::new(6, 128, NoiseModel::None, 0);
        core.gemm_quantized(&x, &w);
        // 2 tiles: DAC = 2*(2*128 + 128*3) ; ADC = 2 tiles * 2*3 outputs
        assert_eq!(core.meter.dac_conversions, 2 * (2 * 128 + 128 * 3));
        assert_eq!(core.meter.adc_conversions, 12);
    }

    #[test]
    fn backend_name() {
        let core = FixedPointCore::new(4, 128, NoiseModel::None, 0);
        assert_eq!(GemmBackend::name(&core), "fixed-point-b4");
    }
}
