//! The RNS-based analog core (paper Fig. 2) with optional RRNS fault
//! tolerance (§IV).
//!
//! Dataflow per K-tile (tile height = the analog array size h):
//!   1. forward-convert the quantized *activation* tile to n residue
//!      channels (the weight side is prepared once per layer — see below);
//!   2. run the modular MVM on every channel — through the pluggable
//!      `ModularGemmEngine` (native rust, or the AOT-compiled pallas kernel
//!      via PJRT);
//!   3. per-channel ADC capture with noise injection;
//!   4. plain RNS: batch CRT over the whole tile;
//!      RRNS(n, k): two-tier decode — a whole-tile consistency pre-check
//!      (batch CRT over the info moduli, re-encode, compare) batch-decodes
//!      every clean element, and only mismatching elements run the voting
//!      decode with the paper's recompute-and-revote loop, up to
//!      `max_attempts` (see DESIGN.md §7; bit-identical to all-voting);
//!   5. accumulate the signed partial outputs digitally; dequantize once at
//!      the end.
//!
//! **Prepared execution**: weights are stationary in the analog arrays, so
//! their quantization, per-channel forward conversion, u32 staging, and
//! weight-DAC energy are all one-time per-layer costs.  Plans live in a
//! shared, read-only `PlanStore` (`crate::store`): the core borrows an
//! `Arc<RnsPlan>` per weight matrix (keyed by pointer + shape +
//! fingerprint + moduli config) and the store builds each plan exactly
//! once, however many cores share it.  A standalone core gets a private
//! store; the coordinator hands every worker one shared store so W
//! workers hold one plan instance per layer, not W.  (The per-core LRU
//! `PlanCache` this module carried in PR 1 is gone — deprecated in favor
//! of the store so there is one cache, not two; the store bounds
//! untagged one-shot plans with the same LRU discipline.)
//! `gemm_quantized` fetches/builds the plan on first sight of a layer and
//! then only processes activations.  `gemm_quantized_unprepared` keeps
//! the original per-call path as a bit-identical reference (asserted by
//! the integration_plan tests).
//!
//! Energy stays per-core even though plans are shared: each core charges
//! the one-time weight-DAC cost the first time *it* adopts a layer's
//! plan, mirroring one accelerator's arrays being loaded per worker.
//! Adoption tracks the plan *instance* (a `Weak` to the store's `Arc`),
//! so a plan that was LRU-evicted and later rebuilt is re-adopted and
//! re-charged — rebuilding reloads the arrays, exactly as the PR-1
//! per-call accounting had it — and the adoption map stays bounded by
//! the store's residency instead of growing one entry per weight matrix
//! ever seen (fig3-style sweep campaigns).
//!
//! The ADCs in every channel run at `ceil(log2 m_i)` bits — never at
//! `b_out` — which is the entire point of the design.

use std::collections::HashMap;
use std::sync::{Arc, Weak};
use std::time::Instant;

use crate::analog::energy::EnergyMeter;
use crate::analog::mvm_unit::RnsMvmUnit;
use crate::analog::noise::NoiseModel;
use crate::analog::{GemmBackend, StageMicros};
use crate::quant::{dequantize, quantize_activations, quantize_weights};
use crate::rns::inject::{FaultInjector, FaultSpec};
use crate::rns::moduli::{extend_moduli, required_output_bits, select_moduli};
use crate::rns::rrns::{Decode, RrnsCode};
use crate::rns::RnsContext;
use crate::runtime::engine::{ModularGemmEngine, NativeEngine};
use crate::runtime::plan::{forward_residues, forward_residues_sparse, PreparedWeights, RnsPlan};
use crate::store::{PlanKey, PlanStore};
use crate::tensor::{MatF, MatI};
use crate::util::rng::Rng;

/// `adopted` map size below which dead-entry purging is skipped (keeps
/// the amortized purge from thrashing on small models).
const ADOPTED_PURGE_FLOOR: usize = 64;

/// Whole microseconds since `t0` (saturating cast; a stage timer that
/// somehow exceeds u64 µs has bigger problems than truncation).
#[inline]
fn elapsed_us(t0: Instant) -> u64 {
    t0.elapsed().as_micros() as u64
}

/// Configuration for one RNS-based core instance.
#[derive(Clone, Debug)]
pub struct RnsCoreConfig {
    pub bits: u32,
    /// Analog array height (dot-product length per tile).
    pub h: usize,
    /// Information moduli (Table-I selection if built via `for_bits`).
    pub moduli: Vec<u64>,
    /// Number of redundant moduli (0 = plain RNS, no fault tolerance).
    pub redundant: usize,
    /// Max dot-product attempts for Case-2 outcomes (paper's R).
    pub max_attempts: u32,
    pub noise: NoiseModel,
    pub seed: u64,
    /// Force the per-element voting decode for every RRNS element instead
    /// of the two-tier batched pipeline (tier 1: whole-tile consistency
    /// pre-check, tier 2: voting only for mismatching elements).  The two
    /// paths are bit-identical by construction — this flag exists for the
    /// equivalence tests and the bench baseline, not for serving.
    pub reference_decode: bool,
    /// Seeded fault injection applied to every tile (drift campaigns:
    /// `FaultSpec::TemporalBurst` persists one corrupted rectangle
    /// across consecutive tiles).  `None` (the default) injects nothing.
    /// Where the corruption lands is `fault_site`'s call.
    pub fault_injection: Option<(FaultSpec, u64)>,
    /// Which side of the ADC the injected fault models (ignored without
    /// `fault_injection`):
    ///
    /// * `Capture` (default): the *captured* residues are corrupted and
    ///   the retry loop recomputes from the clean channel outputs — a
    ///   drift event hitting the ADC capture, recoverable by the
    ///   paper's detect → recompute loop;
    /// * `Array`: the channel outputs themselves are corrupted before
    ///   capture, so every recompute of the same tile re-reads the same
    ///   corruption until the drift event expires — the failure mode
    ///   that exhausts `max_attempts` whenever the burst width exceeds
    ///   the correction radius t.
    pub fault_site: InjectionSite,
    /// Conversion-avoiding sparse execution (RedPIM-style): charge
    /// activation-DAC only for nonzero activation elements, and skip ADC
    /// capture, noise draws, and CRT decode for output rows whose dot
    /// product is structurally zero (the activation slice row is all
    /// zeros, so every channel's clean output row is exactly 0 — the
    /// forward-conversion offset is a multiple of each modulus).
    ///
    /// Default **off**: with a noise model active, skipping rows
    /// legitimately changes the RNG stream, so the knob is opt-in to keep
    /// bit/RNG-stream compatibility for existing seeds.  Under
    /// `NoiseModel::None` with no fault injector, sparse output is
    /// bit-identical to dense on every decode path.
    pub sparse_capture: bool,
}

/// Where `RnsCoreConfig::fault_injection` corrupts a tile (see the
/// field docs): at the ADC capture (retry recomputes clean) or in the
/// analog array outputs (retry re-reads the same corruption).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum InjectionSite {
    #[default]
    Capture,
    Array,
}

impl RnsCoreConfig {
    /// Paper defaults: Table-I moduli for (bits, h), no redundancy, ideal.
    pub fn for_bits(bits: u32, h: usize) -> Self {
        RnsCoreConfig {
            bits,
            h,
            moduli: select_moduli(bits, h).expect("moduli selection"),
            redundant: 0,
            max_attempts: 1,
            noise: NoiseModel::None,
            seed: 0,
            reference_decode: false,
            fault_injection: None,
            fault_site: InjectionSite::default(),
            sparse_capture: false,
        }
    }

    pub fn with_noise(mut self, noise: NoiseModel) -> Self {
        self.noise = noise;
        self
    }

    pub fn with_rrns(mut self, redundant: usize, max_attempts: u32) -> Self {
        self.redundant = redundant;
        self.max_attempts = max_attempts.max(1);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_reference_decode(mut self, reference: bool) -> Self {
        self.reference_decode = reference;
        self
    }

    /// Inject seeded faults into every captured tile (see
    /// `fault_injection`).  The injector's RNG is separate from the
    /// core's noise RNG, so a campaign replays bit-for-bit from
    /// `(spec, seed)` whatever the noise model draws.
    pub fn with_fault_injection(mut self, spec: FaultSpec, seed: u64) -> Self {
        self.fault_injection = Some((spec, seed));
        self
    }

    /// Choose where the injected faults land (capture vs array side);
    /// see `fault_site`.
    pub fn with_fault_site(mut self, site: InjectionSite) -> Self {
        self.fault_site = site;
        self
    }

    /// Enable conversion-avoiding sparse execution (see `sparse_capture`).
    pub fn with_sparse_capture(mut self, sparse: bool) -> Self {
        self.sparse_capture = sparse;
        self
    }
}

/// Fault-tolerance counters (per core lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Output elements decoded in total — exactly one count per output
    /// element per tile decode, independent of how many voting retries an
    /// element needed (retries are visible in `detections`, not here).
    pub decoded: u64,
    /// Elements whose first decode had inconsistent residues but still
    /// reached majority (Case 1 with corrections).
    pub corrected: u64,
    /// Case-2 detections (each triggers one recompute attempt).
    pub detections: u64,
    /// Elements still undecodable after `max_attempts` (fell back to the
    /// information-moduli CRT).
    pub exhausted: u64,
    /// RRNS elements decoded by the batched no-fault fast path (tier-1
    /// consistency pre-check passed).  Plain-RNS tiles, which have no
    /// voting tier at all, count in neither this nor `voted_elems`.
    pub fast_path_elems: u64,
    /// RRNS elements that fell back to per-element voting (tier 2).
    /// `fast_path_elems + voted_elems == decoded` for every RRNS core;
    /// under `reference_decode` every element counts here.
    pub voted_elems: u64,
    /// Output rows sparse capture proved structurally zero and never
    /// captured nor decoded (their elements appear in *no* other counter:
    /// not `decoded`, not `fast_path_elems`).  Always 0 with
    /// `sparse_capture` off.
    pub skipped_rows: u64,
}

pub struct RnsCore {
    pub cfg: RnsCoreConfig,
    /// Context over all (info + redundant) moduli.
    all_ctx: RnsContext,
    /// RRNS codec when redundancy is configured.
    code: Option<RrnsCode>,
    units: Vec<RnsMvmUnit>,
    engine: Box<dyn ModularGemmEngine>,
    pub meter: EnergyMeter,
    pub stats: FaultStats,
    /// Cumulative per-stage wall-clock timers (DAC forward, analog GEMM,
    /// ADC capture, decode) — the serving tier reads batch deltas
    /// (`StageMicros::delta_since`) the same way it reads
    /// `meter`/`stats` deltas, and those single delta values feed both
    /// the `rns_stage_latency_us` histograms and per-request span
    /// traces, so the two views can never disagree.
    pub stage_us: StageMicros,
    rng: Rng,
    /// Shared (or private) read-only plan store this core borrows from.
    store: Arc<PlanStore>,
    /// Plan instances this core has adopted: the one-time weight-DAC
    /// conversion is charged when a plan is first seen by *this* core,
    /// whether the shared store built it here or another worker built it
    /// first.  Values are `Weak` handles to the store's `Arc`, so an
    /// entry dies when the store evicts the plan — a rebuilt plan is a
    /// new instance and is charged again (the arrays are reloaded), and
    /// dead entries are purged so sweeps don't grow this map unboundedly.
    adopted: HashMap<PlanKey, Weak<RnsPlan>>,
    /// Monotonic adoption count (== weight-DAC charge events); unlike
    /// `adopted.len()` it never shrinks when dead entries are purged.
    adoptions: u64,
    /// Amortized purge threshold for `adopted` (see `obtain_plan`).
    adopted_purge_at: usize,
    /// Model name attributed to subsequent plan lookups (per-model store
    /// counters + eviction by model unload).
    model_tag: Option<String>,
    /// Seeded tile-capture fault injector (drift campaigns); `None` for
    /// normal serving.
    injector: Option<FaultInjector>,
}

impl RnsCore {
    pub fn new(cfg: RnsCoreConfig) -> Result<Self, String> {
        Self::with_engine(cfg, Box::new(NativeEngine::default()))
    }

    /// Core with a private plan store (standalone / sweep use).
    pub fn with_engine(cfg: RnsCoreConfig, engine: Box<dyn ModularGemmEngine>) -> Result<Self, String> {
        Self::with_engine_and_store(cfg, engine, Arc::new(PlanStore::default()))
    }

    /// Core borrowing plans from a shared store (the coordinator path:
    /// every worker gets a clone of one `Arc<PlanStore>`).
    pub fn with_store(cfg: RnsCoreConfig, store: Arc<PlanStore>) -> Result<Self, String> {
        Self::with_engine_and_store(cfg, Box::new(NativeEngine::default()), store)
    }

    pub fn with_engine_and_store(
        cfg: RnsCoreConfig,
        engine: Box<dyn ModularGemmEngine>,
        store: Arc<PlanStore>,
    ) -> Result<Self, String> {
        let all_moduli = if cfg.redundant > 0 {
            extend_moduli(&cfg.moduli, cfg.redundant)?
        } else {
            cfg.moduli.clone()
        };
        let all_ctx = RnsContext::new(&all_moduli)?;
        let code = if cfg.redundant > 0 {
            let c = RrnsCode::new(&all_moduli, cfg.moduli.len())?;
            // the legitimate range must still cover the per-tile dot product
            let b_out = required_output_bits(cfg.bits, cfg.bits, cfg.h);
            if c.legitimate_range < (1u128 << b_out) {
                return Err(format!(
                    "RRNS legitimate range 2^{:.1} < required 2^{b_out}",
                    (c.legitimate_range as f64).log2()
                ));
            }
            Some(c)
        } else {
            let b_out = required_output_bits(cfg.bits, cfg.bits, cfg.h);
            if all_ctx.big_m < (1u128 << b_out) {
                return Err(format!(
                    "RNS range 2^{:.1} < required 2^{b_out} (Eq. 4 violated)",
                    (all_ctx.big_m as f64).log2()
                ));
            }
            None
        };
        let units =
            all_moduli.iter().map(|&m| RnsMvmUnit::new(m, cfg.noise)).collect::<Vec<_>>();
        let rng = Rng::seed_from(cfg.seed ^ 0x5EED_CAFE);
        let injector = cfg.fault_injection.map(|(spec, seed)| FaultInjector::new(spec, seed));
        Ok(RnsCore {
            cfg,
            all_ctx,
            code,
            units,
            engine,
            meter: EnergyMeter::default(),
            stats: FaultStats::default(),
            stage_us: StageMicros::default(),
            rng,
            store,
            adopted: HashMap::new(),
            adoptions: 0,
            adopted_purge_at: ADOPTED_PURGE_FLOOR,
            model_tag: None,
            injector,
        })
    }

    pub fn n_channels(&self) -> usize {
        self.units.len()
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Layer plans this core has adopted (built here or first borrowed
    /// from the shared store) — the per-worker serving metric.  A plan
    /// evicted from the store and later rebuilt counts again, in step
    /// with its weight-DAC energy being re-charged.  The store's
    /// `stats().builds` is the deduplicated global build count.
    pub fn plans_built(&self) -> u64 {
        self.adoptions
    }

    /// The plan store this core borrows from (shared across workers in
    /// the coordinator, private otherwise).
    pub fn plan_store(&self) -> &Arc<PlanStore> {
        &self.store
    }

    /// Attribute subsequent plan lookups to `model` (per-model store
    /// counters; tagged plans are pinned until the model is unloaded).
    pub fn set_model_tag(&mut self, tag: &str) {
        if self.model_tag.as_deref() != Some(tag) {
            self.model_tag = Some(tag.to_string());
        }
    }

    /// Control-plane release (the counterpart of `set_model_tag`): drop
    /// the model tag if it names `model` and purge adoption entries whose
    /// plan the store has evicted.  The coordinator unloads the store
    /// *before* telling workers to release, so the unloaded model's
    /// adoptions are dead `Weak`s by the time this runs — purging them
    /// here (instead of at the next amortized threshold) means a worker
    /// that never serves the name again holds nothing for it.
    pub fn release_model(&mut self, model: &str) {
        if self.model_tag.as_deref() == Some(model) {
            self.model_tag = None;
        }
        self.adopted.retain(|_, plan| plan.strong_count() > 0);
        self.adopted_purge_at = (self.adopted.len() * 2).max(ADOPTED_PURGE_FLOOR);
    }

    /// Fetch (or build, exactly once store-wide) the layer plan for `w`,
    /// charging the one-time weight-DAC conversions when *this* core
    /// first adopts the plan — weights are stationary, so this is the
    /// only place weight conversions cost anything.
    pub fn prepare_weights(&mut self, w: &MatF) {
        let _ = self.obtain_plan(w);
    }

    fn obtain_plan(&mut self, w: &MatF) -> Arc<RnsPlan> {
        let key = PlanKey::for_weights(w, self.cfg.bits, self.cfg.h, &self.all_ctx.moduli);
        let plan = {
            let (bits, h) = (self.cfg.bits, self.cfg.h);
            let moduli = &self.all_ctx.moduli;
            self.store
                .get_or_build(key, self.model_tag.as_deref(), || RnsPlan::build(w, bits, h, moduli))
        };
        // adopted == this exact instance: a dead Weak (store evicted the
        // plan) or a different Arc (evicted + rebuilt) is a re-adoption
        // and re-charges the array load
        let already = self
            .adopted
            .get(&key)
            .and_then(Weak::upgrade)
            .is_some_and(|held| Arc::ptr_eq(&held, &plan));
        if !already {
            self.adopted.insert(key, Arc::downgrade(&plan));
            self.adoptions += 1;
            for u in &self.units {
                self.meter.record_dac(plan.weight_elems(), u.enob);
            }
            self.purge_dead_adoptions();
        }
        plan
    }

    /// Drop adoption entries whose plan the store has evicted, once the
    /// map grows past an amortized threshold: live entries are bounded by
    /// the store's residency, so sweep campaigns of one-shot weights keep
    /// `adopted` at O(store capacity) instead of one entry per weight
    /// ever seen.
    fn purge_dead_adoptions(&mut self) {
        if self.adopted.len() < self.adopted_purge_at {
            return;
        }
        self.adopted.retain(|_, plan| plan.strong_count() > 0);
        self.adopted_purge_at = (self.adopted.len() * 2).max(ADOPTED_PURGE_FLOOR);
    }

    /// Full quantized GEMM through the simulated RNS core (prepared path:
    /// the per-layer plan is fetched from the store — built on first
    /// sight anywhere — and only activations are processed per call).
    pub fn gemm_quantized(&mut self, x: &MatF, w: &MatF) -> MatF {
        assert_eq!(x.cols, w.rows, "gemm shape mismatch");
        let plan = self.obtain_plan(w);
        self.gemm_with_plan(x, &plan)
    }

    /// Prepared GEMM against an explicit plan (the coordinator path).
    pub fn gemm_with_plan(&mut self, x: &MatF, plan: &RnsPlan) -> MatF {
        assert_eq!(x.cols, plan.k, "gemm shape mismatch");
        assert_eq!(plan.bits, self.cfg.bits, "plan built for different precision");
        assert_eq!(plan.h, self.cfg.h, "plan tiled for a different array height");
        assert_eq!(
            plan.moduli, self.all_ctx.moduli,
            "plan built for a different channel set (info + redundant moduli)"
        );
        let qa = quantize_activations(x, self.cfg.bits);
        let mut acc = MatI::zeros(x.rows, plan.n);
        for tile in &plan.tiles {
            let xt = qa.q.slice_cols(tile.k0, tile.k1);
            let part = self.tile_mvm_prepared(&xt, &tile.weights);
            for (a, &p) in acc.data.iter_mut().zip(&part.data) {
                *a += p;
            }
        }
        dequantize(&acc, &qa, &plan.qw)
    }

    /// Reference path: re-quantizes and re-converts the weights on every
    /// call (the pre-plan behavior, minus the weight-DAC over-count —
    /// weight conversions are charged once per call here, not once per
    /// tile).  Kept for the prepared-vs-unprepared equivalence tests and
    /// bench baselines; bit-identical to `gemm_quantized` under the same
    /// seed by construction.
    pub fn gemm_quantized_unprepared(&mut self, x: &MatF, w: &MatF) -> MatF {
        assert_eq!(x.cols, w.rows, "gemm shape mismatch");
        let qa = quantize_activations(x, self.cfg.bits);
        let qw = quantize_weights(w, self.cfg.bits);
        for u in &self.units {
            self.meter.record_dac((w.rows * w.cols) as u64, u.enob);
        }
        let mut acc = MatI::zeros(x.rows, w.cols);
        let k = x.cols;
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + self.cfg.h).min(k);
            let xt = qa.q.slice_cols(k0, k1);
            let wt = qw.q.slice_rows(k0, k1);
            let part = self.tile_mvm_unprepared(&xt, &wt);
            for (a, &p) in acc.data.iter_mut().zip(&part.data) {
                *a += p;
            }
            k0 = k1;
        }
        dequantize(&acc, &qa, &qw)
    }

    /// One prepared tile through the analog channels + decode (signed
    /// output).  Only activations are converted here; the weight side
    /// comes pre-staged from the plan.
    fn tile_mvm_prepared(&mut self, xt: &MatI, wt: &PreparedWeights) -> MatI {
        let t0 = Instant::now();
        let (xr, zero_rows) = self.forward_activations(xt);
        self.stage_us.dac_forward_us += elapsed_us(t0);
        // clean channel outputs (the engine is the ideal analog array)
        let t1 = Instant::now();
        let clean = self.engine.matmul_mod_prepared(&xr, wt);
        self.stage_us.analog_gemm_us += elapsed_us(t1);
        self.capture_and_decode(clean, zero_rows)
    }

    /// One unprepared tile: forward-converts both operands (reference path).
    fn tile_mvm_unprepared(&mut self, xt: &MatI, wt: &MatI) -> MatI {
        let t0 = Instant::now();
        let (xr, zero_rows) = self.forward_activations(xt);
        let moduli = &self.all_ctx.moduli;
        let wr: Vec<MatI> =
            moduli.iter().map(|&m| forward_residues(wt, m, self.cfg.bits)).collect();
        self.stage_us.dac_forward_us += elapsed_us(t0);
        let t1 = Instant::now();
        let clean = self.engine.matmul_mod(&xr, &wr, moduli);
        self.stage_us.analog_gemm_us += elapsed_us(t1);
        self.capture_and_decode(clean, zero_rows)
    }

    /// Forward-convert one activation tile into every channel and charge
    /// the activation-DAC.  Dense: every element, every channel.  Sparse
    /// capture: only nonzero elements are converted/charged (a zero
    /// activation's residue is 0 in every channel, so no DAC needs to
    /// fire); the remainder is counted as `skipped_dac`.  Also returns
    /// the per-row all-zero mask (`None` when dense) that
    /// `capture_and_decode` uses to skip structurally-zero output rows.
    fn forward_activations(&mut self, xt: &MatI) -> (Vec<MatI>, Option<Vec<bool>>) {
        let moduli = &self.all_ctx.moduli;
        if !self.cfg.sparse_capture {
            let xr: Vec<MatI> =
                moduli.iter().map(|&m| forward_residues(xt, m, self.cfg.bits)).collect();
            for u in &self.units {
                self.meter.record_dac((xt.rows * xt.cols) as u64, u.enob);
            }
            return (xr, None);
        }
        let mut zero_rows = vec![true; xt.rows];
        let mut nnz = 0u64;
        for (r, flag) in zero_rows.iter_mut().enumerate() {
            for &v in xt.row(r) {
                if v != 0 {
                    *flag = false;
                    nnz += 1;
                }
            }
        }
        let xr: Vec<MatI> = moduli
            .iter()
            .map(|&m| forward_residues_sparse(xt, m, self.cfg.bits))
            .collect();
        for u in &self.units {
            self.meter.record_dac(nnz, u.enob);
        }
        let zeros = (xt.rows * xt.cols) as u64 - nnz;
        let channels = self.units.len() as u64;
        self.meter.record_skipped_dac(zeros * channels);
        (xr, Some(zero_rows))
    }

    /// ADC capture with noise, per channel, then decode.  Serial on purpose:
    /// all rng draws happen here in channel-major order, so outputs are
    /// identical whatever the engine's parallel schedule was.
    ///
    /// `zero_rows` (sparse capture only) marks activation rows that were
    /// all zeros; after array-side injection the candidates are verified
    /// against the clean channel outputs and the surviving rows bypass
    /// capture and decode entirely (see `capture_and_decode_masked`).
    fn capture_and_decode(&mut self, mut clean: Vec<MatI>, zero_rows: Option<Vec<bool>>) -> MatI {
        // array-side drift corrupts the channel outputs *before* capture:
        // the retry loop recomputes from the same corrupted values, so a
        // burst wider than t exhausts `max_attempts` instead of being
        // recovered — the event only clears when its tile budget expires
        if self.cfg.fault_site == InjectionSite::Array {
            if let Some(inj) = &mut self.injector {
                inj.corrupt_tile(&mut clean, &self.all_ctx.moduli);
            }
        }
        if let Some(mut skip) = zero_rows {
            // a row is skippable only while every channel's clean output
            // row is still exactly 0 — array-side injection can corrupt a
            // structurally-zero row, and a corrupted row must be captured
            // and decoded like any other so detection/voting still sees it
            for (r, flag) in skip.iter_mut().enumerate() {
                if *flag && !clean.iter().all(|ch| ch.row(r).iter().all(|&v| v == 0)) {
                    *flag = false;
                }
            }
            if skip.iter().any(|&z| z) {
                return self.capture_and_decode_masked(clean, &skip);
            }
        }
        let t0 = Instant::now();
        let mut captured: Vec<MatI> = Vec::with_capacity(clean.len());
        for (u, ch) in self.units.iter().zip(&clean) {
            captured.push(u.recapture(ch, &mut self.rng, &mut self.meter));
        }
        self.stage_us.adc_capture_us += elapsed_us(t0);
        // capture-side drift corrupts the captured residues only: the
        // retry loop recomputes from `clean` (plus the noise model), so
        // a detected injected fault is recoverable by recompute
        if self.cfg.fault_site == InjectionSite::Capture {
            if let Some(inj) = &mut self.injector {
                inj.corrupt_tile(&mut captured, &self.all_ctx.moduli);
            }
        }
        let t1 = Instant::now();
        let out = self.decode_tile(&clean, captured);
        self.stage_us.decode_us += elapsed_us(t1);
        out
    }

    /// Sparse capture with at least one verified structurally-zero row:
    /// compact the kept rows, run the unmodified capture → inject →
    /// decode pipeline on the compacted tile, and scatter the decoded
    /// rows back around true zeros.
    ///
    /// The ADCs never see the skipped rows, so noise draws, retry loops,
    /// and CRT charges all operate on kept rows only — in the same
    /// row-major order dense capture visits them — and skipped rows are
    /// counted in none of `decoded`/`fast_path_elems`/`voted_elems`.
    /// Under `NoiseModel::None` (no draws at all) this is bit-identical
    /// to the dense path: a structurally-zero row decodes to exactly 0.
    fn capture_and_decode_masked(&mut self, clean: Vec<MatI>, skip: &[bool]) -> MatI {
        let (rows, cols) = (clean[0].rows, clean[0].cols);
        let kept: Vec<usize> = (0..rows).filter(|&r| !skip[r]).collect();
        let skipped = rows - kept.len();
        let channels = self.units.len() as u64;
        self.meter.record_skipped_adc((skipped * cols) as u64 * channels);
        self.stats.skipped_rows += skipped as u64;
        if kept.is_empty() {
            // whole tile structurally zero: no capture, no decode, no
            // RNG draws, zero ADC conversions, zero CRT charges
            return MatI::zeros(rows, cols);
        }
        let compact = |ch: &MatI| {
            let mut out = MatI::zeros(kept.len(), cols);
            for (dst, &src) in kept.iter().enumerate() {
                out.row_mut(dst).copy_from_slice(ch.row(src));
            }
            out
        };
        let clean_kept: Vec<MatI> = clean.iter().map(compact).collect();
        let t0 = Instant::now();
        let mut captured: Vec<MatI> = Vec::with_capacity(clean_kept.len());
        for (u, ch) in self.units.iter().zip(&clean_kept) {
            captured.push(u.recapture(ch, &mut self.rng, &mut self.meter));
        }
        self.stage_us.adc_capture_us += elapsed_us(t0);
        if self.cfg.fault_site == InjectionSite::Capture {
            if let Some(inj) = &mut self.injector {
                inj.corrupt_tile(&mut captured, &self.all_ctx.moduli);
            }
        }
        let t1 = Instant::now();
        let decoded = self.decode_tile(&clean_kept, captured);
        self.stage_us.decode_us += elapsed_us(t1);
        let mut out = MatI::zeros(rows, cols);
        for (src, &dst) in kept.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(decoded.row(src));
        }
        out
    }

    /// Decode every output element of one tile.
    ///
    /// Plain RNS tiles go through the batch CRT.  RRNS tiles take the
    /// two-tier pipeline: a whole-tile consistency pre-check batch-decodes
    /// everything clean, and only mismatching elements run the per-element
    /// voting + retry loop (`decode_tile_reference` keeps the original
    /// all-voting path; the two are bit-identical — fast-path elements
    /// draw no randomness in either path, and fallback elements are
    /// visited in the same row-major order, so the RNG stream, the output
    /// matrix, and the energy totals all agree exactly).
    fn decode_tile(&mut self, clean: &[MatI], captured: Vec<MatI>) -> MatI {
        if self.code.is_none() {
            // plain RNS: no retry loop, so the whole tile decodes in
            // one batch CRT pass (hoisted coefficients, see crt.rs)
            let elems = (captured[0].rows * captured[0].cols) as u64;
            self.stats.decoded += elems;
            self.meter.record_crt(elems);
            return self.all_ctx.crt_signed_tile(&captured);
        }
        if self.cfg.reference_decode {
            self.decode_tile_reference(clean, captured)
        } else {
            self.decode_tile_batched(clean, captured)
        }
    }

    /// Two-tier RRNS decode: batched no-fault fast path + voting fallback.
    fn decode_tile_batched(&mut self, clean: &[MatI], mut captured: Vec<MatI>) -> MatI {
        let code = self.code.as_ref().expect("RRNS decode without a code");
        let (rows, cols) = (clean[0].rows, clean[0].cols);
        let elems = (rows * cols) as u64;
        let n = self.units.len();
        // tier 1: one batch CRT over the information moduli for the whole
        // tile, re-encoded into the redundant channels and compared
        let pre = code.precheck_tile(&captured);
        self.stats.decoded += elems;
        self.stats.fast_path_elems += elems - pre.fallback.len() as u64;
        self.stats.voted_elems += pre.fallback.len() as u64;
        // one CRT per element, as the reference path charges
        self.meter.record_crt(elems);
        let mut out = pre.values;
        // tier 2: per-element voting + retry, only where the pre-check
        // failed, in row-major order (RNG parity with the reference path)
        let mut residues = vec![0u64; n];
        for &e in &pre.fallback {
            for (res, ch) in residues.iter_mut().zip(&captured) {
                *res = ch.data[e] as u64;
            }
            out.data[e] = self.vote_element(clean, &mut captured, &mut residues, e);
        }
        out
    }

    /// Reference path: the original per-element voting decode for every
    /// element.  Kept (behind `RnsCoreConfig::reference_decode`) as the
    /// bit-identical baseline for the equivalence tests and benches.
    fn decode_tile_reference(&mut self, clean: &[MatI], mut captured: Vec<MatI>) -> MatI {
        let (rows, cols) = (clean[0].rows, clean[0].cols);
        let n = self.units.len();
        let mut out = MatI::zeros(rows, cols);
        let mut residues = vec![0u64; n];
        for e in 0..rows * cols {
            for (res, ch) in residues.iter_mut().zip(&captured) {
                *res = ch.data[e] as u64;
            }
            self.stats.decoded += 1;
            self.stats.voted_elems += 1;
            self.meter.record_crt(1);
            out.data[e] = self.vote_element(clean, &mut captured, &mut residues, e);
        }
        out
    }

    /// Voting decode of one element (linear index `e`), with the paper's
    /// detect → recompute retry loop.  Shared verbatim by the reference
    /// path and the batched path's tier-2 fallback: any change here keeps
    /// the two bit-identical by construction.
    fn vote_element(
        &mut self,
        clean: &[MatI],
        captured: &mut [MatI],
        residues: &mut [u64],
        e: usize,
    ) -> i64 {
        let code = self.code.as_ref().expect("RRNS decode without a code");
        let n = self.units.len();
        let mut attempt = 0;
        loop {
            match code.decode(residues) {
                Decode::Ok { value, suspects } => {
                    if !suspects.is_empty() {
                        self.stats.corrected += 1;
                    }
                    return value as i64;
                }
                Decode::Detected => {
                    self.stats.detections += 1;
                    attempt += 1;
                    if attempt >= self.cfg.max_attempts {
                        self.stats.exhausted += 1;
                        // fall back to the maximum-likelihood
                        // candidate (most consistent residues)
                        return code.decode_best_effort(residues) as i64;
                    }
                    // recompute the dot product: fresh noise
                    // on each channel's clean value
                    for i in 0..n {
                        let cv = clean[i].data[e] as u64;
                        let noisy = self.units[i].noise.apply_residue(
                            cv,
                            self.units[i].modulus,
                            &mut self.rng,
                        );
                        residues[i] = noisy;
                        self.meter.record_adc(1, self.units[i].enob);
                        captured[i].data[e] = noisy as i64;
                    }
                    self.meter.record_crt(1);
                }
            }
        }
    }
}

impl GemmBackend for RnsCore {
    fn gemm(&mut self, x: &MatF, w: &MatF) -> MatF {
        self.gemm_quantized(x, w)
    }
    fn prepare(&mut self, w: &MatF) {
        self.prepare_weights(w);
    }
    fn plans_built(&self) -> u64 {
        RnsCore::plans_built(self)
    }
    fn set_model_tag(&mut self, tag: &str) {
        RnsCore::set_model_tag(self, tag);
    }
    fn release_model(&mut self, model: &str) {
        RnsCore::release_model(self, model);
    }
    fn name(&self) -> String {
        let rr = if self.cfg.redundant > 0 {
            format!("+rrns({},{})", self.n_channels(), self.cfg.moduli.len())
        } else {
            String::new()
        };
        format!("rns-b{}{rr}", self.cfg.bits)
    }
    fn meter(&self) -> Option<EnergyMeter> {
        Some(self.meter)
    }
    fn fault_stats(&self) -> Option<FaultStats> {
        Some(self.stats)
    }
    fn stage_micros(&self) -> Option<StageMicros> {
        Some(self.stage_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gemm::gemm_f32;

    fn rand_mat(seed: u64, rows: usize, cols: usize, scale: f32) -> MatF {
        let mut rng = Rng::seed_from(seed);
        MatF::from_vec(rows, cols, (0..rows * cols).map(|_| rng.uniform_f32(-scale, scale)).collect())
    }

    #[test]
    fn clean_rns_error_is_quantization_only() {
        // paper claim: no information loss beyond quantization.
        let x = rand_mat(1, 4, 128, 1.0);
        let w = rand_mat(2, 128, 8, 0.5);
        let want = gemm_f32(&x, &w);
        for bits in [4u32, 6, 8] {
            let mut core = RnsCore::new(RnsCoreConfig::for_bits(bits, 128)).unwrap();
            let got = core.gemm_quantized(&x, &w);
            let qm = crate::quant::qmax(bits) as f32;
            let tol = 128.0 * 1.5 / qm; // conservative quantization bound
            for (g, f) in got.data.iter().zip(&want.data) {
                assert!((g - f).abs() < tol, "bits={bits}: {g} vs {f}");
            }
        }
    }

    #[test]
    fn rns_beats_fixed_point_same_bits() {
        use crate::analog::fixed_point_core::FixedPointCore;
        let x = rand_mat(3, 4, 128, 1.0);
        let w = rand_mat(4, 128, 8, 0.5);
        let want = gemm_f32(&x, &w);
        let mean_err = |got: &MatF| {
            got.data.iter().zip(&want.data).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
                / want.data.len() as f64
        };
        for bits in [4u32, 6, 8] {
            let mut rns = RnsCore::new(RnsCoreConfig::for_bits(bits, 128)).unwrap();
            let mut fxp = FixedPointCore::new(bits, 128, NoiseModel::None, 0);
            let e_rns = mean_err(&rns.gemm_quantized(&x, &w));
            let e_fxp = mean_err(&fxp.gemm_quantized(&x, &w));
            assert!(e_fxp > 3.0 * e_rns, "bits={bits}: fxp {e_fxp} rns {e_rns}");
        }
    }

    #[test]
    fn tiled_equals_wide_array_when_clean() {
        // K = 256 on h=128 (2 tiles) must equal h=256 (1 tile): RNS loses
        // nothing at tile boundaries (unlike the fixed-point core).
        let x = rand_mat(5, 2, 256, 1.0);
        let w = rand_mat(6, 256, 4, 1.0);
        let mut a = RnsCore::new(RnsCoreConfig::for_bits(8, 128)).unwrap();
        let mut cfg_wide = RnsCoreConfig::for_bits(8, 128);
        cfg_wide.h = 256;
        cfg_wide.moduli = select_moduli(8, 256).unwrap();
        let mut b = RnsCore::new(cfg_wide).unwrap();
        let ya = a.gemm_quantized(&x, &w);
        let yb = b.gemm_quantized(&x, &w);
        for (p, q) in ya.data.iter().zip(&yb.data) {
            assert!((p - q).abs() < 1e-6);
        }
    }

    #[test]
    fn eq4_violation_rejected() {
        let mut cfg = RnsCoreConfig::for_bits(4, 128);
        cfg.moduli = vec![15, 14]; // M = 210 << 2^14
        assert!(RnsCore::new(cfg).is_err());
    }

    #[test]
    fn rrns_restores_accuracy_under_noise() {
        let x = rand_mat(7, 4, 128, 1.0);
        let w = rand_mat(8, 128, 8, 0.5);
        let want = gemm_f32(&x, &w);
        let mean_err = |got: &MatF| {
            got.data.iter().zip(&want.data).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
                / want.data.len() as f64
        };
        let noise = NoiseModel::ResidueFlip { p: 0.02 };
        let mut plain =
            RnsCore::new(RnsCoreConfig::for_bits(8, 128).with_noise(noise).with_seed(1)).unwrap();
        let mut protected = RnsCore::new(
            RnsCoreConfig::for_bits(8, 128).with_noise(noise).with_rrns(2, 3).with_seed(1),
        )
        .unwrap();
        let e_plain = mean_err(&plain.gemm_quantized(&x, &w));
        let e_prot = mean_err(&protected.gemm_quantized(&x, &w));
        assert!(
            e_prot < e_plain / 10.0,
            "rrns {e_prot} should be far below unprotected {e_plain}"
        );
        assert!(protected.stats.corrected > 0, "some corrections should have happened");
    }

    #[test]
    fn rrns_range_check() {
        // too much redundancy shrinks the legitimate range below Eq. 4
        let mut cfg = RnsCoreConfig::for_bits(4, 128).with_rrns(3, 2);
        cfg.moduli = vec![15, 14, 13, 11];
        // redundant candidates 9?? gcd(9,15)=3 -> 8? gcd(8,14)=2 -> 7? gcd(7,14)=7
        // -> legit range with small redundant moduli collapses
        assert!(RnsCore::new(cfg).is_err());
    }

    #[test]
    fn stats_and_energy_flow() {
        let x = rand_mat(9, 2, 128, 1.0);
        let w = rand_mat(10, 128, 4, 1.0);
        let mut core = RnsCore::new(
            RnsCoreConfig::for_bits(6, 128)
                .with_noise(NoiseModel::ResidueFlip { p: 0.01 })
                .with_rrns(2, 2),
        )
        .unwrap();
        core.gemm_quantized(&x, &w);
        assert_eq!(core.stats.decoded, 8);
        // two-tier split partitions the decoded elements exactly
        assert_eq!(core.stats.fast_path_elems + core.stats.voted_elems, 8);
        assert!(core.meter.adc_conversions >= 8 * core.n_channels() as u64);
        assert!(core.meter.total_joules() > 0.0);
    }

    #[test]
    fn clean_rrns_tiles_never_vote() {
        let x = rand_mat(30, 3, 256, 1.0);
        let w = rand_mat(31, 256, 5, 1.0);
        let mut core =
            RnsCore::new(RnsCoreConfig::for_bits(8, 128).with_rrns(2, 3)).unwrap();
        core.gemm_quantized(&x, &w);
        // 2 K-tiles x 3x5 outputs, all clean: everything fast-paths
        assert_eq!(core.stats.decoded, 2 * 15);
        assert_eq!(core.stats.fast_path_elems, 2 * 15);
        assert_eq!(core.stats.voted_elems, 0);
        assert_eq!(core.stats.detections, 0);
        assert_eq!(core.stats.corrected, 0);
    }

    #[test]
    fn batched_decode_matches_reference_decode() {
        let x = rand_mat(32, 4, 200, 1.0);
        let w = rand_mat(33, 200, 6, 0.5);
        let cfg = RnsCoreConfig::for_bits(8, 128)
            .with_noise(NoiseModel::ResidueFlip { p: 0.03 })
            .with_rrns(2, 3)
            .with_seed(99);
        let mut fast = RnsCore::new(cfg.clone()).unwrap();
        let mut refc = RnsCore::new(cfg.with_reference_decode(true)).unwrap();
        let ya = fast.gemm_quantized(&x, &w);
        let yb = refc.gemm_quantized(&x, &w);
        assert_eq!(ya.data, yb.data, "two-tier decode must be bit-identical");
        assert_eq!(fast.stats.decoded, refc.stats.decoded);
        assert_eq!(fast.stats.corrected, refc.stats.corrected);
        assert_eq!(fast.stats.detections, refc.stats.detections);
        assert_eq!(fast.stats.exhausted, refc.stats.exhausted);
        assert_eq!(refc.stats.voted_elems, refc.stats.decoded);
        assert_eq!(refc.stats.fast_path_elems, 0);
        assert_eq!(fast.stats.fast_path_elems + fast.stats.voted_elems, fast.stats.decoded);
        assert!(fast.stats.fast_path_elems > 0, "p=0.03 leaves most elements clean");
    }

    #[test]
    fn plan_is_reused_and_weight_dac_charged_once() {
        let x = rand_mat(11, 2, 128, 1.0);
        let w = rand_mat(12, 128, 4, 1.0);
        let mut core = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
        core.gemm_quantized(&x, &w);
        let dac_after_first = core.meter.dac_conversions;
        let n = core.n_channels() as u64;
        // first call: weights (128*4) once + inputs (2*128), per channel
        assert_eq!(dac_after_first, n * (128 * 4 + 2 * 128));
        assert_eq!(core.plans_built(), 1);
        core.gemm_quantized(&x, &w);
        // second call on the same layer: inputs only, no new plan
        assert_eq!(core.meter.dac_conversions, dac_after_first + n * 2 * 128);
        assert_eq!(core.plans_built(), 1);
        // a different weight matrix is a different layer
        let w2 = rand_mat(13, 128, 4, 1.0);
        core.gemm_quantized(&x, &w2);
        assert_eq!(core.plans_built(), 2);
    }

    #[test]
    fn prepare_weights_warms_the_cache() {
        let x = rand_mat(14, 3, 128, 1.0);
        let w = rand_mat(15, 128, 6, 1.0);
        let mut core = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
        core.prepare_weights(&w);
        assert_eq!(core.plans_built(), 1);
        let dac_after_warm = core.meter.dac_conversions;
        core.gemm_quantized(&x, &w);
        assert_eq!(core.plans_built(), 1, "warm plan must be reused");
        let n = core.n_channels() as u64;
        assert_eq!(core.meter.dac_conversions, dac_after_warm + n * 3 * 128);
    }

    #[test]
    fn untagged_plan_store_is_bounded() {
        // one-shot weight sweeps (fig3-style) must not accumulate plans:
        // a core without a model tag writes LRU-bounded store entries
        use crate::store::DEFAULT_UNTAGGED_CAPACITY;
        let x = rand_mat(20, 1, 32, 1.0);
        let mut core = RnsCore::new(RnsCoreConfig::for_bits(4, 32)).unwrap();
        let sweeps = DEFAULT_UNTAGGED_CAPACITY + 10;
        for i in 0..sweeps {
            let w = rand_mat(100 + i as u64, 32, 2, 1.0);
            core.gemm_quantized(&x, &w);
        }
        assert_eq!(core.plans_built(), sweeps as u64);
        let s = core.plan_store().stats();
        assert_eq!(s.builds, sweeps as u64);
        assert_eq!(s.resident_plans, DEFAULT_UNTAGGED_CAPACITY);
        assert_eq!(s.evicted, 10);
        // the adoption map purges entries for evicted plans, so it stays
        // O(store capacity) across the campaign instead of O(sweeps)
        assert!(
            core.adopted.len() <= 2 * DEFAULT_UNTAGGED_CAPACITY,
            "adopted map must stay bounded, got {}",
            core.adopted.len()
        );
    }

    #[test]
    fn evicted_plan_readoption_recharges_weight_dac() {
        // PR-1 accounting: rebuilding an evicted plan reloads the arrays,
        // so the one-time weight-DAC cost is charged again
        use crate::store::PlanStore;
        let x = rand_mat(50, 1, 32, 1.0);
        let w = rand_mat(51, 32, 2, 1.0);
        let w2 = rand_mat(52, 32, 2, 1.0);
        let store = Arc::new(PlanStore::with_capacity(1));
        let mut core =
            RnsCore::with_store(RnsCoreConfig::for_bits(4, 32), Arc::clone(&store)).unwrap();
        core.gemm_quantized(&x, &w);
        assert_eq!(core.plans_built(), 1);
        let n = core.n_channels() as u64;
        let weight_dac = n * 32 * 2;
        // w2 evicts w's plan from the capacity-1 store
        core.gemm_quantized(&x, &w2);
        assert_eq!(core.plans_built(), 2);
        let dac_before = core.meter.dac_conversions;
        // returning to w rebuilds the plan: re-adopted, re-charged
        core.gemm_quantized(&x, &w);
        assert_eq!(core.plans_built(), 3);
        assert_eq!(store.stats().builds, 3);
        assert_eq!(core.meter.dac_conversions, dac_before + weight_dac + n * 32);
        // a still-resident plan is not re-charged
        core.gemm_quantized(&x, &w);
        assert_eq!(core.plans_built(), 3);
    }

    #[test]
    fn shared_store_builds_once_but_charges_each_core() {
        // two workers' cores over one store: one plan build, one Arc —
        // but each simulated accelerator still loads its own arrays, so
        // weight-DAC energy is charged per core
        use crate::store::PlanStore;
        use std::sync::Arc;
        let x = rand_mat(40, 2, 128, 1.0);
        let w = rand_mat(41, 128, 4, 1.0);
        let store = Arc::new(PlanStore::default());
        let mut a = RnsCore::with_store(RnsCoreConfig::for_bits(6, 128), Arc::clone(&store)).unwrap();
        let mut b = RnsCore::with_store(RnsCoreConfig::for_bits(6, 128), Arc::clone(&store)).unwrap();
        let ya = a.gemm_quantized(&x, &w);
        let yb = b.gemm_quantized(&x, &w);
        assert_eq!(ya.data, yb.data);
        assert_eq!(store.stats().builds, 1, "plan deduplicated across cores");
        assert_eq!(a.plans_built(), 1);
        assert_eq!(b.plans_built(), 1, "adoption is per core");
        assert_eq!(a.meter.dac_conversions, b.meter.dac_conversions);
        // a different moduli config on the same store is a different plan
        let mut c = RnsCore::with_store(RnsCoreConfig::for_bits(8, 128), store.clone()).unwrap();
        c.gemm_quantized(&x, &w);
        assert_eq!(store.stats().builds, 2);
    }

    #[test]
    fn prepared_matches_unprepared_reference() {
        let x = rand_mat(16, 5, 300, 1.0);
        let w = rand_mat(17, 300, 9, 0.5);
        let mut a = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
        let mut b = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
        let ya = a.gemm_quantized(&x, &w);
        let yb = b.gemm_quantized_unprepared(&x, &w);
        assert_eq!(ya.data, yb.data, "prepared path must be bit-identical");
    }

    /// ~50%-sparse ReLU-style batch with two all-zero sample rows.
    fn sparse_batch(seed: u64, rows: usize, k: usize) -> MatF {
        let mut rng = Rng::seed_from(seed);
        let mut x = MatF::from_vec(
            rows,
            k,
            (0..rows * k).map(|_| rng.uniform_f32(-1.0, 1.0).max(0.0)).collect(),
        );
        for r in [1, rows - 2] {
            for v in x.row_mut(r) {
                *v = 0.0;
            }
        }
        x
    }

    #[test]
    fn sparse_capture_bit_identical_and_cheaper_all_decode_paths() {
        // the tentpole contract: under NoiseModel::None with no injector,
        // sparse capture is bit-identical to dense on every decode path
        // (plain CRT, RRNS batched, RRNS reference) with strictly fewer
        // DAC/ADC conversions on a 50%-sparse ReLU workload
        let x = sparse_batch(60, 6, 256);
        let w = rand_mat(61, 256, 8, 0.5);
        let configs: Vec<(&str, RnsCoreConfig)> = vec![
            ("plain", RnsCoreConfig::for_bits(6, 128)),
            ("rrns-batched", RnsCoreConfig::for_bits(8, 128).with_rrns(2, 2)),
            (
                "rrns-reference",
                RnsCoreConfig::for_bits(8, 128).with_rrns(2, 2).with_reference_decode(true),
            ),
        ];
        for (name, cfg) in configs {
            let mut dense = RnsCore::new(cfg.clone()).unwrap();
            let mut sparse = RnsCore::new(cfg.with_sparse_capture(true)).unwrap();
            let yd = dense.gemm_quantized(&x, &w);
            let ys = sparse.gemm_quantized(&x, &w);
            assert_eq!(yd.data, ys.data, "{name}: sparse output must be bit-identical");
            assert!(
                sparse.meter.dac_conversions < dense.meter.dac_conversions,
                "{name}: dac {} !< {}",
                sparse.meter.dac_conversions,
                dense.meter.dac_conversions
            );
            assert!(
                sparse.meter.adc_conversions < dense.meter.adc_conversions,
                "{name}: adc {} !< {}",
                sparse.meter.adc_conversions,
                dense.meter.adc_conversions
            );
            assert!(sparse.meter.total_joules() < dense.meter.total_joules(), "{name}");
            assert!(sparse.meter.skipped_dac > 0 && sparse.meter.skipped_adc > 0, "{name}");
            assert_eq!(dense.meter.skipped_dac, 0, "{name}: dense never skips");
            assert_eq!(dense.meter.skipped_adc, 0, "{name}: dense never skips");
            // 2 zero sample rows x 2 K-tiles
            assert_eq!(sparse.stats.skipped_rows, 4, "{name}");
            // skipped rows appear in no decode counter
            assert_eq!(
                sparse.stats.decoded,
                dense.stats.decoded - sparse.stats.skipped_rows * w.cols as u64,
                "{name}"
            );
            // conservation: performed + skipped == the dense totals
            assert_eq!(
                sparse.meter.dac_conversions + sparse.meter.skipped_dac,
                dense.meter.dac_conversions,
                "{name}"
            );
            assert_eq!(
                sparse.meter.adc_conversions + sparse.meter.skipped_adc,
                dense.meter.adc_conversions,
                "{name}"
            );
        }
    }

    #[test]
    fn sparse_capture_all_zero_tile_converts_nothing() {
        // exactness: an all-zero input performs zero activation-DAC, zero
        // ADC conversions, and zero CRT charges — only the one-time
        // weight-DAC plan charge remains
        let x = MatF::zeros(3, 128);
        let w = rand_mat(62, 128, 5, 0.5);
        let cfg = RnsCoreConfig::for_bits(6, 128).with_sparse_capture(true);
        let mut core = RnsCore::new(cfg).unwrap();
        let y = core.gemm_quantized(&x, &w);
        assert!(y.data.iter().all(|&v| v == 0.0));
        let n = core.n_channels() as u64;
        assert_eq!(core.meter.adc_conversions, 0);
        assert_eq!(core.meter.digital_joules, 0.0, "no CRT charges");
        assert_eq!(core.meter.dac_conversions, n * 128 * 5, "weight-DAC only");
        assert_eq!(core.meter.skipped_dac, n * 3 * 128);
        assert_eq!(core.meter.skipped_adc, n * 3 * 5);
        assert_eq!(core.stats.skipped_rows, 3);
        assert_eq!(core.stats.decoded, 0);
        // dense reference on the same input agrees bit-for-bit
        let mut dense = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
        assert_eq!(dense.gemm_quantized(&x, &w).data, y.data);
    }

    #[test]
    fn sparse_capture_unprepared_path_matches_dense() {
        let x = sparse_batch(63, 5, 300);
        let w = rand_mat(64, 300, 7, 0.5);
        let mut dense = RnsCore::new(RnsCoreConfig::for_bits(6, 128)).unwrap();
        let mut sparse =
            RnsCore::new(RnsCoreConfig::for_bits(6, 128).with_sparse_capture(true)).unwrap();
        let yd = dense.gemm_quantized_unprepared(&x, &w);
        let ys = sparse.gemm_quantized_unprepared(&x, &w);
        assert_eq!(yd.data, ys.data);
        assert!(sparse.meter.adc_conversions < dense.meter.adc_conversions);
        assert!(sparse.stats.skipped_rows > 0);
    }

    #[test]
    fn sparse_capture_array_injected_zero_rows_are_not_skipped() {
        // array-side drift can corrupt a structurally-zero row; such a row
        // must be captured and decoded like any other.  With the same
        // injector seed and no noise, dense and sparse see the identical
        // full-size clean tile at injection time, so outputs must agree
        // bit-for-bit: corrupted zero rows decode identically, untouched
        // zero rows are emitted as true zeros.
        let x = MatF::zeros(4, 128);
        let w = rand_mat(65, 128, 6, 0.5);
        let base = RnsCoreConfig::for_bits(8, 128)
            .with_rrns(2, 2)
            .with_fault_injection(FaultSpec::Burst { elems: 3, width: 1 }, 77)
            .with_fault_site(InjectionSite::Array);
        let mut dense = RnsCore::new(base.clone()).unwrap();
        let mut sparse = RnsCore::new(base.with_sparse_capture(true)).unwrap();
        let yd = dense.gemm_quantized(&x, &w);
        let ys = sparse.gemm_quantized(&x, &w);
        assert_eq!(yd.data, ys.data);
        // the burst hit at least one element, so at least one of the 4
        // candidate rows was rescued from skipping
        assert!(sparse.stats.skipped_rows < 4, "corrupted rows must not be skipped");
        assert!(sparse.stats.skipped_rows > 0, "untouched rows still skip");
    }

    #[test]
    fn sparse_capture_noise_is_seeded_deterministic() {
        // with noise active the RNG stream legitimately differs from
        // dense — pin seeded determinism instead, and the counter
        // relations that must hold regardless
        let x = sparse_batch(66, 6, 256);
        let w = rand_mat(67, 256, 8, 0.5);
        let cfg = RnsCoreConfig::for_bits(8, 128)
            .with_rrns(2, 3)
            .with_noise(NoiseModel::ResidueFlip { p: 0.05 })
            .with_seed(9)
            .with_sparse_capture(true);
        let mut a = RnsCore::new(cfg.clone()).unwrap();
        let mut b = RnsCore::new(cfg).unwrap();
        let ya = a.gemm_quantized(&x, &w);
        let yb = b.gemm_quantized(&x, &w);
        assert_eq!(ya.data, yb.data, "same seed, same sparse output");
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.meter.adc_conversions, b.meter.adc_conversions);
        // skipped rows never land in decoded / fast_path / voted
        assert_eq!(a.stats.fast_path_elems + a.stats.voted_elems, a.stats.decoded);
        assert_eq!(a.stats.skipped_rows, 4);
        let total_elems = 2 * (x.rows * w.cols) as u64; // 2 K-tiles
        assert_eq!(a.stats.decoded, total_elems - a.stats.skipped_rows * w.cols as u64);
    }
}
