//! Per-tile analog MVM unit simulators.
//!
//! One `RnsMvmUnit` is the digital twin of one residue channel in Fig. 2:
//! a fixed h×h analog array that multiplies a residue tile, applies the
//! analog-domain modulo, suffers noise, and is captured by b-bit ADCs.
//! `FixedPointMvmUnit` is the baseline core's array: exact analog MVM,
//! noise, then an ADC that keeps only the `b_adc` MSBs of the `b_out`-bit
//! output (paper Table I, right half).

use crate::analog::energy::EnergyMeter;
use crate::analog::noise::NoiseModel;
use crate::rns::moduli::required_output_bits;
use crate::tensor::gemm::{gemm_i64, gemm_mod};
use crate::tensor::MatI;
use crate::util::rng::Rng;

/// One RNS residue channel: modulus `m`, converters at `ceil(log2 m)` bits.
#[derive(Clone, Debug)]
pub struct RnsMvmUnit {
    pub modulus: u64,
    pub enob: u32,
    pub noise: NoiseModel,
}

impl RnsMvmUnit {
    pub fn new(modulus: u64, noise: NoiseModel) -> Self {
        let enob = 64 - (modulus - 1).leading_zeros();
        RnsMvmUnit { modulus, enob, noise }
    }

    /// Execute one tile: `(x_res @ w_res) mod m` + noise.
    ///
    /// `x_res`: (B, K) residues, `w_res`: (K, N) residues, both already in
    /// `[0, m)`.  Energy: B*K input-DAC + K*N weight-DAC conversions and
    /// B*N ADC conversions, all at this channel's ENOB.
    pub fn execute(
        &self,
        x_res: &MatI,
        w_res: &MatI,
        rng: &mut Rng,
        meter: &mut EnergyMeter,
    ) -> MatI {
        meter.record_dac((x_res.rows * x_res.cols + w_res.rows * w_res.cols) as u64, self.enob);
        let mut out = gemm_mod(x_res, w_res, self.modulus);
        if self.noise != NoiseModel::None {
            for v in out.data.iter_mut() {
                *v = self.noise.apply_residue(*v as u64, self.modulus, rng) as i64;
            }
        }
        meter.record_adc((out.rows * out.cols) as u64, self.enob);
        out
    }

    /// Re-capture given pre-computed clean residues (used by the RRNS retry
    /// path: the analog MVM is recomputed, fresh noise is drawn).
    pub fn recapture(&self, clean: &MatI, rng: &mut Rng, meter: &mut EnergyMeter) -> MatI {
        let mut out = clean.clone();
        if self.noise != NoiseModel::None {
            for v in out.data.iter_mut() {
                *v = self.noise.apply_residue(*v as u64, self.modulus, rng) as i64;
            }
        }
        meter.record_adc((out.rows * out.cols) as u64, self.enob);
        out
    }
}

/// The regular fixed-point analog array with MSB-keeping ADCs.
#[derive(Clone, Debug)]
pub struct FixedPointMvmUnit {
    pub bits: u32,
    pub adc_bits: u32,
    /// Physical array height.  The ADC's full-scale range is sized for an
    /// h-long dot product (Eq. (4) with this h), so the number of dropped
    /// LSBs is a property of the *array*, not of the tile actually fed in —
    /// which is how a larger array hurts accuracy in Fig. 1 even when some
    /// layers have short dot products.
    pub h: usize,
    pub noise: NoiseModel,
}

impl FixedPointMvmUnit {
    /// `bits` = b_in = b_w = b_DAC; `adc_bits` = b_ADC.
    pub fn new(bits: u32, adc_bits: u32, h: usize, noise: NoiseModel) -> Self {
        assert!(h > 0);
        FixedPointMvmUnit { bits, adc_bits, h, noise }
    }

    /// Execute one tile: exact MVM, noise, then drop `b_out - b_adc` LSBs
    /// (sign-symmetric truncation — the ADC reads MSBs of |y|).
    pub fn execute(&self, x: &MatI, w: &MatI, rng: &mut Rng, meter: &mut EnergyMeter) -> MatI {
        assert!(x.cols <= self.h, "tile exceeds array height");
        meter.record_dac((x.rows * x.cols + w.rows * w.cols) as u64, self.bits);
        let mut y = gemm_i64(x, w);
        if self.noise != NoiseModel::None {
            for v in y.data.iter_mut() {
                *v = self.noise.apply_linear(*v, rng);
            }
        }
        let b_out = required_output_bits(self.bits, self.bits, self.h);
        let dropped = b_out.saturating_sub(self.adc_bits);
        if dropped >= 63 {
            // the truncation step 2^dropped exceeds i64: every
            // representable |y| < 2^63 truncates to 0, so a tiny ADC on a
            // huge array reads all zeros instead of overflowing the shift
            for v in y.data.iter_mut() {
                *v = 0;
            }
        } else if dropped > 0 {
            let scale = 1i64 << dropped;
            for v in y.data.iter_mut() {
                *v = v.signum() * (v.abs() / scale) * scale;
            }
        }
        meter.record_adc((y.rows * y.cols) as u64, self.adc_bits);
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mats(m: u64) -> (MatI, MatI) {
        let mut rng = Rng::seed_from(9);
        let x = MatI::from_vec(2, 16, (0..32).map(|_| rng.gen_range(m) as i64).collect());
        let w = MatI::from_vec(16, 3, (0..48).map(|_| rng.gen_range(m) as i64).collect());
        (x, w)
    }

    #[test]
    fn enob_from_modulus() {
        assert_eq!(RnsMvmUnit::new(59, NoiseModel::None).enob, 6);
        assert_eq!(RnsMvmUnit::new(63, NoiseModel::None).enob, 6);
        assert_eq!(RnsMvmUnit::new(64, NoiseModel::None).enob, 6); // values 0..63
        assert_eq!(RnsMvmUnit::new(255, NoiseModel::None).enob, 8);
    }

    #[test]
    fn clean_channel_is_exact() {
        let unit = RnsMvmUnit::new(63, NoiseModel::None);
        let (x, w) = mats(63);
        let mut rng = Rng::seed_from(0);
        let mut meter = EnergyMeter::default();
        let out = unit.execute(&x, &w, &mut rng, &mut meter);
        assert_eq!(out.data, gemm_mod(&x, &w, 63).data);
        assert_eq!(meter.dac_conversions, 32 + 48);
        assert_eq!(meter.adc_conversions, 6);
    }

    #[test]
    fn noisy_channel_stays_in_range() {
        let unit = RnsMvmUnit::new(59, NoiseModel::ResidueFlip { p: 0.5 });
        let (x, w) = mats(59);
        let mut rng = Rng::seed_from(1);
        let mut meter = EnergyMeter::default();
        let out = unit.execute(&x, &w, &mut rng, &mut meter);
        assert!(out.data.iter().all(|&v| (0..59).contains(&v)));
    }

    #[test]
    fn fixed_point_truncation() {
        // b=4, K=16 -> b_out = 4+4+4-1 = 11, adc=4 -> drop 7 bits
        let unit = FixedPointMvmUnit::new(4, 4, 16, NoiseModel::None);
        let x = MatI::from_vec(1, 16, vec![7; 16]);
        let w = MatI::from_vec(16, 1, vec![7; 16]);
        let mut rng = Rng::seed_from(2);
        let mut meter = EnergyMeter::default();
        let y = unit.execute(&x, &w, &mut rng, &mut meter);
        let exact = 16 * 49i64; // 784
        let scale = 1i64 << 7;
        assert_eq!(y.data[0], (exact / scale) * scale); // 768
        assert_eq!(meter.adc_conversions, 1);
    }

    #[test]
    fn fixed_point_no_drop_when_adc_wide_enough() {
        let unit = FixedPointMvmUnit::new(4, 11, 16, NoiseModel::None);
        let (x, w) = {
            let mut rng = Rng::seed_from(3);
            let x = MatI::from_vec(1, 16, (0..16).map(|_| rng.gen_range_i64(-7, 7)).collect());
            let w = MatI::from_vec(16, 1, (0..16).map(|_| rng.gen_range_i64(-7, 7)).collect());
            (x, w)
        };
        let mut rng = Rng::seed_from(4);
        let mut meter = EnergyMeter::default();
        let y = unit.execute(&x, &w, &mut rng, &mut meter);
        assert_eq!(y.data, gemm_i64(&x, &w).data);
    }

    #[test]
    fn extreme_truncation_zeroes_instead_of_overflowing() {
        // wide array + tiny ADC: b_out = 31+31+3-1 = 64, adc=1 -> dropped
        // = 63.  `1i64 << 63` would overflow (debug panic); the clamp must
        // zero the output instead — every |y| < 2^63 truncates to 0.
        let unit = FixedPointMvmUnit::new(31, 1, 8, NoiseModel::None);
        assert_eq!(required_output_bits(31, 31, 8).saturating_sub(1), 63);
        let x = MatI::from_vec(1, 8, vec![1000; 8]);
        let w = MatI::from_vec(8, 2, vec![-1000; 16]);
        let mut rng = Rng::seed_from(6);
        let mut meter = EnergyMeter::default();
        let y = unit.execute(&x, &w, &mut rng, &mut meter);
        assert!(y.data.iter().all(|&v| v == 0), "{:?}", y.data);
        assert_eq!(meter.adc_conversions, 2);
    }

    #[test]
    fn truncation_error_is_bounded() {
        let unit = FixedPointMvmUnit::new(6, 6, 128, NoiseModel::None);
        let mut rng = Rng::seed_from(5);
        let x = MatI::from_vec(2, 128, (0..256).map(|_| rng.gen_range_i64(-31, 31)).collect());
        let w = MatI::from_vec(128, 4, (0..512).map(|_| rng.gen_range_i64(-31, 31)).collect());
        let mut meter = EnergyMeter::default();
        let y = unit.execute(&x, &w, &mut rng, &mut meter);
        let exact = gemm_i64(&x, &w);
        let dropped = required_output_bits(6, 6, 128) - 6; // 12
        for (a, b) in y.data.iter().zip(&exact.data) {
            assert!((a - b).abs() < (1 << dropped));
        }
    }
}
