//! Analog MVM-unit SNR / energy model (paper §V, second-order claim).
//!
//! "The energy consumption of the analog MVM unit depends on the SNR for
//! the analog signals, and this SNR increases exponentially with the
//! desired compute precision.  Thus, RNS brings additional savings by
//! allowing the MVM units to work with lower SNR."
//!
//! Model: to resolve `b` bits at the unit output the analog signal chain
//! needs SNR >= 6.02 b + 1.76 dB (the quantization-noise-limited bound);
//! for a fixed noise floor the signal *power* — and hence the analog MVM
//! energy — scales linearly with the required SNR, i.e. exponentially
//! (4^b) with the bit precision.  We normalize to an energy constant per
//! MAC at 1-bit SNR so comparisons are technology-agnostic, which is all
//! the paper claims (no absolute numbers are given there either).

/// Quantization-limited SNR (dB) needed to resolve `bits` at the output.
pub fn required_snr_db(bits: u32) -> f64 {
    6.02 * bits as f64 + 1.76
}

/// Linear-scale SNR from dB.
pub fn snr_linear(snr_db: f64) -> f64 {
    10f64.powf(snr_db / 10.0)
}

/// Relative analog MVM energy per MAC for a unit that must resolve `bits`
/// output bits, normalized to a 1-bit unit (energy ∝ required signal
/// power ∝ linear SNR).
pub fn relative_mvm_energy(bits: u32) -> f64 {
    snr_linear(required_snr_db(bits)) / snr_linear(required_snr_db(1))
}

/// Analog-MVM energy comparison for an RNS core (n units at `bits`) vs a
/// fixed-point core (1 unit that must resolve `b_out` bits).  Returns
/// (rns_relative, fxp_relative, ratio fxp/rns).
pub fn mvm_energy_comparison(bits: u32, n_channels: usize, b_out: u32) -> (f64, f64, f64) {
    let rns = n_channels as f64 * relative_mvm_energy(bits);
    let fxp = relative_mvm_energy(b_out);
    (rns, fxp, fxp / rns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::required_output_bits;

    #[test]
    fn snr_reference_points() {
        // the classic 6 dB/bit rule
        assert!((required_snr_db(8) - 49.92).abs() < 0.01);
        assert!((required_snr_db(16) - 98.08).abs() < 0.01);
    }

    #[test]
    fn energy_quadruples_per_bit() {
        for b in 2..12 {
            let r = relative_mvm_energy(b + 1) / relative_mvm_energy(b);
            assert!((r - 4.0).abs() < 0.01, "b={b}: {r}");
        }
    }

    #[test]
    fn rns_needs_less_mvm_energy_than_fixed_point() {
        // paper §V: RNS lowers the required SNR in the analog units.
        for bits in 4..=8u32 {
            let b_out = required_output_bits(bits, bits, 128);
            let n = crate::rns::select_moduli(bits, 128).unwrap().len();
            let (rns, fxp, ratio) = mvm_energy_comparison(bits, n, b_out);
            assert!(rns < fxp, "bits={bits}");
            // the gap grows with precision (exponential vs linear-in-n)
            assert!(ratio > 100.0, "bits={bits} ratio={ratio}");
        }
    }

    #[test]
    fn ratio_monotone_in_bits() {
        let mut prev = 0.0;
        for bits in 4..=8u32 {
            let b_out = required_output_bits(bits, bits, 128);
            let n = crate::rns::select_moduli(bits, 128).unwrap().len();
            let (_, _, ratio) = mvm_energy_comparison(bits, n, b_out);
            assert!(ratio > prev, "bits={bits}");
            prev = ratio;
        }
    }
}
