//! Analog noise models.
//!
//! The paper abstracts analog error to "probability of error in a single
//! residue p" (§IV) for all RRNS analysis; `ResidueFlip` implements exactly
//! that.  `Gaussian` additionally models additive pre-ADC noise in LSB
//! units and is used to show how an SNR maps onto an effective p (the
//! connection §V draws between SNR and compute precision).

use crate::rns::inject::flip_residue;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NoiseModel {
    /// Ideal analog hardware.
    None,
    /// Each captured residue independently flips to a uniform wrong value
    /// with probability `p` (the paper's §IV error model).
    ResidueFlip { p: f64 },
    /// Additive zero-mean Gaussian with std `sigma_lsb` (in output-LSB
    /// units) applied to the pre-ADC analog value, then re-quantized.
    Gaussian { sigma_lsb: f64 },
}

impl NoiseModel {
    /// Corrupt one residue (value in `[0, m)`), returning the captured value.
    #[inline]
    pub fn apply_residue(&self, value: u64, m: u64, rng: &mut Rng) -> u64 {
        match *self {
            NoiseModel::None => value,
            NoiseModel::ResidueFlip { p } => {
                // same draw order + arithmetic as the rns::inject harness,
                // so noise-driven and injected faults are one fault model
                if rng.bernoulli(p) {
                    flip_residue(value, m, rng)
                } else {
                    value
                }
            }
            NoiseModel::Gaussian { sigma_lsb } => {
                let noisy = value as f64 + rng.normal() * sigma_lsb;
                // the analog modulo wraps the perturbed signal back into [0, m)
                let wrapped = noisy.rem_euclid(m as f64);
                (wrapped.round() as u64) % m
            }
        }
    }

    /// Corrupt one plain (non-RNS) pre-ADC value in LSB units.
    #[inline]
    pub fn apply_linear(&self, value: i64, rng: &mut Rng) -> i64 {
        match *self {
            NoiseModel::None => value,
            // ResidueFlip has no meaning for a non-residue channel; treat a
            // flip as a uniformly wrong LSB-scale perturbation of +-1 LSB.
            NoiseModel::ResidueFlip { p } => {
                if rng.bernoulli(p) {
                    value + if rng.bernoulli(0.5) { 1 } else { -1 }
                } else {
                    value
                }
            }
            NoiseModel::Gaussian { sigma_lsb } => {
                (value as f64 + rng.normal() * sigma_lsb).round() as i64
            }
        }
    }

    /// Effective single-residue error probability of a Gaussian channel:
    /// a captured residue is wrong when |noise| rounds away from 0, i.e.
    /// P(|N(0, sigma)| > 0.5) = erfc(0.5 / (sigma * sqrt(2))).
    pub fn effective_p(&self) -> f64 {
        match *self {
            NoiseModel::None => 0.0,
            NoiseModel::ResidueFlip { p } => p,
            NoiseModel::Gaussian { sigma_lsb } => erfc(0.5 / (sigma_lsb * std::f64::consts::SQRT_2)),
        }
    }
}

/// Complementary error function (Abramowitz & Stegun 7.1.26, |err| < 1.5e-7).
pub fn erfc(x: f64) -> f64 {
    let sign_negative = x < 0.0;
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592 + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let e = poly * (-x * x).exp();
    if sign_negative {
        2.0 - e
    } else {
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_identity() {
        let mut rng = Rng::seed_from(0);
        assert_eq!(NoiseModel::None.apply_residue(42, 63, &mut rng), 42);
        assert_eq!(NoiseModel::None.apply_linear(-5, &mut rng), -5);
        assert_eq!(NoiseModel::None.effective_p(), 0.0);
    }

    #[test]
    fn residue_flip_rate_and_range() {
        let nm = NoiseModel::ResidueFlip { p: 0.2 };
        let mut rng = Rng::seed_from(1);
        let mut flips = 0;
        for _ in 0..20_000 {
            let out = nm.apply_residue(10, 59, &mut rng);
            assert!(out < 59);
            if out != 10 {
                flips += 1;
            }
        }
        let rate = flips as f64 / 20_000.0;
        assert!((rate - 0.2).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn flip_never_returns_same_value() {
        let nm = NoiseModel::ResidueFlip { p: 1.0 };
        let mut rng = Rng::seed_from(2);
        for v in 0..59u64 {
            assert_ne!(nm.apply_residue(v, 59, &mut rng), v);
        }
    }

    #[test]
    fn gaussian_wraps_into_range() {
        let nm = NoiseModel::Gaussian { sigma_lsb: 30.0 };
        let mut rng = Rng::seed_from(3);
        for _ in 0..5000 {
            assert!(nm.apply_residue(5, 11, &mut rng) < 11);
        }
    }

    #[test]
    fn gaussian_effective_p_matches_simulation() {
        let nm = NoiseModel::Gaussian { sigma_lsb: 0.4 };
        let mut rng = Rng::seed_from(4);
        let m = 1_000_003; // large modulus: wraparound negligible
        let mut wrong = 0;
        let trials = 100_000;
        for _ in 0..trials {
            if nm.apply_residue(500_000, m, &mut rng) != 500_000 {
                wrong += 1;
            }
        }
        let sim = wrong as f64 / trials as f64;
        let analytic = nm.effective_p();
        assert!((sim - analytic).abs() < 0.01, "sim {sim} vs analytic {analytic}");
    }

    #[test]
    fn erfc_reference_values() {
        assert!((erfc(0.0) - 1.0).abs() < 1e-7);
        assert!((erfc(1.0) - 0.157299).abs() < 1e-5);
        assert!((erfc(-1.0) - 1.842701).abs() < 1e-5);
        assert!(erfc(5.0) < 1e-10);
    }
}
