//! Data-converter energy models (paper §V, Eqs. (6)-(7), after Murmann).
//!
//!   E_DAC = ENOB^2 * C_u * V_DD^2          (C_u = 0.5 fF, V_DD = 1 V)
//!   E_ADC = k1 * ENOB + k2 * 4^ENOB        (k1 ≈ 100 fJ, k2 ≈ 1 aJ)
//!
//! The exponential ADC term dominates above ~10 bits — the entire reason
//! the paper's low-ENOB RNS design wins by orders of magnitude.

/// Unit capacitance (F).
pub const C_U: f64 = 0.5e-15;
/// Supply voltage (V).
pub const V_DD: f64 = 1.0;
/// ADC linear coefficient (J/bit).
pub const K1: f64 = 100e-15;
/// ADC exponential coefficient (J).
pub const K2: f64 = 1e-18;
/// Digital CRT + forward-conversion cost per output element (J) — the
/// paper's ASAP7 synthesis bound ("≤ 0.1 pJ per conversion, negligible").
pub const E_CRT_DIGITAL: f64 = 0.1e-12;

/// Eq. (6): DAC energy per conversion (J).
pub fn dac_energy(enob: u32) -> f64 {
    (enob as f64).powi(2) * C_U * V_DD * V_DD
}

/// Eq. (7): ADC energy per conversion (J).
pub fn adc_energy(enob: u32) -> f64 {
    K1 * enob as f64 + K2 * 4f64.powi(enob as i32)
}

/// Running energy/conversion counters for one simulated core.
///
/// `skipped_dac` / `skipped_adc` count conversions that sparse capture
/// proved unnecessary (zero activations / structurally-zero output rows)
/// and therefore never performed nor charged — the converter-activation
/// savings RedPIM-style execution buys on top of low ENOB.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyMeter {
    pub dac_conversions: u64,
    pub adc_conversions: u64,
    pub skipped_dac: u64,
    pub skipped_adc: u64,
    pub dac_joules: f64,
    pub adc_joules: f64,
    pub digital_joules: f64,
}

impl EnergyMeter {
    pub fn record_dac(&mut self, count: u64, enob: u32) {
        self.dac_conversions += count;
        self.dac_joules += count as f64 * dac_energy(enob);
    }

    pub fn record_adc(&mut self, count: u64, enob: u32) {
        self.adc_conversions += count;
        self.adc_joules += count as f64 * adc_energy(enob);
    }

    pub fn record_crt(&mut self, count: u64) {
        self.digital_joules += count as f64 * E_CRT_DIGITAL;
    }

    /// Count DAC conversions avoided by sparse capture (no energy charged).
    pub fn record_skipped_dac(&mut self, count: u64) {
        self.skipped_dac += count;
    }

    /// Count ADC conversions avoided by sparse capture (no energy charged).
    pub fn record_skipped_adc(&mut self, count: u64) {
        self.skipped_adc += count;
    }

    pub fn total_joules(&self) -> f64 {
        self.dac_joules + self.adc_joules + self.digital_joules
    }

    pub fn merge(&mut self, other: &EnergyMeter) {
        self.dac_conversions += other.dac_conversions;
        self.adc_conversions += other.adc_conversions;
        self.skipped_dac += other.skipped_dac;
        self.skipped_adc += other.skipped_adc;
        self.dac_joules += other.dac_joules;
        self.adc_joules += other.adc_joules;
        self.digital_joules += other.digital_joules;
    }

    pub fn reset(&mut self) {
        *self = EnergyMeter::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq6_dac_values() {
        // 8-bit DAC: 64 * 0.5fF * 1V^2 = 32 fJ
        assert!((dac_energy(8) - 32e-15).abs() < 1e-20);
        assert_eq!(dac_energy(0), 0.0);
    }

    #[test]
    fn eq7_adc_values() {
        // 6-bit: 600 fJ + 4^6 aJ = 600fJ + 4.096fJ
        let e6 = adc_energy(6);
        assert!((e6 - (600e-15 + 4096e-18)).abs() < 1e-20);
        // exponential term dominates by 14 bits: 4^14 aJ = 268 nJ >> k1*14
        assert!(adc_energy(14) > 100.0 * adc_energy(8));
    }

    #[test]
    fn adc_exponential_growth_factor() {
        // paper: "roughly 4x increase for each additional output bit" at
        // high ENOB where the exponential dominates
        let r = adc_energy(16) / adc_energy(15);
        assert!((r - 4.0).abs() < 0.1, "ratio {r}");
    }

    #[test]
    fn meter_accumulates_and_merges() {
        let mut m = EnergyMeter::default();
        m.record_dac(10, 8);
        m.record_adc(5, 6);
        m.record_crt(5);
        assert_eq!(m.dac_conversions, 10);
        assert!((m.dac_joules - 10.0 * dac_energy(8)).abs() < 1e-25);
        let mut m2 = EnergyMeter::default();
        m2.record_adc(5, 6);
        m2.merge(&m);
        assert_eq!(m2.adc_conversions, 10);
        assert!(m2.total_joules() > 0.0);
        m2.reset();
        assert_eq!(m2.total_joules(), 0.0);
    }

    #[test]
    fn skipped_conversions_count_but_cost_nothing() {
        let mut m = EnergyMeter::default();
        m.record_skipped_dac(7);
        m.record_skipped_adc(3);
        assert_eq!((m.skipped_dac, m.skipped_adc), (7, 3));
        assert_eq!((m.dac_conversions, m.adc_conversions), (0, 0));
        assert_eq!(m.total_joules(), 0.0);
        let mut m2 = EnergyMeter::default();
        m2.record_skipped_adc(1);
        m2.merge(&m);
        assert_eq!((m2.skipped_dac, m2.skipped_adc), (7, 4));
        m2.reset();
        assert_eq!((m2.skipped_dac, m2.skipped_adc), (0, 0));
    }

    #[test]
    fn rns_vs_fixed_point_headline_ratio() {
        // Fig. 7 structure: b=8 RNS (3 ADC conversions @ 8 bits) vs fixed
        // point (1 ADC @ b_out = 22 bits): ratio must be >= 5 orders.
        let rns = 3.0 * adc_energy(8);
        let fixed = adc_energy(22);
        assert!(fixed / rns > 1e5, "ratio {}", fixed / rns);
    }
}
