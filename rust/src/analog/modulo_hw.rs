//! Analog-domain modulo implementations (paper §V, last paragraph).
//!
//! The paper sketches two physical realizations of the in-analog modulo
//! that keeps residue outputs inside `[0, m)`:
//!
//!   * **Ring oscillator** (electrical): an odd chain of `m` inverters;
//!     the position of the travelling edge after a time proportional to
//!     `x` is `x mod m`.  Discrete position readout -> exact modulo, with
//!     edge-jitter noise modeled as a small Gaussian on the dwell time.
//!   * **Optical phase** (photonic): accumulating phase wraps at 2π, so
//!     scaling values by `2π/m` makes phase accumulation a modular adder.
//!     Continuous phase -> modulo with Gaussian phase noise, then readout
//!     rounds to the nearest code.
//!
//! Both are *models for the simulator* — they produce `x mod m` plus a
//! technology-flavored error process, and expose an energy estimate so the
//! ablation experiments can compare the paper's "modulo is essentially
//! free" claim across realizations.

use crate::util::rng::Rng;

/// Energy of one inverter transition at 7nm-class nodes (J) — order of
/// magnitude consistent with the paper's "a set of inverters is trivial
/// circuitry" remark.
const E_INVERTER: f64 = 1e-17;

/// A physical modulo stage.
pub trait AnalogModulo {
    /// Compute `x mod m` under the stage's noise process.
    fn modulo(&self, x: u64, rng: &mut Rng) -> u64;
    /// Energy per modulo operation (J).
    fn energy_per_op(&self) -> f64;
    fn name(&self) -> &'static str;
}

/// Ring-oscillator modulo: exact winding position + edge jitter.
#[derive(Clone, Debug)]
pub struct RingOscillatorModulo {
    pub m: u64,
    /// Std of the edge-position jitter, in inverter stages (0 = ideal).
    pub jitter_stages: f64,
    /// Oscillation cycles needed to integrate the input (energy model).
    cycles_per_op: f64,
}

impl RingOscillatorModulo {
    pub fn new(m: u64, jitter_stages: f64) -> Self {
        // rough order-of-magnitude model: the edge winds a fraction of the
        // dot-product integration window through the m-stage ring
        RingOscillatorModulo { m, jitter_stages, cycles_per_op: m as f64 / 8.0 }
    }
}

impl AnalogModulo for RingOscillatorModulo {
    fn modulo(&self, x: u64, rng: &mut Rng) -> u64 {
        let ideal = x % self.m;
        if self.jitter_stages == 0.0 {
            return ideal;
        }
        let noisy = ideal as f64 + rng.normal() * self.jitter_stages;
        noisy.rem_euclid(self.m as f64).round() as u64 % self.m
    }

    fn energy_per_op(&self) -> f64 {
        // m inverters transitioning for cycles_per_op laps
        self.m as f64 * self.cycles_per_op * E_INVERTER
    }

    fn name(&self) -> &'static str {
        "ring-oscillator"
    }
}

/// Optical-phase modulo: values scaled by 2π/m, phase wraps at 2π.
#[derive(Clone, Debug)]
pub struct OpticalPhaseModulo {
    pub m: u64,
    /// Phase-noise std in radians (0 = ideal).
    pub phase_noise_rad: f64,
}

impl OpticalPhaseModulo {
    pub fn new(m: u64, phase_noise_rad: f64) -> Self {
        OpticalPhaseModulo { m, phase_noise_rad }
    }
}

impl AnalogModulo for OpticalPhaseModulo {
    fn modulo(&self, x: u64, rng: &mut Rng) -> u64 {
        let two_pi = std::f64::consts::TAU;
        let scale = two_pi / self.m as f64;
        let phase = (x as f64 * scale) % two_pi;
        let noisy = phase + rng.normal() * self.phase_noise_rad;
        let wrapped = noisy.rem_euclid(two_pi);
        ((wrapped / scale).round() as u64) % self.m
    }

    fn energy_per_op(&self) -> f64 {
        // phase accumulates in passive shifters: no added energy beyond the
        // existing optical path (the paper: "without any additional cost")
        0.0
    }

    fn name(&self) -> &'static str {
        "optical-phase"
    }
}

/// The effective per-residue error probability a modulo stage introduces
/// (measured empirically over `trials` random inputs).
pub fn measure_error_rate(stage: &dyn AnalogModulo, m: u64, trials: u32, seed: u64) -> f64 {
    let mut rng = Rng::seed_from(seed);
    let mut wrong = 0u32;
    for _ in 0..trials {
        let x = rng.gen_range(m * m); // dot-product-scale inputs
        if stage.modulo(x, &mut rng) != x % m {
            wrong += 1;
        }
    }
    wrong as f64 / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_stages_are_exact() {
        let mut rng = Rng::seed_from(0);
        for &m in &[59u64, 63, 127, 255] {
            let ro = RingOscillatorModulo::new(m, 0.0);
            let op = OpticalPhaseModulo::new(m, 0.0);
            for _ in 0..500 {
                let x = rng.gen_range(m * m * 4);
                assert_eq!(ro.modulo(x, &mut rng), x % m, "ring m={m}");
                assert_eq!(op.modulo(x, &mut rng), x % m, "optical m={m}");
            }
        }
    }

    #[test]
    fn outputs_always_in_range() {
        let mut rng = Rng::seed_from(1);
        let ro = RingOscillatorModulo::new(63, 5.0);
        let op = OpticalPhaseModulo::new(63, 0.5);
        for _ in 0..2000 {
            let x = rng.gen_range(1 << 20);
            assert!(ro.modulo(x, &mut rng) < 63);
            assert!(op.modulo(x, &mut rng) < 63);
        }
    }

    #[test]
    fn noise_increases_error_rate_monotonically() {
        let quiet = RingOscillatorModulo::new(63, 0.1);
        let loud = RingOscillatorModulo::new(63, 2.0);
        let e_quiet = measure_error_rate(&quiet, 63, 20_000, 2);
        let e_loud = measure_error_rate(&loud, 63, 20_000, 2);
        assert!(e_quiet < e_loud, "{e_quiet} vs {e_loud}");
        assert!(e_quiet < 0.05);
        assert!(e_loud > 0.3);
    }

    #[test]
    fn optical_phase_noise_maps_to_code_errors() {
        // phase step is 2π/63 ≈ 0.0997 rad; noise σ of half a step flips
        // a meaningful fraction of readouts
        let stage = OpticalPhaseModulo::new(63, 0.05);
        let rate = measure_error_rate(&stage, 63, 20_000, 3);
        assert!(rate > 0.1 && rate < 0.8, "rate {rate}");
    }

    #[test]
    fn energy_model_orders() {
        let ro = RingOscillatorModulo::new(255, 0.0);
        // must stay far below one ADC conversion (the paper's point that
        // analog modulo adds negligible cost)
        assert!(ro.energy_per_op() < crate::analog::energy::adc_energy(8) / 2.0);
        assert_eq!(OpticalPhaseModulo::new(255, 0.0).energy_per_op(), 0.0);
    }
}
