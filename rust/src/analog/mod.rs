//! Analog accelerator simulator: data-converter energy models (§V), noise
//! models (§IV), per-tile MVM units, and the two competing cores — the
//! regular fixed-point core and the paper's RNS-based core (Fig. 2).

pub mod energy;
pub mod fixed_point_core;
pub mod modulo_hw;
pub mod mvm_unit;
pub mod noise;
pub mod rns_core;
pub mod snr;

pub use energy::EnergyMeter;
pub use fixed_point_core::FixedPointCore;
pub use noise::NoiseModel;
pub use rns_core::{FaultStats, InjectionSite, RnsCore, RnsCoreConfig};

use crate::tensor::gemm::gemm_f32;
use crate::tensor::MatF;

/// Cumulative wall-clock microseconds a backend has spent in each
/// pipeline stage of the analog dataflow (DAC forward conversion →
/// analog modular GEMM → ADC capture → decode).  The serving tier reads
/// this per batch, takes deltas, and feeds the per-stage latency
/// histograms — the same delta discipline `EnergyMeter`/`FaultStats`
/// already follow, so a crashed partial forward never lands.
///
/// Decode time includes tier-2 voting retries (their ADC recompute
/// draws happen inside the decode loop, and splitting them out would
/// cost one `Instant::now()` per retried element on the hot path).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageMicros {
    /// Activation (and unprepared-path weight) forward conversion.
    pub dac_forward_us: u64,
    /// Modular MVM across all residue channels (the engine call).
    pub analog_gemm_us: u64,
    /// ADC recapture of the channel outputs (noise application).
    pub adc_capture_us: u64,
    /// CRT / RRNS two-tier decode, incl. voting retries.
    pub decode_us: u64,
}

impl StageMicros {
    /// This cumulative snapshot minus `prev`, per field (saturating) —
    /// the serving tier's per-batch delta, feeding both the stage
    /// histograms and the per-request span traces from one value.
    pub fn delta_since(&self, prev: &StageMicros) -> StageMicros {
        StageMicros {
            dac_forward_us: self.dac_forward_us.saturating_sub(prev.dac_forward_us),
            analog_gemm_us: self.analog_gemm_us.saturating_sub(prev.analog_gemm_us),
            adc_capture_us: self.adc_capture_us.saturating_sub(prev.adc_capture_us),
            decode_us: self.decode_us.saturating_sub(prev.decode_us),
        }
    }

    /// Sum of all four stage timers (each stage is timed disjointly, so
    /// the total can never exceed the forward's wall clock).
    pub fn total_us(&self) -> u64 {
        self.dac_forward_us + self.analog_gemm_us + self.adc_capture_us + self.decode_us
    }
}

/// A GEMM execution backend: the FP32 reference, the fixed-point analog
/// core, or the RNS analog core.  The nn layer routes every GEMM in a
/// model through one of these, which is how the accuracy experiments swap
/// hardware under an unchanged model.
pub trait GemmBackend {
    fn gemm(&mut self, x: &MatF, w: &MatF) -> MatF;
    /// Pre-build any per-layer state for a weight matrix (e.g. the RNS
    /// core's `RnsPlan`: quantization + per-channel residues + u32
    /// staging).  `Model::warm` calls this for every weight GEMM a model
    /// will issue so the first request pays no plan-build latency.
    /// Default: nothing — stateless backends have no per-layer state.
    fn prepare(&mut self, _w: &MatF) {}
    /// Number of per-layer plans this backend has adopted — built or
    /// first borrowed from the shared plan store (serving metric; the
    /// store's own `builds` counter is the deduplicated build count).
    fn plans_built(&self) -> u64 {
        0
    }
    /// Tag subsequent plan lookups with the model they belong to, for
    /// per-model plan-store attribution and eviction by model unload.
    /// Default: ignored — stateless backends have no plan store.
    fn set_model_tag(&mut self, _tag: &str) {}
    /// Proactively drop per-model backend state (stale plan adoptions,
    /// the model tag) when the coordinator's control plane unloads
    /// `model` — the release-side counterpart of `set_model_tag`.
    /// Default: nothing — stateless backends hold no per-model state.
    fn release_model(&mut self, _model: &str) {}
    fn name(&self) -> String;
    /// Energy meter, if this backend models hardware.
    fn meter(&self) -> Option<EnergyMeter> {
        None
    }
    /// RRNS fault counters, if this backend runs the fault-tolerant core.
    fn fault_stats(&self) -> Option<rns_core::FaultStats> {
        None
    }
    /// Cumulative per-stage wall-clock timers, if this backend times its
    /// pipeline stages (the RNS core does; stateless backends don't).
    fn stage_micros(&self) -> Option<StageMicros> {
        None
    }
}

/// The FP32 ground-truth backend (the paper's normalization baseline).
#[derive(Default, Clone, Copy)]
pub struct Fp32Backend;

impl GemmBackend for Fp32Backend {
    fn gemm(&mut self, x: &MatF, w: &MatF) -> MatF {
        gemm_f32(x, w)
    }
    fn name(&self) -> String {
        "fp32".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_micros_delta_and_total() {
        let prev =
            StageMicros { dac_forward_us: 10, analog_gemm_us: 20, adc_capture_us: 5, decode_us: 1 };
        let now =
            StageMicros { dac_forward_us: 15, analog_gemm_us: 26, adc_capture_us: 5, decode_us: 3 };
        let d = now.delta_since(&prev);
        assert_eq!(
            d,
            StageMicros { dac_forward_us: 5, analog_gemm_us: 6, adc_capture_us: 0, decode_us: 2 }
        );
        assert_eq!(d.total_us(), 13);
        assert_eq!(StageMicros::default().delta_since(&now).total_us(), 0, "deltas saturate");
    }

    #[test]
    fn fp32_backend_is_exact_gemm() {
        let x = MatF::from_vec(1, 2, vec![1.0, 2.0]);
        let w = MatF::from_vec(2, 1, vec![3.0, 4.0]);
        let mut b = Fp32Backend;
        assert_eq!(b.gemm(&x, &w).data, vec![11.0]);
        assert_eq!(b.name(), "fp32");
        assert!(b.meter().is_none());
    }
}
