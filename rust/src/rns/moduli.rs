//! Moduli selection (paper Table I).
//!
//! The paper picks, for a data-converter precision `b` and dot-product
//! length `h`, the *minimum number* of pairwise-coprime moduli below `2^b`
//! whose product `M` covers `b_out = 2b + log2(h) - 1` bits (Eq. (4)),
//! choosing the maximum-product set for that count.  This reproduces the
//! exact Table-I sets, e.g. b=5 → {31, 29, 28, 27} (note: *not* the greedy
//! {31, 30, 29, 23} — 30 excludes too many later candidates).

pub fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

pub fn pairwise_coprime(moduli: &[u64]) -> bool {
    for i in 0..moduli.len() {
        for j in (i + 1)..moduli.len() {
            if gcd(moduli[i], moduli[j]) != 1 {
                return false;
            }
        }
    }
    true
}

/// Eq. (4): bits needed to represent an h-element dot product of
/// `b_in`-bit × `b_w`-bit signed operands without loss.
pub fn required_output_bits(b_in: u32, b_w: u32, h: usize) -> u32 {
    assert!(h > 0);
    b_in + b_w + (h as f64).log2().ceil() as u32 - 1
}

/// Max-product pairwise-coprime subset of size `n` from descending `cands`
/// (branch and bound — candidates are sorted descending so the
/// `prod * c^remaining` bound prunes aggressively).
fn best_coprime_subset(cands: &[u64], n: usize) -> (u128, Vec<u64>) {
    let mut best_prod: u128 = 0;
    let mut best: Vec<u64> = Vec::new();

    fn dfs(
        cands: &[u64],
        n: usize,
        start: usize,
        chosen: &mut Vec<u64>,
        prod: u128,
        best_prod: &mut u128,
        best: &mut Vec<u64>,
    ) {
        if chosen.len() == n {
            if prod > *best_prod {
                *best_prod = prod;
                *best = chosen.clone();
            }
            return;
        }
        let remaining = n - chosen.len();
        for i in start..=cands.len().saturating_sub(remaining) {
            let c = cands[i];
            let bound = prod.saturating_mul((c as u128).pow(remaining as u32));
            if bound <= *best_prod {
                return; // descending order: nothing later can win
            }
            if chosen.iter().all(|&x| gcd(c, x) == 1) {
                chosen.push(c);
                dfs(cands, n, i + 1, chosen, prod * c as u128, best_prod, best);
                chosen.pop();
            }
        }
    }

    let mut chosen = Vec::new();
    dfs(cands, n, 0, &mut chosen, 1, &mut best_prod, &mut best);
    (best_prod, best)
}

/// Table-I selection: minimal-n, max-product moduli under `2^bits` covering
/// `b_out` for an `h`-long dot product with `b_in = b_w = bits`.
pub fn select_moduli(bits: u32, h: usize) -> Result<Vec<u64>, String> {
    assert!((2..=16).contains(&bits), "bits {bits} out of supported range");
    let b_out = required_output_bits(bits, bits, h);
    let target: u128 = 1u128 << b_out;
    let cands: Vec<u64> = (2..(1u64 << bits)).rev().collect();
    for n in 1..=16 {
        let (prod, subset) = best_coprime_subset(&cands, n);
        if prod >= target {
            return Ok(subset);
        }
    }
    Err(format!("cannot cover {b_out} bits with {bits}-bit moduli"))
}

/// Append `extra` redundant moduli: the next largest values coprime to the
/// whole set (RRNS(n, k) with n = k + extra).  Redundant moduli are smaller
/// than the information moduli, which shrinks the *legitimate range* to the
/// min product over k-subsets — `RrnsCode::legitimate_range` accounts for
/// this (see rrns.rs).
pub fn extend_moduli(moduli: &[u64], extra: usize) -> Result<Vec<u64>, String> {
    let mut out = moduli.to_vec();
    let mut cand = *moduli.iter().min().ok_or("empty moduli set")? - 1;
    for _ in 0..extra {
        while cand >= 2 && !out.iter().all(|&x| gcd(cand, x) == 1) {
            cand -= 1;
        }
        if cand < 2 {
            return Err("ran out of coprime candidates for redundancy".into());
        }
        out.push(cand);
        cand -= 1;
    }
    Ok(out)
}

/// The paper's exact Table-I sets (golden values for tests and defaults).
pub fn paper_table1(bits: u32) -> Option<&'static [u64]> {
    match bits {
        4 => Some(&[15, 14, 13, 11]),
        5 => Some(&[31, 29, 28, 27]),
        6 => Some(&[63, 62, 61, 59]),
        7 => Some(&[127, 126, 125]),
        8 => Some(&[255, 254, 253]),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcd_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(17, 13), 1);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(gcd(5, 0), 5);
    }

    #[test]
    fn eq4_bout() {
        assert_eq!(required_output_bits(4, 4, 128), 14);
        assert_eq!(required_output_bits(5, 5, 128), 16);
        assert_eq!(required_output_bits(6, 6, 128), 18);
        assert_eq!(required_output_bits(7, 7, 128), 20);
        assert_eq!(required_output_bits(8, 8, 128), 22);
    }

    #[test]
    fn reproduces_paper_table1() {
        for bits in 4..=8 {
            let got = select_moduli(bits, 128).unwrap();
            assert_eq!(got.as_slice(), paper_table1(bits).unwrap(), "bits={bits}");
        }
    }

    #[test]
    fn selection_invariants_other_h() {
        for (bits, h) in [(4u32, 16usize), (5, 64), (6, 256), (8, 64), (8, 512)] {
            let mods = select_moduli(bits, h).unwrap();
            assert!(pairwise_coprime(&mods));
            assert!(mods.iter().all(|&m| m < (1 << bits)));
            let prod: u128 = mods.iter().map(|&m| m as u128).product();
            assert!(prod >= (1u128 << required_output_bits(bits, bits, h)));
        }
    }

    #[test]
    fn extend_keeps_coprimality() {
        let base = paper_table1(8).unwrap();
        let ext = extend_moduli(base, 3).unwrap();
        assert_eq!(&ext[..3], base);
        assert_eq!(ext.len(), 6);
        assert!(pairwise_coprime(&ext));
        // redundant moduli stay below the chosen bit width
        assert!(ext.iter().all(|&m| m < 256));
    }

    #[test]
    fn extend_b6_known_values() {
        // {63,62,61,59} -> next coprime candidates: 58? gcd(58,62)=2; 57?
        // gcd(57,63)=3; 56? gcd(56,63)=7... 55 coprime to all; then 53.
        let ext = extend_moduli(paper_table1(6).unwrap(), 2).unwrap();
        assert_eq!(ext, vec![63, 62, 61, 59, 55, 53]);
    }
}
