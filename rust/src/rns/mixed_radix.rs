//! Mixed-radix conversion (MRC) and base extension.
//!
//! The paper's footnote 5 notes that CRT-per-group voting "can be too
//! expensive for large numbers of moduli — typically error detection and
//! correction is implemented via more efficient base-extension-based
//! algorithms" (citing Babenko et al.).  This module provides both pieces:
//!
//!   * `to_mixed_radix` / `from_mixed_radix` — the MRC digits of a residue
//!     vector.  MRC is a positional system, so magnitude comparison and
//!     range checks need no big-integer CRT.
//!   * `base_extend` — extend a residue vector from base `{m_1..m_k}` to an
//!     extra modulus `m_e` without reconstructing the integer (Szabo-Tanaka
//!     via the MRC digits).
//!   * `BexDecoder` — a base-extension RRNS decoder: recompute each
//!     redundant residue from the k information residues via base
//!     extension and compare; the syndrome pattern localizes single
//!     errors in the information part.  Used as the fast path in the
//!     ablation benches (`exp/ablation.rs`) against the CRT-voting decoder.

use super::crt::{mod_inverse, RnsContext};

/// Precomputed Szabo-Tanaka inverse table for one moduli base — the hot
/// part of mixed-radix conversion (`m_i^{-1} mod m_j` for i < j).
#[derive(Clone, Debug)]
pub struct MrcTable {
    pub moduli: Vec<u64>,
    /// inv[i][j - i - 1] = m_i^{-1} mod m_j
    inv: Vec<Vec<u64>>,
}

impl MrcTable {
    pub fn new(moduli: &[u64]) -> Result<Self, String> {
        let n = moduli.len();
        let mut inv = Vec::with_capacity(n);
        for i in 0..n {
            let mut row = Vec::with_capacity(n - i - 1);
            for j in (i + 1)..n {
                row.push(mod_inverse(moduli[i] as u128 % moduli[j] as u128, moduli[j] as u128)? as u64);
            }
            inv.push(row);
        }
        Ok(MrcTable { moduli: moduli.to_vec(), inv })
    }

    /// Mixed-radix digits of a residue vector (0 <= d[i] < m_i).
    pub fn digits(&self, residues: &[u64]) -> Vec<u64> {
        let n = self.moduli.len();
        debug_assert_eq!(residues.len(), n);
        let mut work: Vec<u64> = residues.to_vec();
        let mut digits = Vec::with_capacity(n);
        for i in 0..n {
            let d = work[i];
            digits.push(d);
            for j in (i + 1)..n {
                let mj = self.moduli[j];
                // work[j] = (work[j] - d) * m_i^{-1} mod m_j
                let diff = (work[j] + mj - (d % mj)) % mj;
                work[j] = (diff * self.inv[i][j - i - 1]) % mj;
            }
        }
        digits
    }

    /// Base-extend mixed-radix digits to modulus `m_e`.
    pub fn extend_digits(&self, digits: &[u64], m_e: u64) -> u64 {
        let mut acc: u64 = 0;
        let mut weight: u64 = 1 % m_e;
        for (d, &m) in digits.iter().zip(&self.moduli) {
            acc = (acc + (d % m_e) * weight) % m_e;
            weight = (weight * (m % m_e)) % m_e;
        }
        acc
    }
}

/// Mixed-radix digits `d` of the value represented by `residues` w.r.t.
/// `moduli`: value = d[0] + d[1]*m0 + d[2]*m0*m1 + ...  (0 <= d[i] < m_i).
/// (One-shot convenience; hot paths should hold an `MrcTable`.)
pub fn to_mixed_radix(residues: &[u64], moduli: &[u64]) -> Vec<u64> {
    MrcTable::new(moduli).expect("coprime moduli").digits(residues)
}

/// Reconstruct the (unsigned) value from mixed-radix digits.
pub fn from_mixed_radix(digits: &[u64], moduli: &[u64]) -> u128 {
    let mut acc: u128 = 0;
    let mut weight: u128 = 1;
    for (d, &m) in digits.iter().zip(moduli) {
        acc += *d as u128 * weight;
        weight *= m as u128;
    }
    acc
}

/// Base extension: compute `value mod m_e` for the value represented by
/// `residues` over `moduli`, without leaving residue arithmetic.
pub fn base_extend(residues: &[u64], moduli: &[u64], m_e: u64) -> u64 {
    let digits = to_mixed_radix(residues, moduli);
    let mut acc: u64 = 0;
    let mut weight: u64 = 1 % m_e;
    for (d, &m) in digits.iter().zip(moduli) {
        acc = (acc + (d % m_e) as u128 as u64 * weight % m_e) % m_e;
        weight = ((weight as u128 * (m as u128 % m_e as u128)) % m_e as u128) as u64;
    }
    acc
}

/// Outcome of a base-extension syndrome decode.
#[derive(Clone, Debug, PartialEq)]
pub enum BexOutcome {
    /// All syndromes zero: the information residues are consistent.
    Clean { value: i128 },
    /// Syndromes nonzero but a single-residue correction explains them.
    Corrected { value: i128, suspect: usize },
    /// Syndromes inconsistent with any single error: detected.
    Detected,
}

/// Base-extension RRNS decoder for n = k + r moduli (information first).
///
/// Cost: r base extensions (each O(k^2) small-word ops) instead of
/// C(n, k) CRTs — the asymptotic win the paper's footnote points at.
/// Correction power: locates any single erroneous *information* residue
/// when r >= 2, and flags redundant-residue errors for free.
pub struct BexDecoder {
    pub moduli: Vec<u64>,
    pub k: usize,
    info_ctx: RnsContext,
    /// Precomputed Szabo-Tanaka inverses over the information base.
    table: MrcTable,
    /// Precomputed `M_info mod m_e` per redundant modulus (signed fix-up).
    m_info_mod: Vec<u64>,
    /// Full-range signed bound (product of information moduli).
    half: i128,
}

impl BexDecoder {
    pub fn new(moduli: &[u64], k: usize) -> Result<Self, String> {
        if k == 0 || k > moduli.len() {
            return Err(format!("invalid k={k} for n={}", moduli.len()));
        }
        let info_ctx = RnsContext::new(&moduli[..k])?;
        let table = MrcTable::new(&moduli[..k])?;
        let m_info_mod =
            moduli[k..].iter().map(|&m_e| (info_ctx.big_m % m_e as u128) as u64).collect();
        let half = (info_ctx.big_m / 2) as i128;
        Ok(BexDecoder { moduli: moduli.to_vec(), k, info_ctx, table, m_info_mod, half })
    }

    /// Decode: recompute each redundant residue from the info base and
    /// compare (syndromes); try single-error hypotheses when they differ.
    ///
    /// Sign handling: the full codeword encodes the *signed* value (a
    /// negative A wraps through the full product), so the extension of the
    /// unsigned info reconstruction `U = A mod M_info` must be corrected by
    /// `-M_info mod m_e` when U lands in the negative half-range — the
    /// standard signed base-extension fix-up, done per redundant modulus.
    pub fn decode(&self, residues: &[u64]) -> BexOutcome {
        assert_eq!(residues.len(), self.moduli.len());
        let info = &residues[..self.k];
        let info_moduli = &self.moduli[..self.k];
        // one mixed-radix conversion (precomputed inverses), then every
        // redundant extension is O(k) small-word ops
        let digits = self.table.digits(info);
        let u = from_mixed_radix(&digits, info_moduli);
        let negative = u > self.info_ctx.big_m / 2;
        let mut syndromes = Vec::with_capacity(self.moduli.len() - self.k);
        for (idx, &m_e) in self.moduli[self.k..].iter().enumerate() {
            let mut expect = self.table.extend_digits(&digits, m_e);
            if negative {
                expect = (expect + m_e - self.m_info_mod[idx]) % m_e;
            }
            syndromes.push((expect != residues[self.k + idx], idx));
        }
        let bad = syndromes.iter().filter(|(b, _)| *b).count();
        if bad == 0 {
            return BexOutcome::Clean { value: self.info_ctx.crt_signed(info) };
        }
        if bad < syndromes.len() {
            // Some redundant residues agree with the info base: with a
            // single-error assumption the error is in a *redundant* residue
            // (the info value is vouched for by the agreeing extensions).
            if syndromes.len() >= 2 {
                let suspect = self.k + syndromes.iter().find(|(b, _)| *b).unwrap().1;
                return BexOutcome::Corrected { value: self.info_ctx.crt_signed(info), suspect };
            }
            return BexOutcome::Detected;
        }
        // all redundant residues disagree -> hypothesize one bad info residue
        for cand in 0..self.k {
            // solve for the info residue value that makes every redundant
            // syndrome vanish, using the other info residues + the first
            // redundant residue as a (k)-base reconstruction
            let mut base: Vec<u64> = Vec::with_capacity(self.k);
            let mut base_moduli: Vec<u64> = Vec::with_capacity(self.k);
            for i in 0..self.k {
                if i != cand {
                    base.push(residues[i]);
                    base_moduli.push(self.moduli[i]);
                }
            }
            base.push(residues[self.k]);
            base_moduli.push(self.moduli[self.k]);
            let ctx = match RnsContext::new(&base_moduli) {
                Ok(c) => c,
                Err(_) => continue,
            };
            let v = ctx.crt_signed(&base);
            if v > self.half || v < -(self.half - 1) {
                continue;
            }
            // verify against the remaining redundant residues
            let consistent = self.moduli[self.k + 1..]
                .iter()
                .enumerate()
                .all(|(j, &m)| (v.rem_euclid(m as i128)) as u64 == residues[self.k + 1 + j]);
            if consistent {
                return BexOutcome::Corrected { value: v, suspect: cand };
            }
        }
        BexOutcome::Detected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::{extend_moduli, paper_table1};
    use crate::util::prop::{prop_assert_eq, run_prop};

    const MODS: [u64; 4] = [63, 62, 61, 59];

    #[test]
    fn mrc_roundtrip_prop() {
        let ctx = RnsContext::new(&MODS).unwrap();
        run_prop("mixed-radix roundtrip", 300, |rng| {
            let v = rng.gen_range((ctx.big_m as u64).min(u64::MAX)) as u128;
            let res: Vec<u64> = MODS.iter().map(|&m| (v % m as u128) as u64).collect();
            let digits = to_mixed_radix(&res, &MODS);
            for (d, &m) in digits.iter().zip(&MODS) {
                assert!(*d < m);
            }
            prop_assert_eq(from_mixed_radix(&digits, &MODS), v, "roundtrip")
        });
    }

    #[test]
    fn mrc_matches_crt() {
        let ctx = RnsContext::new(&MODS).unwrap();
        for v in [0u128, 1, 62, 63, 12345, 14057693] {
            let res: Vec<u64> = MODS.iter().map(|&m| (v % m as u128) as u64).collect();
            assert_eq!(from_mixed_radix(&to_mixed_radix(&res, &MODS), &MODS), ctx.crt(&res));
        }
    }

    #[test]
    fn base_extension_correct_prop() {
        run_prop("base extension", 300, |rng| {
            let v = rng.gen_range(14_057_694) as u128; // < M
            let res: Vec<u64> = MODS.iter().map(|&m| (v % m as u128) as u64).collect();
            for m_e in [55u64, 53, 127, 255] {
                prop_assert_eq(
                    base_extend(&res, &MODS, m_e),
                    (v % m_e as u128) as u64,
                    &format!("m_e={m_e}"),
                )?;
            }
            Ok(())
        });
    }

    fn decoder() -> BexDecoder {
        let all = extend_moduli(paper_table1(8).unwrap(), 2).unwrap();
        BexDecoder::new(&all, 3).unwrap()
    }

    #[test]
    fn bex_clean_words() {
        let d = decoder();
        let all = d.moduli.clone();
        let ctx = RnsContext::new(&all).unwrap();
        for v in [-1_000_000i64, -1, 0, 1, 7_000_000] {
            let res = ctx.forward(v);
            assert_eq!(d.decode(&res), BexOutcome::Clean { value: v as i128 }, "v={v}");
        }
    }

    #[test]
    fn bex_corrects_single_info_error() {
        let d = decoder();
        let all = d.moduli.clone();
        let ctx = RnsContext::new(&all).unwrap();
        run_prop("bex info-error correction", 300, |rng| {
            let v = rng.gen_range_i64(-7_000_000, 7_000_000);
            let mut res = ctx.forward(v);
            let i = rng.gen_range(3) as usize; // info residue
            res[i] = (res[i] + 1 + rng.gen_range(all[i] - 1)) % all[i];
            match d.decode(&res) {
                BexOutcome::Corrected { value, suspect } => {
                    prop_assert_eq(value, v as i128, "value")?;
                    prop_assert_eq(suspect, i, "suspect")
                }
                other => Err(format!("expected correction, got {other:?}")),
            }
        });
    }

    #[test]
    fn bex_flags_single_redundant_error() {
        let d = decoder();
        let all = d.moduli.clone();
        let ctx = RnsContext::new(&all).unwrap();
        run_prop("bex redundant-error handling", 200, |rng| {
            let v = rng.gen_range_i64(-7_000_000, 7_000_000);
            let mut res = ctx.forward(v);
            let i = 3 + rng.gen_range(2) as usize; // redundant residue
            res[i] = (res[i] + 1 + rng.gen_range(all[i] - 1)) % all[i];
            match d.decode(&res) {
                BexOutcome::Corrected { value, suspect } => {
                    prop_assert_eq(value, v as i128, "value survives")?;
                    prop_assert_eq(suspect, i, "suspect is the redundant residue")
                }
                other => Err(format!("expected correction, got {other:?}")),
            }
        });
    }

    #[test]
    fn bex_agrees_with_voting_decoder() {
        use crate::rns::rrns::{Decode, RrnsCode};
        let all = extend_moduli(paper_table1(8).unwrap(), 2).unwrap();
        let bex = BexDecoder::new(&all, 3).unwrap();
        let vote = RrnsCode::new(&all, 3).unwrap();
        let ctx = RnsContext::new(&all).unwrap();
        run_prop("bex == voting on single errors", 200, |rng| {
            let v = rng.gen_range_i64(-7_000_000, 7_000_000);
            let mut res = ctx.forward(v);
            if rng.bernoulli(0.7) {
                let i = rng.gen_range(5) as usize;
                res[i] = (res[i] + 1 + rng.gen_range(all[i] - 1)) % all[i];
            }
            let bex_val = match bex.decode(&res) {
                BexOutcome::Clean { value } | BexOutcome::Corrected { value, .. } => Some(value),
                BexOutcome::Detected => None,
            };
            let vote_val = match vote.decode(&res) {
                Decode::Ok { value, .. } => Some(value),
                Decode::Detected => None,
            };
            prop_assert_eq(bex_val, vote_val, "decoder agreement")
        });
    }

    #[test]
    fn invalid_params() {
        assert!(BexDecoder::new(&MODS, 0).is_err());
        assert!(BexDecoder::new(&MODS, 5).is_err());
    }
}
