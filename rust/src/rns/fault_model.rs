//! Fault model for the RRNS code (paper §IV, Figs. 5-6).
//!
//! The paper abstracts analog noise to "probability of error in a single
//! residue" `p` and classifies a codeword decode into three cases with
//! probabilities `p_c` (correct/correctable), `p_d` (detectable), `p_u`
//! (undetectable), `p_c + p_d + p_u = 1`.
//!
//! We provide:
//!   * an *analytic* model: `p_c` exactly (binomial over <= t errors plus
//!     the correctable part is exact under the independent-error model);
//!     `p_d`/`p_u` from a Monte-Carlo split of the >t-error mass, because
//!     the paper's own equations (James/Peng) are not reprinted and the
//!     undetectable fraction depends on codeword geometry;
//!   * `p_err(R)` — the repeated-attempt output error probability.
//!     Eq. (5) as printed (`1 - p_c * sum_{k=1..R} p_d^k`) does not recover
//!     `p_err(1) = 1 - p_c`; we implement the corrected geometric series
//!     `1 - p_c * sum_{j=0..R-1} p_d^j`, whose R->infinity limit
//!     `p_u / (p_u + p_c)` matches the limit printed in the paper.

use super::rrns::{Decode, RrnsCode};
use crate::util::rng::Rng;

/// Binomial coefficient as f64 (n small; exact for our sizes).
pub fn binom(n: usize, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1.0f64;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
    }
    acc
}

/// Case probabilities for one codeword at single-residue error rate `p`.
#[derive(Clone, Copy, Debug, Default)]
pub struct CaseProbs {
    pub p_c: f64,
    pub p_d: f64,
    pub p_u: f64,
}

impl CaseProbs {
    /// Output error probability after at most `r` attempts (corrected
    /// Eq. (5)): success iff some attempt lands in Case 1 before a Case 3
    /// slips through; each retry is triggered by a Case 2 outcome.
    pub fn p_err(&self, r: u32) -> f64 {
        let mut geo = 0.0;
        let mut pd_pow = 1.0;
        for _ in 0..r {
            geo += pd_pow;
            pd_pow *= self.p_d;
        }
        (1.0 - self.p_c * geo).clamp(0.0, 1.0)
    }

    /// `lim_{R->inf} p_err(R) = p_u / (p_u + p_c)` (paper §IV).
    pub fn p_err_limit(&self) -> f64 {
        if self.p_u + self.p_c == 0.0 {
            1.0
        } else {
            self.p_u / (self.p_u + self.p_c)
        }
    }
}

/// Exact probability that at most `t` of `n` residues are erroneous —
/// the guaranteed-correctable mass (a lower bound on the true `p_c`;
/// under voting decode some >t patterns also decode correctly, which the
/// Monte-Carlo estimator captures).
pub fn p_correctable_analytic(n: usize, k: usize, p: f64) -> f64 {
    let t = (n - k) / 2;
    (0..=t).map(|i| binom(n, i) * p.powi(i as i32) * (1.0 - p).powi((n - i) as i32)).sum()
}

/// Monte-Carlo estimate of the three case probabilities by running the
/// actual voting decoder against uniformly-corrupted residues.
///
/// Error model (matching the paper's abstraction): each residue
/// independently flips to a uniform wrong value with probability `p`.
pub fn estimate_case_probs(code: &RrnsCode, p: f64, trials: u32, seed: u64) -> CaseProbs {
    let mut rng = Rng::seed_from(seed);
    let half = (code.legitimate_range / 2) as i64;
    let (mut c, mut d, mut u) = (0u64, 0u64, 0u64);
    let n = code.n();
    let mut res = vec![0u64; n];
    for _ in 0..trials {
        let a = rng.gen_range_i64(-(half - 1), half);
        code.full.forward_into(a, &mut res);
        for i in 0..n {
            if rng.bernoulli(p) {
                let m = code.full.moduli[i];
                res[i] = (res[i] + 1 + rng.gen_range(m - 1)) % m;
            }
        }
        match code.decode(&res) {
            Decode::Ok { value, .. } if value == a as i128 => c += 1,
            Decode::Ok { .. } => u += 1,
            Decode::Detected => d += 1,
        }
    }
    let total = trials as f64;
    CaseProbs { p_c: c as f64 / total, p_d: d as f64 / total, p_u: u as f64 / total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::{extend_moduli, paper_table1};

    fn code(bits: u32, extra: usize) -> RrnsCode {
        let base = paper_table1(bits).unwrap();
        let all = extend_moduli(base, extra).unwrap();
        RrnsCode::new(&all, base.len()).unwrap()
    }

    #[test]
    fn binom_values() {
        assert_eq!(binom(5, 0), 1.0);
        assert_eq!(binom(5, 2), 10.0);
        assert_eq!(binom(6, 3), 20.0);
        assert_eq!(binom(3, 5), 0.0);
    }

    #[test]
    fn case_probs_sum_to_one() {
        let code = code(8, 2);
        for p in [1e-3, 1e-2, 0.1, 0.4] {
            let cp = estimate_case_probs(&code, p, 4000, 1);
            assert!((cp.p_c + cp.p_d + cp.p_u - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_noise_is_always_correct() {
        let code = code(8, 2);
        let cp = estimate_case_probs(&code, 0.0, 500, 2);
        assert_eq!(cp.p_c, 1.0);
        assert_eq!(cp.p_err(1), 0.0);
    }

    #[test]
    fn analytic_lower_bounds_mc() {
        let code = code(8, 2);
        for p in [1e-2, 5e-2, 0.1] {
            let analytic = p_correctable_analytic(code.n(), code.k, p);
            let mc = estimate_case_probs(&code, p, 20_000, 3).p_c;
            assert!(
                mc >= analytic - 0.02,
                "p={p}: MC p_c {mc} should not be below analytic bound {analytic}"
            );
        }
    }

    #[test]
    fn attempts_reduce_p_err_monotonically() {
        let cp = CaseProbs { p_c: 0.7, p_d: 0.25, p_u: 0.05 };
        let mut prev = 1.0;
        for r in 1..10 {
            let pe = cp.p_err(r);
            assert!(pe <= prev + 1e-15, "R={r}");
            prev = pe;
        }
        // converges to the limit from above
        assert!((cp.p_err(200) - cp.p_err_limit()).abs() < 1e-9);
        assert!((cp.p_err_limit() - 0.05 / 0.75).abs() < 1e-12);
    }

    #[test]
    fn eq5_correction_recovers_single_attempt() {
        let cp = CaseProbs { p_c: 0.9, p_d: 0.08, p_u: 0.02 };
        assert!((cp.p_err(1) - (1.0 - 0.9)).abs() < 1e-12);
    }

    #[test]
    fn more_redundancy_lowers_p_err() {
        let p = 0.05;
        let cp1 = estimate_case_probs(&code(8, 1), p, 20_000, 4);
        let cp3 = estimate_case_probs(&code(8, 3), p, 20_000, 4);
        assert!(
            cp3.p_err(2) < cp1.p_err(2),
            "n-k=3 {} should beat n-k=1 {}",
            cp3.p_err(2),
            cp1.p_err(2)
        );
    }
}
