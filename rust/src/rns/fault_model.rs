//! Fault model for the RRNS code (paper §IV, Figs. 5-6).
//!
//! The paper abstracts analog noise to "probability of error in a single
//! residue" `p` and classifies a codeword decode into three cases with
//! probabilities `p_c` (correct/correctable), `p_d` (detectable), `p_u`
//! (undetectable), `p_c + p_d + p_u = 1`.
//!
//! We provide:
//!   * an *analytic* model: `p_c` exactly (binomial over <= t errors plus
//!     the correctable part is exact under the independent-error model);
//!     `p_d`/`p_u` from a Monte-Carlo split of the >t-error mass, because
//!     the paper's own equations (James/Peng) are not reprinted and the
//!     undetectable fraction depends on codeword geometry;
//!   * `p_err(R)` — the repeated-attempt output error probability.
//!     Eq. (5) as printed (`1 - p_c * sum_{k=1..R} p_d^k`) does not recover
//!     `p_err(1) = 1 - p_c`; we implement the corrected geometric series
//!     `1 - p_c * sum_{j=0..R-1} p_d^j`, whose R->infinity limit
//!     `p_u / (p_u + p_c)` matches the limit printed in the paper.

use super::inject::FaultSpec;
use super::rrns::{Decode, RrnsCode};
use crate::util::rng::Rng;

/// Binomial coefficient as f64 (n small; exact for our sizes).
pub fn binom(n: usize, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1.0f64;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
    }
    acc
}

/// Case probabilities for one codeword at single-residue error rate `p`.
#[derive(Clone, Copy, Debug, Default)]
pub struct CaseProbs {
    pub p_c: f64,
    pub p_d: f64,
    pub p_u: f64,
    /// Fraction of trials whose *injected* fault count was <= t — the
    /// simulated counterpart of `p_correctable_analytic` (they estimate
    /// the same binomial mass, so the two must agree within MC noise),
    /// and an exact lower bound on `p_c` trial-by-trial: every <= t
    /// pattern is guaranteed correctable.
    pub p_le_t: f64,
}

impl CaseProbs {
    /// Output error probability after at most `r` attempts (corrected
    /// Eq. (5)): success iff some attempt lands in Case 1 before a Case 3
    /// slips through; each retry is triggered by a Case 2 outcome.
    pub fn p_err(&self, r: u32) -> f64 {
        let mut geo = 0.0;
        let mut pd_pow = 1.0;
        for _ in 0..r {
            geo += pd_pow;
            pd_pow *= self.p_d;
        }
        (1.0 - self.p_c * geo).clamp(0.0, 1.0)
    }

    /// `lim_{R->inf} p_err(R) = p_u / (p_u + p_c)` (paper §IV).
    pub fn p_err_limit(&self) -> f64 {
        if self.p_u + self.p_c == 0.0 {
            1.0
        } else {
            self.p_u / (self.p_u + self.p_c)
        }
    }
}

/// Exact probability that at most `t` of `n` residues are erroneous —
/// the guaranteed-correctable mass (a lower bound on the true `p_c`;
/// under voting decode some >t patterns also decode correctly, which the
/// Monte-Carlo estimator captures).
pub fn p_correctable_analytic(n: usize, k: usize, p: f64) -> f64 {
    let t = (n - k) / 2;
    (0..=t).map(|i| binom(n, i) * p.powi(i as i32) * (1.0 - p).powi((n - i) as i32)).sum()
}

/// Monte-Carlo estimate of the three case probabilities by running the
/// actual voting decoder against uniformly-corrupted residues.
///
/// Error model (matching the paper's abstraction): each residue
/// independently flips to a uniform wrong value with probability `p`.
/// Bit-compatible with the pre-injector implementation — the shared
/// `FaultSpec::Bernoulli` injector draws in the same channel order.
pub fn estimate_case_probs(code: &RrnsCode, p: f64, trials: u32, seed: u64) -> CaseProbs {
    estimate_case_probs_spec(code, FaultSpec::Bernoulli { p }, trials, seed)
}

/// Case-probability Monte-Carlo under any injected-fault regime (the
/// shared `rns::inject` harness): Bernoulli reproduces the paper's model,
/// `Channels {count}` pins the exact fault weight (count <= t must give
/// `p_c == 1` exactly), `Burst` models correlated channel faults.
pub fn estimate_case_probs_spec(
    code: &RrnsCode,
    spec: FaultSpec,
    trials: u32,
    seed: u64,
) -> CaseProbs {
    let mut rng = Rng::seed_from(seed);
    let half = (code.legitimate_range / 2) as i64;
    let t = code.correctable();
    let (mut c, mut d, mut u, mut le_t) = (0u64, 0u64, 0u64, 0u64);
    let mut res = vec![0u64; code.n()];
    for _ in 0..trials {
        let a = rng.gen_range_i64(-(half - 1), half);
        code.full.forward_into(a, &mut res);
        let hit = spec.apply_word(&mut res, &code.full.moduli, &mut rng);
        if hit.len() <= t {
            le_t += 1;
        }
        match code.decode(&res) {
            Decode::Ok { value, .. } if value == a as i128 => c += 1,
            Decode::Ok { .. } => u += 1,
            Decode::Detected => d += 1,
        }
    }
    let total = trials as f64;
    CaseProbs {
        p_c: c as f64 / total,
        p_d: d as f64 / total,
        p_u: u as f64 / total,
        p_le_t: le_t as f64 / total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::{extend_moduli, paper_table1};

    fn code(bits: u32, extra: usize) -> RrnsCode {
        let base = paper_table1(bits).unwrap();
        let all = extend_moduli(base, extra).unwrap();
        RrnsCode::new(&all, base.len()).unwrap()
    }

    #[test]
    fn binom_values() {
        assert_eq!(binom(5, 0), 1.0);
        assert_eq!(binom(5, 2), 10.0);
        assert_eq!(binom(6, 3), 20.0);
        assert_eq!(binom(3, 5), 0.0);
    }

    #[test]
    fn case_probs_sum_to_one() {
        let code = code(8, 2);
        for p in [1e-3, 1e-2, 0.1, 0.4] {
            let cp = estimate_case_probs(&code, p, 4000, 1);
            assert!((cp.p_c + cp.p_d + cp.p_u - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_noise_is_always_correct() {
        let code = code(8, 2);
        let cp = estimate_case_probs(&code, 0.0, 500, 2);
        assert_eq!(cp.p_c, 1.0);
        assert_eq!(cp.p_err(1), 0.0);
    }

    #[test]
    fn analytic_lower_bounds_mc() {
        // The injector reports the injected fault weight, so the old
        // tolerance-only comparison sharpens to two exact facts:
        //   * p_le_t is an unbiased estimate of the analytic binomial
        //     mass (same quantity, MC noise only);
        //   * p_c >= p_le_t holds trial-by-trial (<= t is always
        //     guaranteed correctable), not merely within tolerance.
        let code = code(8, 2);
        for p in [1e-2, 5e-2, 0.1] {
            let cp = estimate_case_probs(&code, p, 20_000, 3);
            let analytic = p_correctable_analytic(code.n(), code.k, p);
            assert!(
                (cp.p_le_t - analytic).abs() < 0.01,
                "p={p}: simulated P(<=t) {} vs analytic {analytic}",
                cp.p_le_t
            );
            assert!(
                cp.p_c >= cp.p_le_t,
                "p={p}: p_c {} below the exact <=t bound {}",
                cp.p_c,
                cp.p_le_t
            );
        }
    }

    #[test]
    fn injection_matches_analytic_on_5_3_code() {
        // (5,3), t = 1: the shared injector replaces the bespoke
        // Monte-Carlo loop; its simulated correctable mass must track the
        // analytic curve across the whole p sweep.
        let base = paper_table1(8).unwrap();
        let all = extend_moduli(base, 2).unwrap();
        let code = RrnsCode::new(&all, base.len()).unwrap();
        assert_eq!((code.n(), code.k, code.correctable()), (5, 3, 1));
        for (i, p) in [1e-3, 1e-2, 5e-2, 0.1, 0.3].into_iter().enumerate() {
            let cp = estimate_case_probs(&code, p, 20_000, 40 + i as u64);
            let analytic = p_correctable_analytic(5, 3, p);
            assert!(
                (cp.p_le_t - analytic).abs() < 0.015,
                "p={p}: P(<=1 fault) sim {} vs analytic {analytic}",
                cp.p_le_t
            );
            assert!(cp.p_c >= cp.p_le_t, "p={p}");
        }
    }

    #[test]
    fn pinned_fault_weight_regimes() {
        use crate::rns::inject::FaultSpec;
        let code = code(8, 2); // (5,3), t = 1
        // <= t faults: guaranteed correctable, exactly, every trial
        for count in [0usize, 1] {
            let cp = estimate_case_probs_spec(&code, FaultSpec::Channels { count }, 2_000, 5);
            assert_eq!(cp.p_c, 1.0, "count={count} must always correct");
            assert_eq!(cp.p_le_t, 1.0);
        }
        // beyond-correctable: never counted as <= t, mostly detected
        let cp2 = estimate_case_probs_spec(&code, FaultSpec::Channels { count: 2 }, 4_000, 6);
        assert_eq!(cp2.p_le_t, 0.0);
        assert!(cp2.p_d > 0.9, "2 faults on t=1 should usually detect: p_d {}", cp2.p_d);
        assert!(cp2.p_c < 0.05, "2 faults rarely land back on the sent value");
        // a 2-wide channel burst behaves like 2 correlated faults
        let cpb =
            estimate_case_probs_spec(&code, FaultSpec::Burst { elems: 1, width: 2 }, 4_000, 7);
        assert_eq!(cpb.p_le_t, 0.0);
        assert!(cpb.p_d > 0.9, "burst width 2 should usually detect: p_d {}", cpb.p_d);
    }

    #[test]
    fn attempts_reduce_p_err_monotonically() {
        let cp = CaseProbs { p_c: 0.7, p_d: 0.25, p_u: 0.05, ..Default::default() };
        let mut prev = 1.0;
        for r in 1..10 {
            let pe = cp.p_err(r);
            assert!(pe <= prev + 1e-15, "R={r}");
            prev = pe;
        }
        // converges to the limit from above
        assert!((cp.p_err(200) - cp.p_err_limit()).abs() < 1e-9);
        assert!((cp.p_err_limit() - 0.05 / 0.75).abs() < 1e-12);
    }

    #[test]
    fn eq5_correction_recovers_single_attempt() {
        let cp = CaseProbs { p_c: 0.9, p_d: 0.08, p_u: 0.02, ..Default::default() };
        assert!((cp.p_err(1) - (1.0 - 0.9)).abs() < 1e-12);
    }

    #[test]
    fn more_redundancy_lowers_p_err() {
        let p = 0.05;
        let cp1 = estimate_case_probs(&code(8, 1), p, 20_000, 4);
        let cp3 = estimate_case_probs(&code(8, 3), p, 20_000, 4);
        assert!(
            cp3.p_err(2) < cp1.p_err(2),
            "n-k=3 {} should beat n-k=1 {}",
            cp3.p_err(2),
            cp1.p_err(2)
        );
    }
}
