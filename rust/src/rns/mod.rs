//! RNS arithmetic substrate: moduli selection (Table I), forward/CRT
//! conversion (Eq. (1)), Barrett reduction for hot modular loops, the
//! RRNS(n, k) error-correcting code (§IV) with its batched no-fault
//! fast path, its fault model (Figs. 5-6), and the deterministic
//! fault-injection harness that validates both.

pub mod barrett;
pub mod crt;
pub mod fault_model;
pub mod inject;
pub mod mixed_radix;
pub mod moduli;
pub mod rrns;

pub use barrett::BarrettReducer;
pub use crt::RnsContext;
pub use fault_model::CaseProbs;
pub use inject::{FaultInjector, FaultSpec};
pub use mixed_radix::{base_extend, BexDecoder, BexOutcome};
pub use moduli::{extend_moduli, paper_table1, required_output_bits, select_moduli};
pub use rrns::{Decode, RrnsCode, TilePrecheck};
