//! Barrett reduction for the hot modular loops (paper §V: "the modulo
//! operations are optimized using Barrett Reduction").
//!
//! For a fixed modulus `m < 2^32` precompute `mu = floor(2^64 / m)`; then
//! for `x < 2^63`, `q = mulhi(x, mu)` satisfies `q <= floor(x/m) <= q + 1`,
//! so one conditional subtraction yields the exact remainder — no division
//! on the hot path.

/// Precomputed Barrett constants for one modulus.
#[derive(Clone, Copy, Debug)]
pub struct BarrettReducer {
    pub m: u64,
    mu: u64, // floor(2^64 / m)
}

impl BarrettReducer {
    pub fn new(m: u64) -> Self {
        assert!(m >= 2, "modulus must be >= 2");
        assert!(m < (1 << 32), "Barrett constants sized for m < 2^32");
        BarrettReducer { m, mu: ((1u128 << 64) / m as u128) as u64 }
    }

    /// Exact `x mod m` for any `x < 2^63`.
    #[inline(always)]
    pub fn reduce(&self, x: u64) -> u64 {
        let q = ((x as u128 * self.mu as u128) >> 64) as u64;
        let mut r = x.wrapping_sub(q.wrapping_mul(self.m));
        // q underestimates floor(x/m) by at most 1 for x < 2^63
        if r >= self.m {
            r -= self.m;
        }
        r
    }

    /// `(a * b) mod m` with both operands already reduced (`< m < 2^32`).
    #[inline(always)]
    pub fn mul_mod(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.m && b < self.m);
        self.reduce(a * b)
    }

    /// `(a + b) mod m` with both operands already reduced.
    #[inline(always)]
    pub fn add_mod(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.m && b < self.m);
        let s = a + b;
        if s >= self.m {
            s - self.m
        } else {
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{prop_assert_eq, run_prop};

    #[test]
    fn matches_native_mod_exhaustive_small() {
        for m in [2u64, 3, 7, 11, 59, 63, 127, 255] {
            let b = BarrettReducer::new(m);
            for x in 0..2000u64 {
                assert_eq!(b.reduce(x), x % m, "x={x} m={m}");
            }
        }
    }

    #[test]
    fn matches_native_mod_prop() {
        run_prop("barrett == %", 2000, |rng| {
            let m = 2 + rng.gen_range((1 << 32) - 2);
            let x = rng.next_u64() >> 1; // < 2^63
            let b = BarrettReducer::new(m);
            prop_assert_eq(b.reduce(x), x % m, &format!("x={x} m={m}"))
        });
    }

    #[test]
    fn mul_add_mod() {
        let b = BarrettReducer::new(251);
        run_prop("barrett mul/add", 500, |rng| {
            let x = rng.gen_range(251);
            let y = rng.gen_range(251);
            prop_assert_eq(b.mul_mod(x, y), (x * y) % 251, "mul")?;
            prop_assert_eq(b.add_mod(x, y), (x + y) % 251, "add")
        });
    }

    #[test]
    fn boundary_values() {
        let b = BarrettReducer::new(59);
        assert_eq!(b.reduce(0), 0);
        assert_eq!(b.reduce(58), 58);
        assert_eq!(b.reduce(59), 0);
        assert_eq!(b.reduce((1 << 63) - 1), ((1u64 << 63) - 1) % 59);
    }

    #[test]
    #[should_panic]
    fn rejects_modulus_one() {
        BarrettReducer::new(1);
    }
}
