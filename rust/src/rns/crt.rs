//! Forward conversion and CRT reconstruction (paper Eq. (1)).
//!
//! All integer arithmetic is exact: residues are `u64`, the dynamic range
//! `M` and the CRT accumulation run in `u128` (Table-I sets have
//! `M < 2^25`, and even RRNS-extended sets stay far below `2^64`, so the
//! headroom is enormous).

use super::barrett::BarrettReducer;
use super::moduli::pairwise_coprime;
use crate::tensor::MatI;

fn egcd(a: i128, b: i128) -> (i128, i128, i128) {
    if b == 0 {
        (a, 1, 0)
    } else {
        let (g, x, y) = egcd(b, a % b);
        (g, y, x - (a / b) * y)
    }
}

/// Multiplicative inverse of `a` modulo `m` (requires gcd(a, m) = 1).
pub fn mod_inverse(a: u128, m: u128) -> Result<u128, String> {
    let (g, x, _) = egcd((a % m) as i128, m as i128);
    if g != 1 {
        return Err(format!("{a} has no inverse mod {m}"));
    }
    Ok(x.rem_euclid(m as i128) as u128)
}

/// Precomputed CRT constants for one moduli set.
///
/// `crt_coeff[i] = |M_i * T_i|_M` with `M_i = M / m_i` and
/// `T_i = M_i^{-1} mod m_i` — the paper's Eq. (1) weights.
#[derive(Clone, Debug)]
pub struct RnsContext {
    pub moduli: Vec<u64>,
    pub big_m: u128,
    pub crt_coeff: Vec<u128>,
    /// u64 fast path (perf pass §Perf): when `n * m_max * M < 2^64` the
    /// whole CRT accumulation fits u64 with a single final reduction —
    /// true for every Table-I set (M < 2^25, residues < 2^8, n <= 8).
    fast: Option<FastCrt>,
}

#[derive(Clone, Debug)]
struct FastCrt {
    coeff: Vec<u64>,
    big_m: u64,
    half: u64,
    /// Barrett constants for the final `mod M` — `Some` iff `M < 2^32`
    /// (every Table-I set).  The fast-path bound only guarantees the
    /// accumulator fits `2^63`, not that `M` fits the Barrett sizing
    /// (e.g. `[2^20, 2^20 - 1]` has `M ≈ 2^40`), so keep a `%` fallback.
    red: Option<BarrettReducer>,
}

impl FastCrt {
    /// Exact `x mod M`, division-free on the Barrett path.
    #[inline(always)]
    fn reduce(&self, x: u64) -> u64 {
        match self.red {
            Some(r) => r.reduce(x),
            None => x % self.big_m,
        }
    }
}

impl RnsContext {
    pub fn new(moduli: &[u64]) -> Result<Self, String> {
        if moduli.is_empty() {
            return Err("empty moduli set".into());
        }
        if moduli.iter().any(|&m| m < 2) {
            return Err(format!("moduli must be >= 2: {moduli:?}"));
        }
        if !pairwise_coprime(moduli) {
            return Err(format!("moduli {moduli:?} are not pairwise coprime"));
        }
        let big_m: u128 = moduli.iter().map(|&m| m as u128).product();
        let mut crt_coeff = Vec::with_capacity(moduli.len());
        for &m in moduli {
            let mi = big_m / m as u128;
            let ti = mod_inverse(mi, m as u128)?;
            crt_coeff.push((mi * ti) % big_m);
        }
        // u64 fast path: sum_i r_i * c_i < n * m_max * M must fit u64
        let m_max = *moduli.iter().max().unwrap() as u128;
        let fast = if moduli.len() as u128 * m_max * big_m < (1u128 << 63) {
            Some(FastCrt {
                coeff: crt_coeff.iter().map(|&c| c as u64).collect(),
                big_m: big_m as u64,
                half: (big_m / 2) as u64,
                red: (big_m < (1u128 << 32)).then(|| BarrettReducer::new(big_m as u64)),
            })
        } else {
            None
        };
        Ok(RnsContext { moduli: moduli.to_vec(), big_m, crt_coeff, fast })
    }

    pub fn n(&self) -> usize {
        self.moduli.len()
    }

    /// Largest magnitude representable in the symmetric signed convention
    /// `(-M/2, M/2]`.
    pub fn signed_max(&self) -> i128 {
        (self.big_m / 2) as i128
    }

    /// Forward conversion of a signed integer (negatives wrap through M:
    /// `a_i = ((a mod m_i) + m_i) mod m_i`).
    pub fn forward(&self, a: i64) -> Vec<u64> {
        self.moduli.iter().map(|&m| a.rem_euclid(m as i64) as u64).collect()
    }

    /// Forward conversion into a caller-provided buffer (hot-path variant;
    /// avoids the per-call allocation of `forward`).
    #[inline]
    pub fn forward_into(&self, a: i64, out: &mut [u64]) {
        debug_assert_eq!(out.len(), self.moduli.len());
        for (o, &m) in out.iter_mut().zip(&self.moduli) {
            *o = a.rem_euclid(m as i64) as u64;
        }
    }

    /// Eq. (1): residues -> unsigned value in `[0, M)`.
    pub fn crt(&self, residues: &[u64]) -> u128 {
        debug_assert_eq!(residues.len(), self.moduli.len());
        if let Some(fast) = &self.fast {
            // single final reduction — ~5x faster than per-term u128 mod.
            // (bound n * m_max * M < 2^63 assumes reduced residues r < m)
            let mut acc: u64 = 0;
            for ((&r, &c), &m) in residues.iter().zip(&fast.coeff).zip(&self.moduli) {
                debug_assert!(r < m, "fast CRT requires reduced residues");
                acc += r * c;
            }
            return fast.reduce(acc) as u128;
        }
        let mut acc: u128 = 0;
        for (&r, &c) in residues.iter().zip(&self.crt_coeff) {
            acc = (acc + (r as u128 % self.big_m) * c) % self.big_m;
        }
        acc
    }

    /// Signed reconstruction into `(-M/2, M/2]`.
    pub fn crt_signed(&self, residues: &[u64]) -> i128 {
        if let Some(fast) = &self.fast {
            let mut acc: u64 = 0;
            for (&r, &c) in residues.iter().zip(&fast.coeff) {
                acc += r * c;
            }
            let v = fast.reduce(acc);
            return if v > fast.half {
                v as i128 - fast.big_m as i128
            } else {
                v as i128
            };
        }
        let v = self.crt(residues);
        if v > self.big_m / 2 {
            v as i128 - self.big_m as i128
        } else {
            v as i128
        }
    }

    /// Reduce an unsigned value into the set's range (for range checks).
    pub fn reduce(&self, a: u128) -> u128 {
        a % self.big_m
    }

    /// Batch CRT: decode a whole tile of per-channel outputs in one pass.
    ///
    /// `channels[i]` holds channel i's captured residues for every output
    /// element (all the same shape).  Equivalent to calling `crt_signed`
    /// per element (signed value truncated to i64, as the cores do), but
    /// with the per-element residue gather and `(M_i, T_i)` coefficient
    /// lookups hoisted: the fast path walks each channel's buffer linearly
    /// against one precomputed coefficient, which vectorizes, then does a
    /// single reduction sweep.  Perf (§Perf log, DESIGN.md §7).
    pub fn crt_signed_tile(&self, channels: &[MatI]) -> MatI {
        assert_eq!(channels.len(), self.moduli.len());
        let (rows, cols) = (channels[0].rows, channels[0].cols);
        debug_assert!(channels.iter().all(|c| c.rows == rows && c.cols == cols));
        let len = rows * cols;
        let mut out = MatI::zeros(rows, cols);
        if let Some(fast) = &self.fast {
            // channel-major accumulation: acc[e] = sum_i r_i[e] * c_i, all
            // below 2^63 by the fast-path bound, then one reduce+sign pass.
            let mut acc = vec![0u64; len];
            for (ch, &c) in channels.iter().zip(&fast.coeff) {
                for (a, &r) in acc.iter_mut().zip(&ch.data) {
                    *a += r as u64 * c;
                }
            }
            for (o, &a) in out.data.iter_mut().zip(&acc) {
                let v = fast.reduce(a);
                *o = if v > fast.half { v as i64 - fast.big_m as i64 } else { v as i64 };
            }
            return out;
        }
        // wide fallback: per-element u128 accumulation with the hoisted
        // crt_coeff table (same math as `crt_signed`)
        let half = self.big_m / 2;
        for e in 0..len {
            let mut a: u128 = 0;
            for (ch, &c) in channels.iter().zip(&self.crt_coeff) {
                a = (a + (ch.data[e] as u64 as u128 % self.big_m) * c) % self.big_m;
            }
            out.data[e] =
                if a > half { (a as i128 - self.big_m as i128) as i64 } else { a as i64 };
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::paper_table1;
    use crate::util::prop::{prop_assert_eq, run_prop};

    #[test]
    fn mod_inverse_basics() {
        assert_eq!(mod_inverse(3, 7).unwrap(), 5); // 3*5 = 15 = 1 mod 7
        assert!(mod_inverse(6, 9).is_err());
    }

    #[test]
    fn rejects_bad_sets() {
        assert!(RnsContext::new(&[]).is_err());
        assert!(RnsContext::new(&[6, 9]).is_err());
        assert!(RnsContext::new(&[1, 3]).is_err());
    }

    #[test]
    fn crt_coeff_orthogonality() {
        let ctx = RnsContext::new(paper_table1(6).unwrap()).unwrap();
        for (i, &c) in ctx.crt_coeff.iter().enumerate() {
            for (j, &m) in ctx.moduli.iter().enumerate() {
                let expect = if i == j { 1 } else { 0 };
                assert_eq!(c % m as u128, expect, "coeff {i} mod m_{j}");
            }
        }
    }

    #[test]
    fn roundtrip_signed_prop() {
        let ctx = RnsContext::new(paper_table1(6).unwrap()).unwrap();
        let half = (ctx.big_m / 2) as i64;
        run_prop("crt signed roundtrip", 500, |rng| {
            let a = rng.gen_range_i64(-(half - 1), half);
            prop_assert_eq(ctx.crt_signed(&ctx.forward(a)), a as i128, "roundtrip")
        });
    }

    #[test]
    fn homomorphism_prop() {
        let ctx = RnsContext::new(paper_table1(8).unwrap()).unwrap();
        let bound = ((ctx.big_m as f64).sqrt() as i64) - 1;
        run_prop("rns ring homomorphism", 300, |rng| {
            let a = rng.gen_range_i64(0, bound);
            let b = rng.gen_range_i64(0, bound);
            let ra = ctx.forward(a);
            let rb = ctx.forward(b);
            let mul: Vec<u64> = ra
                .iter()
                .zip(&rb)
                .zip(&ctx.moduli)
                .map(|((&x, &y), &m)| (x * y) % m)
                .collect();
            let add: Vec<u64> = ra
                .iter()
                .zip(&rb)
                .zip(&ctx.moduli)
                .map(|((&x, &y), &m)| (x + y) % m)
                .collect();
            prop_assert_eq(ctx.crt(&mul), (a as u128) * (b as u128), "mul")?;
            prop_assert_eq(ctx.crt(&add), (a + b) as u128, "add")
        });
    }

    #[test]
    fn forward_into_matches_forward() {
        let ctx = RnsContext::new(paper_table1(5).unwrap()).unwrap();
        let mut buf = vec![0u64; ctx.n()];
        for a in [-1000i64, -1, 0, 1, 31, 12345] {
            ctx.forward_into(a, &mut buf);
            assert_eq!(buf, ctx.forward(a));
        }
    }

    #[test]
    fn crt_signed_tile_matches_per_element() {
        use crate::util::rng::Rng;
        // fast path with Barrett (Table-I set), fast path with `%`
        // fallback (M ≈ 2^40 ≥ 2^32, accumulator still < 2^63), and
        // wide path (big moduli, no fast CRT)
        for moduli in [
            vec![63u64, 62, 61, 59],
            vec![1048576u64, 1048575],
            vec![4294967291u64, 4294967279],
        ] {
            let ctx = RnsContext::new(&moduli).unwrap();
            let mut rng = Rng::seed_from(11);
            let (rows, cols) = (5usize, 7usize);
            let channels: Vec<MatI> = moduli
                .iter()
                .map(|&m| {
                    MatI::from_vec(
                        rows,
                        cols,
                        (0..rows * cols).map(|_| rng.gen_range(m) as i64).collect(),
                    )
                })
                .collect();
            let got = ctx.crt_signed_tile(&channels);
            for r in 0..rows {
                for c in 0..cols {
                    let residues: Vec<u64> =
                        channels.iter().map(|ch| ch.at(r, c) as u64).collect();
                    assert_eq!(got.at(r, c), ctx.crt_signed(&residues) as i64);
                }
            }
        }
    }

    #[test]
    fn even_m_boundary() {
        // For even M, +M/2 is representable, -M/2 aliases to it.
        let ctx = RnsContext::new(&[4, 3]).unwrap(); // M = 12
        assert_eq!(ctx.crt_signed(&ctx.forward(6)), 6);
        assert_eq!(ctx.crt_signed(&ctx.forward(-6)), 6);
        assert_eq!(ctx.crt_signed(&ctx.forward(-5)), -5);
    }
}
