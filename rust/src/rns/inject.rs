//! Deterministic fault injection for RRNS residue channels (paper §IV).
//!
//! The fault model everywhere in this crate is the paper's: a faulty
//! residue flips to a *uniform wrong* value in `[0, m)`.  This module is
//! the single source of that corruption so tests, the Monte-Carlo fault
//! model (`fault_model::estimate_case_probs`), the noise model
//! (`NoiseModel::ResidueFlip`) and the fig5 regenerator all draw from the
//! same arithmetic — and so every injected-fault regime is reproducible
//! from a seed.
//!
//! Regimes (`FaultSpec`):
//!   * `Channels { count }` — exactly `count` distinct channels per
//!     element (count <= correctable() exercises the guaranteed-correct
//!     path, count > correctable() the detect/exhaust path);
//!   * `Bernoulli { p }` — each channel independently with probability
//!     `p` (the paper's §IV abstraction; bit-compatible with the draw
//!     order `estimate_case_probs` has always used);
//!   * `Burst { elems, width }` — one burst event per tile: a contiguous
//!     run of `width` channels corrupted across `elems` consecutive
//!     output elements (a transient glitch spanning adjacent outputs);
//!   * `TemporalBurst { tiles, elems, width }` — a drift-like event: one
//!     `elems × width` rectangle drawn once and re-applied (fresh flip
//!     values, same location) to `tiles` *consecutive* tiles of a layer
//!     before a new rectangle is drawn.  The persistence lives in the
//!     stateful `FaultInjector`; the stateless `apply_tile`/`apply_word`
//!     treat it as a single-tile `Burst`.

use crate::tensor::MatI;
use crate::util::rng::Rng;

/// Flip one residue to a uniformly-chosen *different* value in `[0, m)`.
/// Shared by `NoiseModel::ResidueFlip` and every injection regime; the
/// `1 + gen_range(m - 1)` offset guarantees the value actually changes.
#[inline]
pub fn flip_residue(value: u64, m: u64, rng: &mut Rng) -> u64 {
    debug_assert!(m >= 2 && value < m);
    (value + 1 + rng.gen_range(m - 1)) % m
}

/// One injected-fault regime (see module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultSpec {
    /// Exactly `count` distinct channels corrupted per element.
    Channels { count: usize },
    /// Each channel independently corrupted with probability `p`.
    Bernoulli { p: f64 },
    /// One burst per tile: `elems` consecutive elements x `width`
    /// consecutive channels.  Applied to a single word, `elems` is moot
    /// and only the `width`-channel run is injected.
    Burst { elems: usize, width: usize },
    /// Correlated temporal burst: the same `elems x width` rectangle
    /// persists across `tiles` consecutive tiles (drift-like fault,
    /// fresh flip values each tile).  Requires a `FaultInjector` to carry
    /// the cross-tile state; used standalone it degrades to `Burst`.
    TemporalBurst { tiles: usize, elems: usize, width: usize },
}

impl FaultSpec {
    /// Corrupt one codeword in place; returns the corrupted channel
    /// indices in increasing order.
    ///
    /// Draw order is part of the contract: `Bernoulli` interleaves the
    /// per-channel Bernoulli trial with the flip draw, exactly as the
    /// pre-injector `estimate_case_probs` loop did, so seeded Monte-Carlo
    /// results are unchanged by the shared-injector refactor.
    pub fn apply_word(&self, residues: &mut [u64], moduli: &[u64], rng: &mut Rng) -> Vec<usize> {
        let n = residues.len();
        assert_eq!(n, moduli.len(), "residue/moduli length mismatch");
        match *self {
            FaultSpec::Bernoulli { p } => {
                let mut hit = Vec::new();
                for i in 0..n {
                    if rng.bernoulli(p) {
                        residues[i] = flip_residue(residues[i], moduli[i], rng);
                        hit.push(i);
                    }
                }
                hit
            }
            FaultSpec::Channels { count } => {
                assert!(count <= n, "cannot corrupt {count} of {n} channels");
                let mut hit = rng.sample_indices(n, count);
                hit.sort_unstable();
                for &i in &hit {
                    residues[i] = flip_residue(residues[i], moduli[i], rng);
                }
                hit
            }
            FaultSpec::Burst { elems: _, width }
            | FaultSpec::TemporalBurst { tiles: _, elems: _, width } => {
                let width = width.min(n);
                if width == 0 {
                    return Vec::new();
                }
                let start = rng.gen_range((n - width + 1) as u64) as usize;
                let hit: Vec<usize> = (start..start + width).collect();
                for &i in &hit {
                    residues[i] = flip_residue(residues[i], moduli[i], rng);
                }
                hit
            }
        }
    }
}

/// What a tile-level injection actually touched (for asserting decoder
/// behaviour against ground truth).
#[derive(Clone, Debug, Default)]
pub struct TileFaults {
    /// Corrupted channel indices per element (row-major linear index);
    /// empty for untouched elements.
    pub per_elem: Vec<Vec<usize>>,
    /// Elements with at least one corrupted channel.
    pub corrupted_elems: usize,
    /// Total corrupted (element, channel) pairs.
    pub corrupted_channels: u64,
}

impl TileFaults {
    fn from_per_elem(per_elem: Vec<Vec<usize>>) -> Self {
        let corrupted_elems = per_elem.iter().filter(|h| !h.is_empty()).count();
        let corrupted_channels = per_elem.iter().map(|h| h.len() as u64).sum();
        TileFaults { per_elem, corrupted_elems, corrupted_channels }
    }
}

impl FaultSpec {
    /// Corrupt a whole tile of per-channel residue matrices in place.
    ///
    /// `channels[i]` holds channel i's residues for every output element
    /// (all the same shape, values in `[0, moduli[i])`).  Per-element
    /// regimes walk elements in row-major order with one deterministic
    /// RNG stream; `Burst` draws one (element, channel) rectangle for the
    /// whole tile.
    pub fn apply_tile(&self, channels: &mut [MatI], moduli: &[u64], rng: &mut Rng) -> TileFaults {
        assert!(!channels.is_empty());
        assert_eq!(channels.len(), moduli.len());
        let len = channels[0].data.len();
        debug_assert!(channels.iter().all(|c| c.data.len() == len));
        let burst = match *self {
            FaultSpec::Burst { elems, width } => Some((elems, width)),
            // stateless path: one tile, one rectangle (the cross-tile
            // persistence needs the stateful FaultInjector)
            FaultSpec::TemporalBurst { tiles: _, elems, width } => Some((elems, width)),
            _ => None,
        };
        if let Some((elems, width)) = burst {
            let elems = elems.min(len);
            let width = width.min(channels.len());
            if width == 0 || elems == 0 {
                return TileFaults::from_per_elem(vec![Vec::new(); len]);
            }
            let e0 = rng.gen_range((len - elems + 1) as u64) as usize;
            let c0 = rng.gen_range((channels.len() - width + 1) as u64) as usize;
            return apply_rectangle(channels, moduli, rng, e0, elems, c0, width);
        }
        let mut per_elem = Vec::with_capacity(len);
        let mut word = vec![0u64; channels.len()];
        for e in 0..len {
            for (wv, ch) in word.iter_mut().zip(channels.iter()) {
                *wv = ch.data[e] as u64;
            }
            let hit = self.apply_word(&mut word, moduli, rng);
            for &i in &hit {
                channels[i].data[e] = word[i] as i64;
            }
            per_elem.push(hit);
        }
        TileFaults::from_per_elem(per_elem)
    }
}

/// Flip every (element, channel) pair of one fixed rectangle; fresh flip
/// values come from `rng`.  Shared by the stateless `Burst` tile path and
/// the injector's persistent `TemporalBurst` path so the two corrupt
/// identically given the same rectangle.
fn apply_rectangle(
    channels: &mut [MatI],
    moduli: &[u64],
    rng: &mut Rng,
    e0: usize,
    elems: usize,
    c0: usize,
    width: usize,
) -> TileFaults {
    let len = channels[0].data.len();
    let mut per_elem = vec![Vec::new(); len];
    for e in e0..e0 + elems {
        for ch in c0..c0 + width {
            let r = channels[ch].data[e] as u64;
            channels[ch].data[e] = flip_residue(r, moduli[ch], rng) as i64;
            per_elem[e].push(ch);
        }
    }
    TileFaults::from_per_elem(per_elem)
}

/// An active drift event: where the rectangle sits and how many more
/// tiles it persists for.
#[derive(Clone, Copy, Debug)]
struct TemporalEvent {
    remaining: usize,
    e0: usize,
    c0: usize,
}

/// A seeded injector: `FaultSpec` + its own RNG, so a corruption campaign
/// replays bit-for-bit from `(spec, seed)` alone.  For `TemporalBurst`
/// the injector additionally carries the active drift event across
/// `corrupt_tile` calls — feed it a layer's tiles in execution order.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    pub spec: FaultSpec,
    rng: Rng,
    temporal: Option<TemporalEvent>,
}

impl FaultInjector {
    pub fn new(spec: FaultSpec, seed: u64) -> Self {
        FaultInjector { spec, rng: Rng::seed_from(seed), temporal: None }
    }

    /// Corrupt one codeword in place; returns corrupted channel indices.
    pub fn corrupt_word(&mut self, residues: &mut [u64], moduli: &[u64]) -> Vec<usize> {
        self.spec.apply_word(residues, moduli, &mut self.rng)
    }

    /// Corrupt a tile of per-channel residue matrices in place.  For
    /// `TemporalBurst`, consecutive calls re-corrupt the same rectangle
    /// until its tile budget is spent, then draw a new one.
    pub fn corrupt_tile(&mut self, channels: &mut [MatI], moduli: &[u64]) -> TileFaults {
        if let FaultSpec::TemporalBurst { tiles, elems, width } = self.spec {
            return self.corrupt_tile_temporal(channels, moduli, tiles, elems, width);
        }
        self.spec.apply_tile(channels, moduli, &mut self.rng)
    }

    fn corrupt_tile_temporal(
        &mut self,
        channels: &mut [MatI],
        moduli: &[u64],
        tiles: usize,
        elems: usize,
        width: usize,
    ) -> TileFaults {
        assert!(!channels.is_empty());
        assert_eq!(channels.len(), moduli.len());
        let len = channels[0].data.len();
        debug_assert!(channels.iter().all(|c| c.data.len() == len));
        let elems = elems.min(len);
        let width = width.min(channels.len());
        if tiles == 0 || elems == 0 || width == 0 {
            return TileFaults::from_per_elem(vec![Vec::new(); len]);
        }
        // draw a new event when none is active (first tile, or budget
        // spent); the rectangle — not the flip values — is what persists
        let ev = match self.temporal {
            Some(ev) if ev.remaining > 0 => ev,
            _ => TemporalEvent {
                remaining: tiles,
                e0: self.rng.gen_range((len - elems + 1) as u64) as usize,
                c0: self.rng.gen_range((channels.len() - width + 1) as u64) as usize,
            },
        };
        // tiles of one layer share an output shape; clamp defensively if
        // a caller feeds a smaller trailing tile or channel set
        let e0 = ev.e0.min(len - elems);
        let c0 = ev.c0.min(channels.len() - width);
        let faults = apply_rectangle(channels, moduli, &mut self.rng, e0, elems, c0, width);
        self.temporal = Some(TemporalEvent { remaining: ev.remaining - 1, ..ev });
        faults
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::{extend_moduli, paper_table1};

    fn moduli53() -> Vec<u64> {
        extend_moduli(paper_table1(8).unwrap(), 2).unwrap() // (5,3): {255,254,253,251,249}
    }

    fn tile(moduli: &[u64], rows: usize, cols: usize, seed: u64) -> Vec<MatI> {
        let mut rng = Rng::seed_from(seed);
        moduli
            .iter()
            .map(|&m| {
                MatI::from_vec(
                    rows,
                    cols,
                    (0..rows * cols).map(|_| rng.gen_range(m) as i64).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn flip_always_changes_and_stays_in_range() {
        let mut rng = Rng::seed_from(1);
        for m in [2u64, 3, 59, 255] {
            for v in 0..m.min(40) {
                let f = flip_residue(v, m, &mut rng);
                assert_ne!(f, v, "m={m}");
                assert!(f < m);
            }
        }
    }

    #[test]
    fn injection_is_deterministic_in_seed() {
        let moduli = moduli53();
        for spec in [
            FaultSpec::Channels { count: 2 },
            FaultSpec::Bernoulli { p: 0.3 },
            FaultSpec::Burst { elems: 3, width: 2 },
        ] {
            let mut a = tile(&moduli, 4, 6, 9);
            let mut b = tile(&moduli, 4, 6, 9);
            let fa = FaultInjector::new(spec, 77).corrupt_tile(&mut a, &moduli);
            let fb = FaultInjector::new(spec, 77).corrupt_tile(&mut b, &moduli);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.data, y.data, "{spec:?}");
            }
            assert_eq!(fa.per_elem, fb.per_elem);
            // a different seed must differ somewhere for non-empty specs
            let mut c = tile(&moduli, 4, 6, 9);
            FaultInjector::new(spec, 78).corrupt_tile(&mut c, &moduli);
            assert!(a.iter().zip(&c).any(|(x, y)| x.data != y.data), "{spec:?}");
        }
    }

    #[test]
    fn channels_corrupts_exactly_count_distinct() {
        let moduli = moduli53();
        let mut rng = Rng::seed_from(3);
        for count in 0..=moduli.len() {
            let mut word: Vec<u64> = moduli.iter().map(|&m| m / 2).collect();
            let orig = word.clone();
            let hit = FaultSpec::Channels { count }.apply_word(&mut word, &moduli, &mut rng);
            assert_eq!(hit.len(), count);
            assert!(hit.windows(2).all(|w| w[0] < w[1]), "sorted + distinct");
            for i in 0..moduli.len() {
                if hit.contains(&i) {
                    assert_ne!(word[i], orig[i]);
                    assert!(word[i] < moduli[i]);
                } else {
                    assert_eq!(word[i], orig[i]);
                }
            }
        }
    }

    #[test]
    fn bernoulli_rate_matches_p() {
        let moduli = moduli53();
        let mut rng = Rng::seed_from(4);
        let spec = FaultSpec::Bernoulli { p: 0.25 };
        let mut hits = 0u64;
        let trials = 20_000;
        for _ in 0..trials {
            let mut word: Vec<u64> = moduli.iter().map(|&m| m - 1).collect();
            hits += spec.apply_word(&mut word, &moduli, &mut rng).len() as u64;
        }
        let rate = hits as f64 / (trials * moduli.len() as u64) as f64;
        assert!((rate - 0.25).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn burst_is_one_contiguous_rectangle_per_tile() {
        let moduli = moduli53();
        let (rows, cols) = (3usize, 7);
        let mut channels = tile(&moduli, rows, cols, 5);
        let orig: Vec<Vec<i64>> = channels.iter().map(|c| c.data.clone()).collect();
        let spec = FaultSpec::Burst { elems: 4, width: 2 };
        let faults = FaultInjector::new(spec, 11).corrupt_tile(&mut channels, &moduli);
        assert_eq!(faults.corrupted_elems, 4);
        assert_eq!(faults.corrupted_channels, 8);
        // affected elements are consecutive and share one channel run
        let touched: Vec<usize> = (0..rows * cols)
            .filter(|&e| !faults.per_elem[e].is_empty())
            .collect();
        assert_eq!(touched.len(), 4);
        assert!(touched.windows(2).all(|w| w[1] == w[0] + 1), "consecutive elements");
        let run = &faults.per_elem[touched[0]];
        assert_eq!(run.len(), 2);
        assert_eq!(run[1], run[0] + 1, "consecutive channels");
        for &e in &touched {
            assert_eq!(&faults.per_elem[e], run, "same channel run for every element");
        }
        // and nothing outside the rectangle moved
        for (ch, (now, before)) in channels.iter().zip(&orig).enumerate() {
            for e in 0..rows * cols {
                let in_rect = faults.per_elem[e].contains(&ch);
                assert_eq!(now.data[e] != before[e], in_rect, "ch={ch} e={e}");
            }
        }
    }

    #[test]
    fn temporal_burst_is_deterministic_and_persists_across_tiles() {
        let moduli = moduli53();
        let (rows, cols) = (4usize, 8);
        let spec = FaultSpec::TemporalBurst { tiles: 3, elems: 5, width: 2 };
        // seeded determinism over a whole tile *sequence*
        let run = |seed: u64| -> Vec<(Vec<Vec<i64>>, Vec<Vec<usize>>)> {
            let mut inj = FaultInjector::new(spec, seed);
            (0..7u64)
                .map(|t| {
                    let mut channels = tile(&moduli, rows, cols, 100 + t);
                    let f = inj.corrupt_tile(&mut channels, &moduli);
                    (channels.iter().map(|c| c.data.clone()).collect(), f.per_elem)
                })
                .collect()
        };
        let a = run(77);
        let b = run(77);
        for (t, ((da, fa), (db, fb))) in a.iter().zip(&b).enumerate() {
            assert_eq!(da, db, "tile {t}: same seed, same corruption");
            assert_eq!(fa, fb, "tile {t}");
        }
        assert_ne!(
            a.iter().map(|(d, _)| d).collect::<Vec<_>>(),
            run(78).iter().map(|(d, _)| d).collect::<Vec<_>>(),
            "different seed must corrupt differently"
        );
        // correlation: the footprint (which (elem, channel) pairs) is
        // identical within each 3-tile window — the drift pins one
        // rectangle — and every tile has exactly the 5x2 rectangle
        let footprints: Vec<&Vec<Vec<usize>>> = a.iter().map(|(_, f)| f).collect();
        for f in &footprints {
            let touched: Vec<usize> =
                (0..rows * cols).filter(|&e| !f[e].is_empty()).collect();
            assert_eq!(touched.len(), 5);
            assert!(touched.windows(2).all(|w| w[1] == w[0] + 1));
            assert!(f[touched[0]].len() == 2);
        }
        assert_eq!(footprints[0], footprints[1]);
        assert_eq!(footprints[1], footprints[2]);
        assert_eq!(footprints[3], footprints[4]);
        assert_eq!(footprints[4], footprints[5]);
        // after a window's budget is spent a fresh rectangle is drawn;
        // draws are independent, so across a handful of seeds at least
        // one must land the second event somewhere else
        let moved = (0..10u64).any(|seed| {
            let mut inj = FaultInjector::new(spec, seed);
            let fs: Vec<Vec<Vec<usize>>> = (0..4)
                .map(|t| {
                    let mut channels = tile(&moduli, rows, cols, 200 + t);
                    inj.corrupt_tile(&mut channels, &moduli).per_elem
                })
                .collect();
            fs[2] != fs[3]
        });
        assert!(moved, "a new event must eventually move the rectangle");
    }

    #[test]
    fn temporal_burst_stateless_fallback_acts_like_burst() {
        // FaultSpec::apply_tile / apply_word (no injector state) treat a
        // TemporalBurst as a single-tile Burst with the same rng stream
        let moduli = moduli53();
        let mut a = tile(&moduli, 3, 7, 50);
        let mut b = tile(&moduli, 3, 7, 50);
        let mut rng_a = Rng::seed_from(9);
        let mut rng_b = Rng::seed_from(9);
        let fa = FaultSpec::TemporalBurst { tiles: 4, elems: 4, width: 2 }
            .apply_tile(&mut a, &moduli, &mut rng_a);
        let fb = FaultSpec::Burst { elems: 4, width: 2 }.apply_tile(&mut b, &moduli, &mut rng_b);
        assert_eq!(fa.per_elem, fb.per_elem);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data, y.data);
        }
        let mut wa: Vec<u64> = moduli.iter().map(|&m| m / 3).collect();
        let mut wb = wa.clone();
        let ha = FaultSpec::TemporalBurst { tiles: 4, elems: 4, width: 2 }
            .apply_word(&mut wa, &moduli, &mut rng_a);
        let hb = FaultSpec::Burst { elems: 4, width: 2 }.apply_word(&mut wb, &moduli, &mut rng_b);
        assert_eq!(ha, hb);
        assert_eq!(wa, wb);
    }

    #[test]
    fn tile_report_counts_match() {
        let moduli = moduli53();
        let mut channels = tile(&moduli, 8, 8, 6);
        let faults =
            FaultInjector::new(FaultSpec::Channels { count: 1 }, 13).corrupt_tile(&mut channels, &moduli);
        assert_eq!(faults.per_elem.len(), 64);
        assert_eq!(faults.corrupted_elems, 64); // count=1 touches every element
        assert_eq!(faults.corrupted_channels, 64);
    }
}
