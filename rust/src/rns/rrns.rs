//! Redundant RNS error detection/correction (paper §IV).
//!
//! An RRNS(n, k) code carries k information + (n-k) redundant residues.
//! Decoding uses the paper's voting mechanism: reconstruct a candidate via
//! CRT for every one of the C(n, k) k-subsets and majority-vote.
//!
//!   * Case 1 — a strict majority agrees: accept that value (no error, or a
//!     correctable error).
//!   * Case 2 — no majority: detectable-but-uncorrectable; the coordinator
//!     recomputes the dot product (the paper's repeated-attempt loop).
//!   * Case 3 — a majority agrees on a *wrong* value: undetectable error
//!     (the decoder cannot know; quantified by `fault_model`).
//!
//! Legitimate range subtlety: the paper appends redundant moduli *below*
//! the chosen bit width, so redundant moduli are smaller than information
//! moduli and some k-subsets have products smaller than the information
//! product.  A group can only vote for values inside its own product, so
//! the legitimate range of the code is `min` over all k-subset products.
//! `RrnsCode::new` computes and exposes it; users must keep dot-product
//! outputs inside this range (checked in debug builds).

use super::barrett::BarrettReducer;
use super::crt::RnsContext;
use crate::tensor::MatI;

/// All k-combinations of `0..n` in lexicographic order.
pub fn combinations(n: usize, k: usize) -> Vec<Vec<usize>> {
    assert!(k <= n);
    let mut out = Vec::new();
    let mut idx: Vec<usize> = (0..k).collect();
    loop {
        out.push(idx.clone());
        // advance
        let mut i = k;
        loop {
            if i == 0 {
                return out;
            }
            i -= 1;
            if idx[i] != i + n - k {
                break;
            }
            if i == 0 {
                return out;
            }
        }
        idx[i] += 1;
        for j in (i + 1)..k {
            idx[j] = idx[j - 1] + 1;
        }
    }
}

/// Result of the batched consistency pre-check over one tile
/// (`RrnsCode::precheck_tile`) — tier 1 of the two-tier decode.
#[derive(Clone, Debug)]
pub struct TilePrecheck {
    /// Information-moduli CRT reconstruction for every element.  Where the
    /// pre-check passed this is exactly what `decode` would return (same
    /// first-group candidate, empty suspect set); where it failed the
    /// entry is meaningless and the element must go through voting.
    pub values: MatI,
    /// Row-major linear indices of elements that failed the pre-check
    /// (some residue inconsistent, or the reconstruction outside the
    /// legitimate range) and need the per-element voting decode.
    pub fallback: Vec<usize>,
}

/// Decode outcome classification (paper §IV cases).
#[derive(Clone, Debug, PartialEq)]
pub enum Decode {
    /// Case 1: majority agreement; value is the voted reconstruction,
    /// `suspect` lists residue indices inconsistent with it (corrected).
    Ok { value: i128, suspects: Vec<usize> },
    /// Case 2: no majority — detected, caller should recompute.
    Detected,
}

/// RRNS(n, k) codec over a full moduli set (information first, then
/// redundant). Precomputes one `RnsContext` per voting group.
#[derive(Clone, Debug)]
pub struct RrnsCode {
    /// Context over all n moduli (encode path).
    pub full: RnsContext,
    pub k: usize,
    groups: Vec<Vec<usize>>,
    group_ctxs: Vec<RnsContext>,
    /// Barrett constants for the redundant moduli (`moduli[k..]`), used by
    /// `precheck_tile`'s re-encode sweep; `None` where a modulus is too
    /// large for the Barrett sizing (`>= 2^32`).
    redundant_red: Vec<Option<BarrettReducer>>,
    /// min over k-subset products: values must lie in (-range/2, range/2].
    pub legitimate_range: u128,
}

impl RrnsCode {
    pub fn new(moduli: &[u64], k: usize) -> Result<Self, String> {
        let n = moduli.len();
        if k == 0 || k > n {
            return Err(format!("invalid RRNS parameters n={n} k={k}"));
        }
        let full = RnsContext::new(moduli)?;
        let groups = combinations(n, k);
        let mut group_ctxs = Vec::with_capacity(groups.len());
        let mut legit = u128::MAX;
        for g in &groups {
            let mods: Vec<u64> = g.iter().map(|&i| moduli[i]).collect();
            let ctx = RnsContext::new(&mods)?;
            legit = legit.min(ctx.big_m);
            group_ctxs.push(ctx);
        }
        let redundant_red = moduli[k..]
            .iter()
            .map(|&m| (m < (1u64 << 32)).then(|| BarrettReducer::new(m)))
            .collect();
        Ok(RrnsCode { full, k, groups, group_ctxs, redundant_red, legitimate_range: legit })
    }

    pub fn n(&self) -> usize {
        self.full.n()
    }

    /// Number of redundant residues.
    pub fn redundancy(&self) -> usize {
        self.n() - self.k
    }

    /// Errors guaranteed correctable: floor((n-k)/2) (paper §IV).
    pub fn correctable(&self) -> usize {
        self.redundancy() / 2
    }

    pub fn groups(&self) -> &[Vec<usize>] {
        &self.groups
    }

    /// Context over the k information moduli alone (the first voting group
    /// — `combinations` is lexicographic, so group 0 is always `0..k`).
    pub fn info_ctx(&self) -> &RnsContext {
        debug_assert!(self.groups[0].iter().copied().eq(0..self.k));
        &self.group_ctxs[0]
    }

    /// Encode a whole tile of signed values into all n residue channels
    /// (per-channel matrices, the layout `RnsCore` decodes from).
    pub fn encode_tile(&self, values: &MatI) -> Vec<MatI> {
        debug_assert!(values.data.iter().all(|&v| (v.unsigned_abs() as u128) <= self.legitimate_range / 2));
        self.full.moduli.iter().map(|&m| values.map(|v| v.rem_euclid(m as i64))).collect()
    }

    /// Tier 1 of the two-tier decode: batched consistency pre-check.
    ///
    /// Reconstructs every element through one batch CRT over the k
    /// information moduli (`crt_signed_tile`, hoisted coefficients), then
    /// re-encodes the reconstruction into each redundant channel and
    /// compares against the captured residues with one linear sweep per
    /// channel.  An element passes iff the reconstruction lies in the
    /// legitimate range and every redundant residue matches (information
    /// residues match by CRT construction, given reduced inputs).
    ///
    /// A passing element is bit-identical to `decode`: the pre-check
    /// condition is precisely "`decode`'s first group candidate is in
    /// range with an empty suspect set", so the voting loop would accept
    /// the same value without drawing anything.  Failing elements are
    /// returned in `fallback` for the per-element voting path.
    ///
    /// Precondition: residues are reduced (`channels[i]` in
    /// `[0, moduli[i])`), which ADC capture guarantees.
    pub fn precheck_tile(&self, channels: &[MatI]) -> TilePrecheck {
        assert_eq!(channels.len(), self.n(), "one channel matrix per modulus");
        // the fast-path accept rule assumes *every* channel is reduced:
        // unreduced info residues would feed the u64 CRT accumulation
        // garbage that could still land in range and match the redundant
        // channels, silently fast-pathing a wrong value
        debug_assert!(channels.iter().zip(&self.full.moduli).all(|(ch, &m)| {
            ch.data.iter().all(|&r| (0..m as i64).contains(&r))
        }));
        let values = self.info_ctx().crt_signed_tile(&channels[..self.k]);
        let len = values.data.len();
        let half = (self.legitimate_range / 2) as i128;
        let mut ok = vec![true; len];
        for (o, &v) in ok.iter_mut().zip(&values.data) {
            let v = v as i128;
            *o = v <= half && v >= -(half - 1);
        }
        for ((j, ch), red) in (self.k..self.n()).zip(&channels[self.k..]).zip(&self.redundant_red) {
            let m = self.full.moduli[j] as i64;
            match red {
                // division-free re-encode: |v| mod m via Barrett, then the
                // signed fold `m - a` for negatives (a = 0 stays 0)
                Some(red) => {
                    for ((o, &v), &r) in ok.iter_mut().zip(&values.data).zip(&ch.data) {
                        let va = v.unsigned_abs();
                        let enc = if va < (1u64 << 63) {
                            let a = red.reduce(va);
                            if v >= 0 || a == 0 { a as i64 } else { (red.m - a) as i64 }
                        } else {
                            // i64::MIN: unsigned_abs is 2^63, outside the
                            // Barrett exactness bound
                            v.rem_euclid(m)
                        };
                        *o &= enc == r;
                    }
                }
                None => {
                    for ((o, &v), &r) in ok.iter_mut().zip(&values.data).zip(&ch.data) {
                        *o &= v.rem_euclid(m) == r;
                    }
                }
            }
        }
        let fallback = ok.iter().enumerate().filter(|&(_, &o)| !o).map(|(e, _)| e).collect();
        TilePrecheck { values, fallback }
    }

    /// Encode a signed value into all n residues.
    pub fn encode(&self, a: i64) -> Vec<u64> {
        debug_assert!(
            (a.unsigned_abs() as u128) <= self.legitimate_range / 2,
            "value {a} outside legitimate range {}",
            self.legitimate_range
        );
        self.full.forward(a)
    }

    /// Voting decode (paper §IV): CRT per k-group, then accept the group
    /// candidate consistent with at least `n - t` of the received residues
    /// (t = floor((n-k)/2)).
    ///
    /// Note on the paper's ">50% of the groups" phrasing: a single
    /// erroneous residue contaminates C(n-1, k-1) of the C(n, k) groups,
    /// which is *more than half* whenever k >= (n+1)/2 — so literal
    /// strict-majority voting over group values cannot correct even one
    /// error for codes like RRNS(5, 3).  The consistency-count vote used
    /// here is the standard maximum-likelihood RRNS decode: a candidate
    /// within the legitimate range that at most t residues disagree with is
    /// unique when at most t errors occurred (codeword distance n-k+1), so
    /// it corrects exactly the floor((n-k)/2) errors the paper claims.
    pub fn decode(&self, residues: &[u64]) -> Decode {
        debug_assert_eq!(residues.len(), self.n());
        let n = self.n();
        let t = self.redundancy() / 2;
        let half = (self.legitimate_range / 2) as i128;
        let mut group_res: Vec<u64> = Vec::with_capacity(self.k);
        let mut seen: Vec<i128> = Vec::with_capacity(self.groups.len());
        for (g, ctx) in self.groups.iter().zip(&self.group_ctxs) {
            group_res.clear();
            group_res.extend(g.iter().map(|&i| residues[i]));
            let v = ctx.crt_signed(&group_res);
            // candidates must lie in the code's legitimate range
            if v > half || v < -(half - 1) || seen.contains(&v) {
                continue;
            }
            seen.push(v);
            let suspects: Vec<usize> = self
                .full
                .moduli
                .iter()
                .enumerate()
                .filter(|&(i, &m)| residues[i] != (v.rem_euclid(m as i128)) as u64)
                .map(|(i, _)| i)
                .collect();
            if suspects.len() <= t {
                // at most t disagreeing residues: unique ML codeword when
                // at most t errors occurred; n - suspects.len() groups that
                // avoid the suspects all voted for this value.
                return Decode::Ok { value: v, suspects };
            }
            let _ = n;
        }
        Decode::Detected
    }

    /// Maximum-likelihood fallback when retries are exhausted: the group
    /// candidate (within the legitimate range) consistent with the most
    /// residues, even if below the guaranteed-correction threshold.  Far
    /// better than trusting the information residues blindly — used by the
    /// core after `max_attempts` Case-2 outcomes.
    pub fn decode_best_effort(&self, residues: &[u64]) -> i128 {
        let half = (self.legitimate_range / 2) as i128;
        let mut best_v = 0i128;
        let mut best_consistent = -1i64;
        let mut group_res: Vec<u64> = Vec::with_capacity(self.k);
        for (g, ctx) in self.groups.iter().zip(&self.group_ctxs) {
            group_res.clear();
            group_res.extend(g.iter().map(|&i| residues[i]));
            let v = ctx.crt_signed(&group_res);
            if v > half || v < -(half - 1) {
                continue;
            }
            let consistent = self
                .full
                .moduli
                .iter()
                .enumerate()
                .filter(|&(i, &m)| residues[i] == (v.rem_euclid(m as i128)) as u64)
                .count() as i64;
            if consistent > best_consistent {
                best_consistent = consistent;
                best_v = v;
            }
        }
        best_v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::moduli::{extend_moduli, paper_table1};
    use crate::util::prop::{prop_assert, prop_assert_eq, run_prop};
    use crate::util::rng::Rng;

    fn code_b8(extra: usize) -> RrnsCode {
        let base = paper_table1(8).unwrap();
        let all = extend_moduli(base, extra).unwrap();
        RrnsCode::new(&all, base.len()).unwrap()
    }

    #[test]
    fn combinations_counts() {
        assert_eq!(combinations(5, 3).len(), 10);
        assert_eq!(combinations(4, 4).len(), 1);
        assert_eq!(combinations(6, 1).len(), 6);
        // lexicographic & distinct
        let c = combinations(5, 2);
        assert_eq!(c[0], vec![0, 1]);
        assert_eq!(c.last().unwrap(), &vec![3, 4]);
    }

    #[test]
    fn clean_roundtrip() {
        let code = code_b8(2);
        let half = (code.legitimate_range / 2) as i64;
        run_prop("rrns clean roundtrip", 300, |rng| {
            let a = rng.gen_range_i64(-(half - 1), half);
            match code.decode(&code.encode(a)) {
                Decode::Ok { value, suspects } => {
                    prop_assert_eq(value, a as i128, "value")?;
                    prop_assert(suspects.is_empty(), "no suspects on clean word")
                }
                Decode::Detected => Err("clean word flagged as detected".into()),
            }
        });
    }

    #[test]
    fn corrects_up_to_t_errors() {
        // n-k = 2 -> t = 1 correctable error; n-k = 4 -> t = 2.
        for extra in [2usize, 4] {
            let code = code_b8(extra);
            let t = code.correctable();
            assert_eq!(t, extra / 2);
            let half = (code.legitimate_range / 2) as i64;
            run_prop(&format!("rrns corrects {t} errors"), 200, |rng| {
                let a = rng.gen_range_i64(-(half - 1), half);
                let mut res = code.encode(a);
                let idxs = {
                    let mut r = Rng::seed_from(rng.next_u64());
                    r.sample_indices(code.n(), t)
                };
                for &i in &idxs {
                    let m = code.full.moduli[i];
                    let delta = 1 + rng.gen_range(m - 1);
                    res[i] = (res[i] + delta) % m;
                }
                match code.decode(&res) {
                    Decode::Ok { value, suspects } => {
                        prop_assert_eq(value, a as i128, "corrected value")?;
                        prop_assert_eq(suspects.len(), idxs.len(), "suspect count")?;
                        let mut s = suspects.clone();
                        s.sort();
                        let mut e = idxs.clone();
                        e.sort();
                        prop_assert_eq(s, e, "suspect identity")
                    }
                    Decode::Detected => Err(format!("{t} errors should be correctable")),
                }
            });
        }
    }

    #[test]
    fn detects_more_than_t_errors() {
        // With n-k = 2 (t = 1), 2 errors must not be silently mis-corrected
        // to a *different* value with majority — they are either Detected or
        // (rarely, Case 3) decoded wrong.  We assert they are never decoded
        // to a wrong value while flagging no suspects.
        let code = code_b8(2);
        let half = (code.legitimate_range / 2) as i64;
        let mut detected = 0u32;
        run_prop("rrns 2-error behaviour", 300, |rng| {
            let a = rng.gen_range_i64(-(half - 1), half);
            let mut res = code.encode(a);
            let idxs = {
                let mut r = Rng::seed_from(rng.next_u64());
                r.sample_indices(code.n(), 2)
            };
            for &i in &idxs {
                let m = code.full.moduli[i];
                res[i] = (res[i] + 1 + rng.gen_range(m - 1)) % m;
            }
            match code.decode(&res) {
                Decode::Detected => {
                    detected += 1;
                    Ok(())
                }
                Decode::Ok { value, suspects } => {
                    // Case 3 (undetected): wrong value with full consistency
                    // is possible but must be rare; wrong value with empty
                    // suspect list is impossible by construction.
                    if value != a as i128 {
                        prop_assert(!suspects.is_empty(), "wrong value cannot be fully consistent")
                    } else {
                        Ok(())
                    }
                }
            }
        });
        assert!(detected > 250, "2 errors should usually be detected, got {detected}/300");
    }

    #[test]
    fn legitimate_range_is_min_group_product() {
        let code = code_b8(2); // moduli {255,254,253,251,249}? extend by 2
        let mods = &code.full.moduli;
        let mut min_prod = u128::MAX;
        for g in code.groups() {
            let p: u128 = g.iter().map(|&i| mods[i] as u128).product();
            min_prod = min_prod.min(p);
        }
        assert_eq!(code.legitimate_range, min_prod);
        // and it still covers the b=8, h=128 dot-product range (Eq. 4)
        assert!(code.legitimate_range >= 1 << 22);
    }

    #[test]
    fn k_equals_n_degenerates_to_plain_rns() {
        let code = RrnsCode::new(paper_table1(6).unwrap(), 4).unwrap();
        assert_eq!(code.redundancy(), 0);
        assert_eq!(code.correctable(), 0);
        match code.decode(&code.encode(-7777)) {
            Decode::Ok { value, .. } => assert_eq!(value, -7777),
            _ => panic!("single group always has majority"),
        }
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(RrnsCode::new(&[255, 254, 253], 0).is_err());
        assert!(RrnsCode::new(&[255, 254, 253], 4).is_err());
    }

    #[test]
    fn precheck_clean_tile_passes_everything() {
        let code = code_b8(2);
        let half = (code.legitimate_range / 2) as i64;
        let mut rng = Rng::seed_from(21);
        let values = MatI::from_vec(
            3,
            5,
            (0..15).map(|_| rng.gen_range_i64(-(half - 1), half)).collect(),
        );
        let channels = code.encode_tile(&values);
        let pre = code.precheck_tile(&channels);
        assert!(pre.fallback.is_empty());
        assert_eq!(pre.values.data, values.data);
    }

    #[test]
    fn precheck_barrett_reencode_matches_rem_euclid() {
        // the redundant-channel sweep re-encodes signed reconstructions
        // with Barrett constants; the signed fold (m - |v| mod m for
        // negatives) must agree with rem_euclid everywhere, including
        // zero, sign flips, and the legitimate-range extremes
        let code = code_b8(2);
        let half = (code.legitimate_range / 2) as i64;
        let mut probe: Vec<i64> = vec![0, 1, -1, half, -(half - 1), half / 2, -(half / 2)];
        let mut rng = Rng::seed_from(23);
        probe.extend((0..57).map(|_| rng.gen_range_i64(-(half - 1), half)));
        let values = MatI::from_vec(8, 8, probe);
        let channels = code.encode_tile(&values);
        let pre = code.precheck_tile(&channels);
        assert!(pre.fallback.is_empty());
        assert_eq!(pre.values.data, values.data);
    }

    #[test]
    fn precheck_flags_exactly_the_corrupted_elements() {
        let code = code_b8(2);
        let half = (code.legitimate_range / 2) as i64;
        let mut rng = Rng::seed_from(22);
        let values = MatI::from_vec(
            4,
            4,
            (0..16).map(|_| rng.gen_range_i64(-(half - 1), half)).collect(),
        );
        let mut channels = code.encode_tile(&values);
        // corrupt element 5 on an info channel and element 12 on a
        // redundant channel: both must fall back, nothing else
        let m1 = code.full.moduli[1];
        channels[1].data[5] = ((channels[1].data[5] as u64 + 1) % m1) as i64;
        let m4 = code.full.moduli[4];
        channels[4].data[12] = ((channels[4].data[12] as u64 + 1) % m4) as i64;
        let pre = code.precheck_tile(&channels);
        assert_eq!(pre.fallback, vec![5, 12]);
        // untouched elements keep their exact values
        for e in 0..16 {
            if e == 5 || e == 12 {
                continue;
            }
            assert_eq!(pre.values.data[e], values.data[e], "element {e}");
        }
    }

    #[test]
    fn precheck_rejects_out_of_legitimate_range_values() {
        // fully consistent residues for a value inside the info product
        // but outside the (smaller) legitimate range must NOT fast-path:
        // decode skips that first-group candidate, so must the pre-check.
        let code = code_b8(2);
        let info_half = (code.info_ctx().big_m / 2) as i64;
        let legit_half = (code.legitimate_range / 2) as i64;
        assert!(info_half > legit_half, "redundant moduli shrink the range");
        let v = legit_half + (info_half - legit_half) / 2;
        let channels: Vec<MatI> = code
            .full
            .moduli
            .iter()
            .map(|&m| MatI::from_vec(1, 1, vec![v.rem_euclid(m as i64)]))
            .collect();
        let pre = code.precheck_tile(&channels);
        assert_eq!(pre.fallback, vec![0]);
    }

    #[test]
    fn precheck_fast_path_matches_decode_on_correctable_words() {
        // elements with faults land in fallback; fast-path elements carry
        // exactly decode()'s value
        let code = code_b8(4); // t = 2
        let half = (code.legitimate_range / 2) as i64;
        run_prop("precheck vs decode", 100, |rng| {
            let values = MatI::from_vec(
                2,
                3,
                (0..6).map(|_| rng.gen_range_i64(-(half - 1), half)).collect(),
            );
            let mut channels = code.encode_tile(&values);
            // corrupt one random element with t faults
            let e = rng.gen_range(6) as usize;
            let idxs = rng.sample_indices(code.n(), code.correctable());
            for &i in &idxs {
                let m = code.full.moduli[i];
                let r = channels[i].data[e] as u64;
                channels[i].data[e] = ((r + 1 + rng.gen_range(m - 1)) % m) as i64;
            }
            let pre = code.precheck_tile(&channels);
            prop_assert_eq(pre.fallback.clone(), vec![e], "only the faulty element falls back")?;
            for (j, &v) in pre.values.data.iter().enumerate() {
                if j == e {
                    continue;
                }
                let residues: Vec<u64> =
                    channels.iter().map(|ch| ch.data[j] as u64).collect();
                match code.decode(&residues) {
                    Decode::Ok { value, suspects } => {
                        prop_assert_eq(value, v as i128, "fast value == decode value")?;
                        prop_assert(suspects.is_empty(), "clean word has no suspects")?;
                    }
                    Decode::Detected => return Err("clean word flagged".into()),
                }
            }
            Ok(())
        });
    }
}
