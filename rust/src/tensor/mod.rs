//! Minimal dense tensor substrate: row-major matrices (f32 / i64) and NHWC
//! image tensors, with the GEMM / im2col machinery the nn layers build on.
//!
//! Deliberately small: the accelerator simulator needs exact integer GEMMs
//! and f32 reference GEMMs, not a full ndarray library.

pub mod gemm;
pub mod im2col;

/// Row-major 2-D matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix<T> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<T>,
}

impl<T: Copy + Default> Matrix<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![T::default(); rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> T {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Self {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.at(r, c));
            }
        }
        out
    }

    /// Map elementwise into a (possibly different-typed) matrix.
    pub fn map<U: Copy + Default, F: Fn(T) -> U>(&self, f: F) -> Matrix<U> {
        Matrix { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Horizontal slice of columns `[c0, c1)` (copied).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Self {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Matrix::zeros(self.rows, c1 - c0);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Vertical slice of rows `[r0, r1)` (copied).
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Self {
        assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }
}

pub type MatF = Matrix<f32>;
pub type MatI = Matrix<i64>;

impl MatF {
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }
}

/// NHWC 4-D tensor (batch, height, width, channels) for the conv layers.
#[derive(Clone, Debug, PartialEq)]
pub struct Nhwc {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Nhwc {
    pub fn zeros(n: usize, h: usize, w: usize, c: usize) -> Self {
        Nhwc { n, h, w, c, data: vec![0.0; n * h * w * c] }
    }

    pub fn from_vec(n: usize, h: usize, w: usize, c: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * h * w * c, "shape/data mismatch");
        Nhwc { n, h, w, c, data }
    }

    #[inline(always)]
    pub fn idx(&self, b: usize, y: usize, x: usize, ch: usize) -> usize {
        ((b * self.h + y) * self.w + x) * self.c + ch
    }

    #[inline(always)]
    pub fn at(&self, b: usize, y: usize, x: usize, ch: usize) -> f32 {
        self.data[self.idx(b, y, x, ch)]
    }

    #[inline(always)]
    pub fn set(&mut self, b: usize, y: usize, x: usize, ch: usize, v: f32) {
        let i = self.idx(b, y, x, ch);
        self.data[i] = v;
    }

    /// Flatten to (n, h*w*c) — matches jax's `reshape((B, -1))` on NHWC.
    pub fn flatten(&self) -> MatF {
        MatF::from_vec(self.n, self.h * self.w * self.c, self.data.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_indexing_row_major() {
        let m = MatF::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 0), 1.);
        assert_eq!(m.at(0, 2), 3.);
        assert_eq!(m.at(1, 0), 4.);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = MatF::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.at(2, 1), 6.);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn slices() {
        let m = MatF::from_vec(3, 4, (0..12).map(|x| x as f32).collect());
        let c = m.slice_cols(1, 3);
        assert_eq!(c.cols, 2);
        assert_eq!(c.row(2), &[9., 10.]);
        let r = m.slice_rows(1, 2);
        assert_eq!(r.rows, 1);
        assert_eq!(r.row(0), &[4., 5., 6., 7.]);
    }

    #[test]
    fn map_changes_type() {
        let m = MatF::from_vec(1, 3, vec![1.4, 2.6, -3.5]);
        let i: MatI = m.map(|x| x.round() as i64);
        assert_eq!(i.data, vec![1, 3, -4]);
    }

    #[test]
    fn nhwc_layout_matches_flatten() {
        let mut t = Nhwc::zeros(1, 2, 2, 3);
        t.set(0, 1, 0, 2, 7.0);
        let flat = t.flatten();
        // NHWC row-major: index = ((y*W)+x)*C + c = ((1*2)+0)*3+2 = 8
        assert_eq!(flat.at(0, 8), 7.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        MatF::from_vec(2, 2, vec![0.0; 3]);
    }

    #[test]
    fn max_abs() {
        let m = MatF::from_vec(1, 4, vec![0.5, -2.5, 1.0, 2.0]);
        assert_eq!(m.max_abs(), 2.5);
    }
}
