//! GEMM kernels: f32 reference (the paper's "FP32 ground truth"), exact
//! i64, and the modular i64 GEMM that models one analog residue channel.
//!
//! Layout convention everywhere: `y = x @ w` with x: (B, K), w: (K, N),
//! y: (B, N) — matching the jax side.  Inner loops are written in the
//! i-k-j order so the w row stays in cache and the compiler can
//! autovectorize the j loop.

use super::{MatF, MatI};
use crate::rns::BarrettReducer;

/// f32 GEMM: y = x @ w (the FP32 baseline all accuracy is normalized to).
pub fn gemm_f32(x: &MatF, w: &MatF) -> MatF {
    assert_eq!(x.cols, w.rows, "gemm shape mismatch");
    let mut y = MatF::zeros(x.rows, w.cols);
    for i in 0..x.rows {
        let xrow = x.row(i);
        let yrow = y.row_mut(i);
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = w.row(k);
            for j in 0..wrow.len() {
                yrow[j] += xv * wrow[j];
            }
        }
    }
    y
}

/// Exact integer GEMM: y = x @ w in i64 (overflow-checked in debug).
pub fn gemm_i64(x: &MatI, w: &MatI) -> MatI {
    assert_eq!(x.cols, w.rows, "gemm shape mismatch");
    let mut y = MatI::zeros(x.rows, w.cols);
    for i in 0..x.rows {
        let xrow = x.row(i);
        let yrow = y.row_mut(i);
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let wrow = w.row(k);
            for j in 0..wrow.len() {
                yrow[j] = yrow[j]
                    .checked_add(xv.checked_mul(wrow[j]).expect("gemm_i64 mul overflow"))
                    .expect("gemm_i64 add overflow");
            }
        }
    }
    y
}

/// Pack one residue weight matrix as `u32` for the staged kernel.
///
/// Perf (§Perf log, DESIGN.md §7): with `u32` weights the inner loop is a
/// u32*u32->u64 widening multiply-add, which the autovectorizer turns into
/// vpmuludq lanes (i64*i64 has no AVX2 vector multiply).  The seed staged
/// on every `gemm_mod` call; `PreparedWeights` (runtime/plan.rs) calls
/// this once per layer instead.
pub fn stage_weights_u32(w: &MatI, m: u64) -> Vec<u32> {
    debug_assert!(m < (1 << 32));
    w.data
        .iter()
        .map(|&v| {
            debug_assert!((0..m as i64).contains(&v), "w residue out of range");
            v as u32
        })
        .collect()
}

/// Column block size for the staged kernel: 256 u64 accumulators = 2 KiB,
/// small enough to stay register/L1-resident while each staged weight row
/// chunk streams through.
const GEMM_MOD_COL_BLOCK: usize = 256;

/// Modular GEMM against pre-staged `u32` weights (`w32` is row-major
/// `x.cols x n_cols`, every value `< m`).  Cache-blocked over output
/// columns; bit-identical to `gemm_mod` since all modular arithmetic is
/// exact regardless of blocking.
///
/// Accumulates u64 partial sums and Barrett-reduces every `block` rows so
/// the accumulator never overflows: with residues < 2^8 and block = 2^16,
/// partial sums stay below 2^32 + m.
pub fn gemm_mod_staged(x: &MatI, w32: &[u32], n_cols: usize, m: u64) -> MatI {
    assert_eq!(w32.len(), x.cols * n_cols, "staged weight shape mismatch");
    // even a block of one product must fit on top of a reduced residual:
    // residual + product <= (m-1) + (m-1)^2 = m(m-1), which must stay
    // inside BarrettReducer::reduce's exact domain (x < 2^63).  Largest
    // admissible modulus: 3037000499 (~2^31.5).
    assert!(
        m.checked_mul(m.saturating_sub(1)).is_some_and(|p| p < (1 << 63)),
        "modulus {m} too large for the staged kernel (residual + one product must stay < 2^63)"
    );
    let red = BarrettReducer::new(m);
    // residue products < m^2, and a mid-stream reduction leaves a
    // residual < m in the accumulator — so size the block for the budget
    // left *after* that residual, not the full 2^63
    let block = (((u64::MAX >> 1) - m) / (m * m).max(1)).min(1 << 20).max(1) as usize;
    let mut y = MatI::zeros(x.rows, n_cols);
    let mut acc = [0u64; GEMM_MOD_COL_BLOCK];
    for i in 0..x.rows {
        let xrow = x.row(i);
        let mut j0 = 0;
        while j0 < n_cols {
            let j1 = (j0 + GEMM_MOD_COL_BLOCK).min(n_cols);
            let acc = &mut acc[..j1 - j0];
            acc.iter_mut().for_each(|a| *a = 0);
            let mut since_reduce = 0usize;
            for (k, &xv) in xrow.iter().enumerate() {
                debug_assert!((0..m as i64).contains(&xv), "x residue out of range");
                let xv = xv as u64;
                if xv != 0 {
                    let wrow = &w32[k * n_cols + j0..k * n_cols + j1];
                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                        *a += xv * wv as u64;
                    }
                }
                since_reduce += 1;
                if since_reduce == block {
                    for a in acc.iter_mut() {
                        *a = red.reduce(*a);
                    }
                    since_reduce = 0;
                }
            }
            for (yv, &a) in y.row_mut(i)[j0..j1].iter_mut().zip(acc.iter()) {
                *yv = red.reduce(a) as i64;
            }
            j0 = j1;
        }
    }
    y
}

/// Modular GEMM for one residue channel: `y = (x @ w) mod m` with inputs
/// already reduced (`< m`).  This is the digital twin of one analog MVM
/// unit + analog modulo in the paper's Fig. 2 — and the rust-native
/// counterpart of the pallas kernel (bit-identical by construction).
///
/// Unprepared entry point: stages `w` on every call.  The prepared path
/// (`ModularGemmEngine::matmul_mod_prepared` over an `RnsPlan`) stages once
/// per layer and calls `gemm_mod_staged` directly.
pub fn gemm_mod(x: &MatI, w: &MatI, m: u64) -> MatI {
    assert_eq!(x.cols, w.rows, "gemm shape mismatch");
    let w32 = stage_weights_u32(w, m);
    gemm_mod_staged(x, &w32, w.cols, m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{prop_assert_eq, run_prop};
    use crate::util::rng::Rng;

    fn rand_mat_i(rng: &mut Rng, rows: usize, cols: usize, lo: i64, hi: i64) -> MatI {
        let data = (0..rows * cols).map(|_| rng.gen_range_i64(lo, hi)).collect();
        MatI::from_vec(rows, cols, data)
    }

    #[test]
    fn gemm_f32_known() {
        let x = MatF::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let w = MatF::from_vec(2, 2, vec![1., 1., 1., 1.]);
        assert_eq!(gemm_f32(&x, &w).data, vec![3., 3., 7., 7.]);
    }

    #[test]
    fn gemm_i64_known() {
        let x = MatI::from_vec(1, 3, vec![1, -2, 3]);
        let w = MatI::from_vec(3, 2, vec![4, 0, 0, 5, 1, 1]);
        assert_eq!(gemm_i64(&x, &w).data, vec![7, -7]);
    }

    #[test]
    fn gemm_mod_matches_i64_then_mod_prop() {
        run_prop("gemm_mod == gemm_i64 % m", 40, |rng| {
            let m = [11u64, 59, 63, 127, 253, 255][rng.gen_range(6) as usize];
            let b = 1 + rng.gen_range(4) as usize;
            let k = 1 + rng.gen_range(200) as usize;
            let n = 1 + rng.gen_range(16) as usize;
            let x = rand_mat_i(rng, b, k, 0, m as i64 - 1);
            let w = rand_mat_i(rng, k, n, 0, m as i64 - 1);
            let exact = gemm_i64(&x, &w);
            let want: Vec<i64> = exact.data.iter().map(|&v| v.rem_euclid(m as i64)).collect();
            prop_assert_eq(gemm_mod(&x, &w, m).data, want, &format!("m={m} k={k}"))
        });
    }

    #[test]
    fn gemm_mod_identity() {
        // x @ I mod m == x mod m
        let m = 63u64;
        let x = MatI::from_vec(2, 3, vec![1, 62, 5, 0, 33, 17]);
        let mut ident = MatI::zeros(3, 3);
        for i in 0..3 {
            ident.set(i, i, 1);
        }
        assert_eq!(gemm_mod(&x, &ident, m).data, x.data);
    }

    #[test]
    fn gemm_mod_staged_matches_unstaged_prop() {
        // staged kernel (cache-blocked, pre-packed u32) == per-call path,
        // including shapes wider than one column block
        run_prop("gemm_mod_staged == gemm_mod", 30, |rng| {
            let m = [11u64, 63, 255, 1021][rng.gen_range(4) as usize];
            let b = 1 + rng.gen_range(3) as usize;
            let k = 1 + rng.gen_range(80) as usize;
            let n = 1 + rng.gen_range(400) as usize;
            let x = rand_mat_i(rng, b, k, 0, m as i64 - 1);
            let w = rand_mat_i(rng, k, n, 0, m as i64 - 1);
            let staged = stage_weights_u32(&w, m);
            prop_assert_eq(
                gemm_mod_staged(&x, &staged, n, m).data,
                gemm_mod(&x, &w, m).data,
                &format!("m={m} n={n}"),
            )
        });
    }

    #[test]
    fn gemm_mod_staged_large_moduli_force_mid_block_reduction() {
        // moduli near 2^31 size the reduction block to 1-2 products, so
        // any K >= 3 forces mid-stream reductions whose residual < m is
        // carried into the next block — the case the block sizing must
        // budget for.  gemm_i64 would overflow here; the reference
        // accumulates in u128.
        run_prop("gemm_mod_staged large moduli", 25, |rng| {
            let m = [2_147_483_647u64, (1 << 31) + 11, 3_037_000_499][rng.gen_range(3) as usize];
            let b = 1 + rng.gen_range(2) as usize;
            let k = 3 + rng.gen_range(20) as usize;
            let n = 1 + rng.gen_range(6) as usize;
            // residues biased into the top of [0, m) to maximize the
            // accumulator (uniform draws would rarely stress the bound)
            let top = |rng: &mut Rng| (m - 1 - rng.gen_range(1 << 8)) as i64;
            let x = MatI::from_vec(b, k, (0..b * k).map(|_| top(rng)).collect());
            let w = MatI::from_vec(k, n, (0..k * n).map(|_| top(rng)).collect());
            let mut want = vec![0i64; b * n];
            for i in 0..b {
                for j in 0..n {
                    let mut acc = 0u128;
                    for kk in 0..k {
                        acc = (acc + x.at(i, kk) as u128 * w.at(kk, j) as u128) % m as u128;
                    }
                    want[i * n + j] = acc as i64;
                }
            }
            let staged = stage_weights_u32(&w, m);
            prop_assert_eq(
                gemm_mod_staged(&x, &staged, n, m).data,
                want,
                &format!("m={m} k={k} n={n}"),
            )
        });
    }

    #[test]
    #[should_panic(expected = "too large for the staged kernel")]
    fn gemm_mod_staged_rejects_oversized_modulus() {
        // 3037000500^2 > 2^63: even a single product overflows the exact
        // Barrett domain, so the kernel must refuse loudly
        let m = 3_037_000_500u64;
        let x = MatI::from_vec(1, 1, vec![1]);
        gemm_mod_staged(&x, &[1u32], 1, m);
    }

    #[test]
    fn zero_k_dimension() {
        let x = MatF::zeros(2, 0);
        let w = MatF::zeros(0, 3);
        let y = gemm_f32(&x, &w);
        assert_eq!(y.data, vec![0.0; 6]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_shapes_panic() {
        gemm_f32(&MatF::zeros(2, 3), &MatF::zeros(4, 2));
    }
}
