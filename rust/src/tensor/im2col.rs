//! im2col lowering: convolution as GEMM.
//!
//! This is how the analog accelerator executes conv layers — the paper's
//! MVM units only see matrices, so conv weights (HWIO) become a
//! (kh*kw*cin, cout) matrix and every output pixel becomes a patch row.
//! Layouts match `jax.lax.conv_general_dilated(NHWC, HWIO, NHWC)` with
//! SAME padding, which is what model.py trains with.

use super::{MatF, Nhwc};

/// Padding mode matching the jax string options we use.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Padding {
    Same,
    Valid,
}

/// Output spatial size for a conv dimension.
pub fn conv_out_dim(input: usize, kernel: usize, stride: usize, pad: Padding) -> usize {
    match pad {
        Padding::Same => input.div_ceil(stride),
        Padding::Valid => (input + 1).saturating_sub(kernel).div_ceil(stride),
    }
}

/// Lower an NHWC input to the im2col patch matrix:
/// rows = n * out_h * out_w, cols = kh * kw * c (column order matches the
/// HWIO weight reshape: kernel-row major, then kernel-col, then channel).
pub fn im2col(input: &Nhwc, kh: usize, kw: usize, stride: usize, pad: Padding) -> MatF {
    let out_h = conv_out_dim(input.h, kh, stride, pad);
    let out_w = conv_out_dim(input.w, kw, stride, pad);
    // SAME padding offsets (jax convention: total pad = max((out-1)*s + k - in, 0))
    let (pad_top, pad_left) = match pad {
        Padding::Valid => (0isize, 0isize),
        Padding::Same => {
            let pad_h = ((out_h - 1) * stride + kh).saturating_sub(input.h);
            let pad_w = ((out_w - 1) * stride + kw).saturating_sub(input.w);
            ((pad_h / 2) as isize, (pad_w / 2) as isize)
        }
    };
    let mut out = MatF::zeros(input.n * out_h * out_w, kh * kw * input.c);
    for b in 0..input.n {
        for oy in 0..out_h {
            for ox in 0..out_w {
                let row_idx = (b * out_h + oy) * out_w + ox;
                let row = out.row_mut(row_idx);
                for ky in 0..kh {
                    let iy = (oy * stride) as isize + ky as isize - pad_top;
                    if iy < 0 || iy >= input.h as isize {
                        continue; // zero padding
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride) as isize + kx as isize - pad_left;
                        if ix < 0 || ix >= input.w as isize {
                            continue;
                        }
                        let src = input.idx(b, iy as usize, ix as usize, 0);
                        let dst = (ky * kw + kx) * input.c;
                        row[dst..dst + input.c]
                            .copy_from_slice(&input.data[src..src + input.c]);
                    }
                }
            }
        }
    }
    out
}

/// Fold a (n*out_h*out_w, cout) GEMM result back into NHWC.
pub fn col2im(cols: &MatF, n: usize, out_h: usize, out_w: usize) -> Nhwc {
    assert_eq!(cols.rows, n * out_h * out_w);
    Nhwc::from_vec(n, out_h, out_w, cols.cols, cols.data.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gemm::gemm_f32;

    /// Direct (naive) conv reference for validating the im2col path.
    fn conv_direct(input: &Nhwc, w: &[f32], kh: usize, kw: usize, cout: usize, pad: Padding) -> Nhwc {
        let cin = input.c;
        let out_h = conv_out_dim(input.h, kh, 1, pad);
        let out_w = conv_out_dim(input.w, kw, 1, pad);
        let (pt, pl) = match pad {
            Padding::Valid => (0isize, 0isize),
            Padding::Same => (
                (((out_h - 1) + kh).saturating_sub(input.h) / 2) as isize,
                (((out_w - 1) + kw).saturating_sub(input.w) / 2) as isize,
            ),
        };
        let mut out = Nhwc::zeros(input.n, out_h, out_w, cout);
        for b in 0..input.n {
            for oy in 0..out_h {
                for ox in 0..out_w {
                    for co in 0..cout {
                        let mut acc = 0.0f32;
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let iy = oy as isize + ky as isize - pt;
                                let ix = ox as isize + kx as isize - pl;
                                if iy < 0 || ix < 0 || iy >= input.h as isize || ix >= input.w as isize {
                                    continue;
                                }
                                for ci in 0..cin {
                                    // HWIO: w[ky][kx][ci][co]
                                    let wv = w[((ky * kw + kx) * cin + ci) * cout + co];
                                    acc += input.at(b, iy as usize, ix as usize, ci) * wv;
                                }
                            }
                        }
                        out.set(b, oy, ox, co, acc);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv_out_dims() {
        assert_eq!(conv_out_dim(28, 3, 1, Padding::Same), 28);
        assert_eq!(conv_out_dim(28, 3, 1, Padding::Valid), 26);
        assert_eq!(conv_out_dim(28, 3, 2, Padding::Same), 14);
    }

    #[test]
    fn im2col_matches_direct_conv() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(11);
        for (hh, ww, cin, cout, kh, kw, pad) in [
            (5usize, 5usize, 1usize, 2usize, 3usize, 3usize, Padding::Same),
            (6, 4, 3, 4, 3, 3, Padding::Same),
            (7, 7, 2, 3, 3, 3, Padding::Valid),
            (4, 4, 1, 1, 1, 1, Padding::Same),
        ] {
            let input = Nhwc::from_vec(
                2, hh, ww, cin,
                (0..2 * hh * ww * cin).map(|_| rng.uniform_f32(-1.0, 1.0)).collect(),
            );
            let wdata: Vec<f32> =
                (0..kh * kw * cin * cout).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
            let patches = im2col(&input, kh, kw, 1, pad);
            let wmat = MatF::from_vec(kh * kw * cin, cout, wdata.clone());
            let y = gemm_f32(&patches, &wmat);
            let out_h = conv_out_dim(hh, kh, 1, pad);
            let out_w = conv_out_dim(ww, kw, 1, pad);
            let got = col2im(&y, 2, out_h, out_w);
            let want = conv_direct(&input, &wdata, kh, kw, cout, pad);
            assert_eq!(got.h, want.h);
            for (a, b) in got.data.iter().zip(&want.data) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b} (pad {pad:?})");
            }
        }
    }

    #[test]
    fn patch_column_order_is_hwio_compatible() {
        // single pixel input, 1x1 kernel: patch == input channels in order
        let input = Nhwc::from_vec(1, 1, 1, 3, vec![1.0, 2.0, 3.0]);
        let p = im2col(&input, 1, 1, 1, Padding::Same);
        assert_eq!(p.data, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn zero_padding_regions_are_zero() {
        let input = Nhwc::from_vec(1, 2, 2, 1, vec![1.0; 4]);
        let p = im2col(&input, 3, 3, 1, Padding::Same);
        // top-left output patch: kernel row 0 is fully in padding
        let row = p.row(0);
        assert_eq!(&row[0..3], &[0.0, 0.0, 0.0]);
    }
}
