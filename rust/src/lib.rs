//! # rns-analog
//!
//! A production-quality reproduction of *"Leveraging Residue Number System
//! for Designing High-Precision Analog Deep Neural Network Accelerators"*
//! (Demirkiran et al., 2023) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1** — the RNS modular-matmul hot path as a Pallas kernel
//!   (`python/compile/kernels/`), AOT-lowered to HLO text;
//! * **L2** — the Fig. 2 dataflow (quantize → residues → modular MVM →
//!   CRT → rescale) as a jitted JAX graph (`python/compile/model.py`);
//! * **L3** — this crate: the analog-accelerator simulator (fixed-point and
//!   RNS cores, noise + energy models), the RRNS fault-tolerant decoder,
//!   the serving coordinator, and the experiment harness that regenerates
//!   every table and figure in the paper.
//!
//! Python runs only at build time (`make artifacts`); the rust binary loads
//! the compiled HLO through PJRT and is self-contained at serving time.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod analog;
pub mod bench;
pub mod coordinator;
pub mod exp;
pub mod net;
pub mod nn;
pub mod quant;
pub mod rns;
pub mod runtime;
pub mod store;
pub mod tensor;
pub mod util;
