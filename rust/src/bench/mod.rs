//! Micro/end-to-end benchmark harness (the image vendors no `criterion`).
//!
//! `Bencher` auto-calibrates the iteration count to a target measurement
//! time, reports median / p95 / mean ns per iteration, and (optionally)
//! derived throughput in user units.  Used by `rust/benches/bench_main.rs`
//! (`cargo bench`, harness = false) and the §Perf optimization passes.

use std::time::{Duration, Instant};

use crate::util::stats::Percentiles;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub mean_ns: f64,
    /// Optional throughput: (value per iteration, unit).
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        let tp = match self.throughput {
            Some((per_iter, unit)) => {
                let rate = per_iter / (self.median_ns * 1e-9);
                format!("  {}", crate::util::format_si(rate, unit))
            }
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} {:>12} {:>12}  x{}{}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.mean_ns),
            self.iters,
            tp
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bencher {
    /// Total sampling budget per benchmark.
    pub budget: Duration,
    /// Number of timed samples (each sample runs a calibrated batch).
    pub samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { budget: Duration::from_millis(600), samples: 20, results: Vec::new() }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { budget: Duration::from_millis(150), samples: 8, results: Vec::new() }
    }

    /// Benchmark `f`, preventing dead-code elimination via the returned
    /// value's drop.  Returns the recorded result.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_throughput(name, None, &mut f)
    }

    /// Benchmark with a throughput annotation (`per_iter` user units per
    /// call, e.g. MACs or samples).
    pub fn bench_with_rate<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        per_iter: f64,
        unit: &'static str,
        mut f: F,
    ) -> &BenchResult {
        self.bench_throughput(name, Some((per_iter, unit)), &mut f)
    }

    fn bench_throughput<T>(
        &mut self,
        name: &str,
        throughput: Option<(f64, &'static str)>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        // warmup + calibration: how many iters fit in budget/samples?
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let per_sample = self.budget.div_f64(self.samples as f64);
        let batch = (per_sample.as_nanos() / once.as_nanos()).clamp(1, 1_000_000) as u64;

        let mut samples = Percentiles::new();
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            let start = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            let elapsed = start.elapsed().as_nanos() as f64 / batch as f64;
            samples.add(elapsed);
            total_iters += batch;
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            median_ns: samples.median(),
            p95_ns: samples.percentile(95.0),
            mean_ns: {
                let mut s = 0.0;
                for q in [10.0, 30.0, 50.0, 70.0, 90.0] {
                    s += samples.percentile(q);
                }
                s / 5.0
            },
            throughput,
        };
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn header() -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  iters",
            "benchmark", "median", "p95", "mean"
        )
    }

    pub fn report(&self) -> String {
        let mut out = vec![Self::header(), "-".repeat(96)];
        out.extend(self.results.iter().map(|r| r.report_line()));
        out.join("\n")
    }

    /// Machine-readable results (hand-rolled JSON — no serde offline).
    /// Consumed by the perf-trajectory tooling: `cargo bench` writes this
    /// to `BENCH_gemm.json` at the repo root (see benches/bench_main.rs).
    pub fn to_json(&self, quick: bool) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v:.3}")
            } else {
                "null".to_string()
            }
        }
        let mut entries = Vec::with_capacity(self.results.len());
        for r in &self.results {
            let mut fields = vec![
                format!("\"name\": \"{}\"", esc(&r.name)),
                format!("\"median_ns\": {}", num(r.median_ns)),
                format!("\"p95_ns\": {}", num(r.p95_ns)),
                format!("\"mean_ns\": {}", num(r.mean_ns)),
                format!("\"iters\": {}", r.iters),
            ];
            if let Some((per_iter, unit)) = r.throughput {
                let rate = per_iter / (r.median_ns * 1e-9);
                fields.push(format!("\"unit\": \"{}\"", esc(unit)));
                fields.push(format!("\"rate\": {}", num(rate)));
            }
            entries.push(format!("    {{{}}}", fields.join(", ")));
        }
        format!(
            "{{\n  \"schema\": \"rns-analog-bench-v1\",\n  \"quick\": {},\n  \"benches\": [\n{}\n  ]\n}}\n",
            quick,
            entries.join(",\n")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_plausible() {
        let mut b = Bencher::quick();
        let r = b.bench("spin", || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(std::hint::black_box(i));
            }
            s
        });
        assert!(r.median_ns > 10.0, "1000 adds can't be {} ns", r.median_ns);
        assert!(r.median_ns < 1e7);
        assert!(r.iters > 0);
    }

    #[test]
    fn report_contains_all_benches() {
        let mut b = Bencher::quick();
        b.bench("a", || 1 + 1);
        b.bench_with_rate("b", 100.0, "Op/s", || 2 + 2);
        let rep = b.report();
        assert!(rep.contains('a') && rep.contains('b'));
        assert!(rep.contains("Op/s"));
    }

    #[test]
    fn json_has_all_benches_and_rates() {
        let mut b = Bencher::quick();
        b.bench("plain \"quoted\"", || 1 + 1);
        b.bench_with_rate("rated", 1e6, "MAC/s", || 2 + 2);
        let json = b.to_json(true);
        assert!(json.contains("\"schema\": \"rns-analog-bench-v1\""));
        assert!(json.contains("\"quick\": true"));
        assert!(json.contains("plain \\\"quoted\\\""));
        assert!(json.contains("\"unit\": \"MAC/s\""));
        assert!(json.contains("\"rate\": "));
        // balanced braces/brackets (cheap well-formedness check)
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.000 s");
    }
}
