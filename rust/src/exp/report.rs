//! Plain-text table rendering for the experiment regenerators, plus the
//! `results/` writer that EXPERIMENTS.md references.

/// One experiment report: a titled, aligned text table with notes.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub title: String,
    pub notes: Vec<String>,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Report { title: title.to_string(), ..Default::default() }
    }

    pub fn note(&mut self, note: impl Into<String>) -> &mut Self {
        self.notes.push(note.into());
        self
    }

    pub fn header(&mut self, cols: &[&str]) -> &mut Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| display_width(h)).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(display_width(cell));
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{}{}", c, " ".repeat(widths[i] - display_width(c))))
                .collect::<Vec<_>>()
                .join("  ")
                .trim_end()
                .to_string()
        };
        let mut out = vec![format!("== {} ==", self.title)];
        out.extend(self.notes.iter().map(|n| format!("   {n}")));
        out.push(String::new());
        out.push(line(&self.header));
        out.push("-".repeat(widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1))));
        out.extend(self.rows.iter().map(|r| line(r)));
        out.join("\n")
    }

    /// Write the rendered report under `results/<id>.txt`.
    pub fn save(&self, results_dir: &str, id: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(results_dir)?;
        let path = format!("{results_dir}/{id}.txt");
        std::fs::write(&path, self.render() + "\n")?;
        Ok(path)
    }
}

/// Unicode-naive display width good enough for ASCII + the sparkline
/// glyphs we emit (each counted as one column).
fn display_width(s: &str) -> usize {
    s.chars().count()
}

/// Format helpers used across the figures.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f4(v: f64) -> String {
    format!("{v:.4}")
}

pub fn sci(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else {
        format!("{v:.2e}")
    }
}

pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut r = Report::new("demo");
        r.note("a note");
        r.header(&["col", "value"]);
        r.row(vec!["x".into(), "1".into()]);
        r.row(vec!["longer".into(), "2".into()]);
        let text = r.render();
        assert!(text.contains("== demo =="));
        assert!(text.contains("a note"));
        let lines: Vec<&str> = text.lines().collect();
        // header and rows align on the second column
        let hpos = lines[3].find("value").unwrap();
        let xpos = lines[5].find('1').unwrap();
        assert_eq!(hpos, xpos);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut r = Report::new("x");
        r.header(&["a", "b"]);
        r.row(vec!["only-one".into()]);
    }

    #[test]
    fn save_writes_file() {
        let dir = std::env::temp_dir().join("rns_results_test");
        let dir = dir.to_str().unwrap();
        let mut r = Report::new("t");
        r.header(&["a"]);
        r.row(vec!["1".into()]);
        let path = r.save(dir, "unit").unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("== t =="));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.234), "1.23");
        assert_eq!(pct(0.987), "98.7%");
        assert_eq!(sci(0.0), "0");
        assert!(sci(3.4e-8).contains("e-8"));
    }
}
