//! Table I regenerator: RNS-based vs regular fixed-point analog core
//! configurations for b = 4..8, h = 128.

use crate::exp::report::Report;
use crate::rns::moduli::{required_output_bits, select_moduli};

pub struct Table1Row {
    pub bits: u32,
    pub moduli: Vec<u64>,
    pub big_m: u128,
    pub b_out: u32,
    pub lost_bits: u32,
}

pub fn compute(h: usize) -> Vec<Table1Row> {
    (4..=8)
        .map(|bits| {
            let moduli = select_moduli(bits, h).expect("selection");
            let big_m: u128 = moduli.iter().map(|&m| m as u128).product();
            let b_out = required_output_bits(bits, bits, h);
            Table1Row { bits, moduli, big_m, b_out, lost_bits: b_out - bits }
        })
        .collect()
}

pub fn run(h: usize) -> Report {
    let mut rep = Report::new(&format!("Table I — RNS vs fixed-point core configurations (h = {h})"));
    rep.note("RNS: b_DAC = b_ADC = ceil(log2 m_i) = b; fixed-point: b_ADC = b, b_out from Eq. (4)");
    rep.header(&[
        "b_in,b_w",
        "RNS moduli set",
        "RNS range M",
        "log2(M)",
        "RNS b_ADC",
        "FXP b_out",
        "FXP b_ADC",
        "FXP lost bits",
    ]);
    for r in compute(h) {
        rep.row(vec![
            r.bits.to_string(),
            format!("{{{}}}", r.moduli.iter().map(|m| m.to_string()).collect::<Vec<_>>().join(", ")),
            r.big_m.to_string(),
            format!("{:.1}", (r.big_m as f64).log2()),
            r.bits.to_string(),
            r.b_out.to_string(),
            r.bits.to_string(),
            r.lost_bits.to_string(),
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::paper_table1;

    #[test]
    fn reproduces_paper_rows() {
        let rows = compute(128);
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert_eq!(r.moduli.as_slice(), paper_table1(r.bits).unwrap());
        }
        // lost-bit column from the paper: 10, 11, 12, 13, 14
        let lost: Vec<u32> = rows.iter().map(|r| r.lost_bits).collect();
        assert_eq!(lost, vec![10, 11, 12, 13, 14]);
    }

    #[test]
    fn rns_range_covers_bout() {
        for r in compute(128) {
            assert!(r.big_m >= (1u128 << r.b_out), "b={}", r.bits);
        }
    }

    #[test]
    fn renders() {
        let rep = run(128);
        let text = rep.render();
        assert!(text.contains("{63, 62, 61, 59}"));
        assert!(text.contains("Table I"));
    }
}
