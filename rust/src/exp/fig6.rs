//! Fig. 6 regenerator: end-to-end DNN accuracy under analog noise for the
//! RRNS-protected RNS core — the ResNet50/BERT-large stand-ins, sweeping
//! the single-residue error probability p, the redundancy n-k, and the
//! number of attempts R.
//!
//! Reproduces the paper's observations: more redundancy and more attempts
//! hold accuracy at higher p, and the tolerable p_err is orders of
//! magnitude above the naive 1/#outputs estimate because DNNs absorb rare
//! large errors.

use crate::analog::{Fp32Backend, NoiseModel, RnsCore, RnsCoreConfig};
use crate::exp::report::{pct, sci, Report};
use crate::nn::dataset::{dataset_for_model, load_eval_set};
use crate::nn::models::{accuracy, load_model};

pub struct Fig6Config {
    pub artifacts_dir: String,
    pub models: Vec<String>,
    pub bits: u32,
    pub h: usize,
    pub redundancies: Vec<usize>,
    pub attempts: Vec<u32>,
    pub ps: Vec<f64>,
    pub samples: usize,
    pub seed: u64,
}

impl Fig6Config {
    pub fn new(artifacts_dir: &str) -> Self {
        Fig6Config {
            artifacts_dir: artifacts_dir.to_string(),
            models: vec!["resnet".into(), "bert".into()],
            bits: 8,
            h: 128,
            redundancies: vec![1, 2],
            attempts: vec![1, 3],
            ps: vec![1e-3, 1e-2, 3e-2, 1e-1],
            samples: 96,
            seed: 23,
        }
    }
}

pub struct Fig6Cell {
    pub model: String,
    pub redundancy: usize,
    pub attempts: u32,
    pub p: f64,
    pub norm_accuracy: f64,
    pub detections: u64,
    pub exhausted: u64,
}

pub fn compute(cfg: &Fig6Config) -> Result<Vec<Fig6Cell>, String> {
    let mut out = Vec::new();
    for model_name in &cfg.models {
        let model = load_model(&cfg.artifacts_dir, model_name)?;
        let eval =
            load_eval_set(&cfg.artifacts_dir, dataset_for_model(model_name))?.take(cfg.samples);
        let fp32 = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut Fp32Backend);
        for &red in &cfg.redundancies {
            for &att in &cfg.attempts {
                for &p in &cfg.ps {
                    let mut core = RnsCore::new(
                        RnsCoreConfig::for_bits(cfg.bits, cfg.h)
                            .with_noise(NoiseModel::ResidueFlip { p })
                            .with_rrns(red, att)
                            .with_seed(cfg.seed),
                    )?;
                    let acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut core);
                    out.push(Fig6Cell {
                        model: model_name.clone(),
                        redundancy: red,
                        attempts: att,
                        p,
                        norm_accuracy: acc / fp32.max(1e-9),
                        detections: core.stats.detections,
                        exhausted: core.stats.exhausted,
                    });
                }
            }
        }
    }
    Ok(out)
}

pub fn run(cfg: &Fig6Config) -> Result<Report, String> {
    let cells = compute(cfg)?;
    let mut rep = Report::new(&format!(
        "Fig. 6 — accuracy under residue noise with RRNS (b = {}, {} samples/model)",
        cfg.bits, cfg.samples
    ));
    rep.note("accuracy normalized to FP32; detections = Case-2 events (each triggers a recompute attempt)");
    let mut header = vec!["model".to_string(), "n-k".to_string(), "R".to_string()];
    header.extend(cfg.ps.iter().map(|p| format!("p={}", sci(*p))));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    rep.header(&header_refs);
    for model in &cfg.models {
        for &red in &cfg.redundancies {
            for &att in &cfg.attempts {
                let mut row = vec![model.clone(), red.to_string(), att.to_string()];
                for &p in &cfg.ps {
                    let c = cells
                        .iter()
                        .find(|c| {
                            &c.model == model && c.redundancy == red && c.attempts == att && c.p == p
                        })
                        .expect("cell");
                    row.push(pct(c.norm_accuracy));
                }
                rep.row(row);
            }
        }
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn have_artifacts() -> bool {
        std::path::Path::new(&format!("{}/models/resnet.rt", artifacts_dir())).exists()
    }

    #[test]
    fn redundancy_preserves_accuracy_under_noise() {
        if !have_artifacts() {
            return;
        }
        let cfg = Fig6Config {
            models: vec!["resnet".into()],
            redundancies: vec![1, 2],
            attempts: vec![3],
            ps: vec![1e-2],
            samples: 48,
            ..Fig6Config::new(&artifacts_dir())
        };
        let cells = compute(&cfg).unwrap();
        let weak = cells.iter().find(|c| c.redundancy == 1).unwrap();
        let strong = cells.iter().find(|c| c.redundancy == 2).unwrap();
        assert!(
            strong.norm_accuracy >= weak.norm_accuracy - 0.05,
            "n-k=2 ({}) should hold at least as well as n-k=1 ({})",
            strong.norm_accuracy,
            weak.norm_accuracy
        );
        assert!(strong.norm_accuracy > 0.95, "n-k=2, R=3 at p=1e-2 should stay near fp32");
        assert!(strong.detections > 0);
    }
}
