//! Energy-vs-sparsity sweep: conversion-avoiding sparse capture on the
//! RNS core across ReLU-style activation sparsity levels.
//!
//! The paper's energy win comes from low-ENOB converters (Fig. 7); sparse
//! capture stacks a second, data-dependent win on top: zero activations
//! need no DAC, and output rows whose dot product is structurally zero
//! need no ADC capture nor CRT decode.  This sweep drives the synthetic
//! MLP at controlled input sparsity and reports energy-per-inference for
//! dense vs sparse capture, plus the skipped-conversion counts.
//!
//! With `NoiseModel::None` the two capture modes are bit-identical, so
//! the sweep also doubles as an end-to-end equivalence check.

use crate::analog::{EnergyMeter, RnsCore, RnsCoreConfig};
use crate::exp::report::{f2, Report};
use crate::nn::models::{Batch, Mlp, Model};
use crate::tensor::Nhwc;
use crate::util::format_si;
use crate::util::rng::Rng;

pub struct SparsityConfig {
    /// Samples per forward batch.
    pub batch: usize,
    /// Converter ENOB (moduli set is chosen for these bits).
    pub bits: u32,
    /// Dot-product length the moduli must cover.
    pub h: usize,
    /// Input sparsity levels to sweep (fraction of zeros, 0.0 ..= 1.0).
    pub levels: Vec<f64>,
    pub seed: u64,
}

impl Default for SparsityConfig {
    fn default() -> Self {
        SparsityConfig {
            batch: 8,
            bits: 6,
            h: 128,
            levels: vec![0.0, 0.25, 0.5, 0.75, 0.9, 1.0],
            seed: 7,
        }
    }
}

pub struct SparsityRow {
    pub level: f64,
    /// dense-capture conversions for the whole batch
    pub dense_dac: u64,
    pub dense_adc: u64,
    /// sparse-capture conversions + skips for the whole batch
    pub sparse_dac: u64,
    pub sparse_adc: u64,
    pub skipped_dac: u64,
    pub skipped_adc: u64,
    /// energy per inference (J), dense vs sparse capture
    pub dense_j_per_inf: f64,
    pub sparse_j_per_inf: f64,
    /// outputs bit-identical between the two modes (must hold: no noise)
    pub identical: bool,
}

/// Batch at a target sparsity `s`: a fraction `s` of the samples is fully
/// zero (so whole output rows become skippable) and the remaining samples
/// have each pixel zeroed with probability `s` (element-level DAC skips).
fn sparse_batch(cfg: &SparsityConfig, s: f64) -> Batch {
    let mut rng = Rng::seed_from(cfg.seed ^ (s * 1000.0) as u64);
    let px = 28 * 28;
    let zero_samples = (s * cfg.batch as f64).round() as usize;
    let mut data = Vec::with_capacity(cfg.batch * px);
    for i in 0..cfg.batch {
        for _ in 0..px {
            if i < zero_samples || rng.bernoulli(s) {
                data.push(0.0);
            } else {
                data.push(rng.uniform_f32(0.0, 1.0));
            }
        }
    }
    Batch::Images(Nhwc::from_vec(cfg.batch, 28, 28, 1, data))
}

pub fn compute(cfg: &SparsityConfig) -> Vec<SparsityRow> {
    let model = Mlp::synthetic(cfg.seed);
    let base = RnsCoreConfig::for_bits(cfg.bits, cfg.h);
    let mut dense = RnsCore::new(base.clone()).expect("dense core");
    let mut sparse = RnsCore::new(base.with_sparse_capture(true)).expect("sparse core");
    // weight programming is charged once per core at prepare time; warm
    // both up front so per-level deltas measure activations only
    model.warm(&mut dense);
    model.warm(&mut sparse);
    let delta = |before: &EnergyMeter, after: &EnergyMeter| EnergyMeter {
        dac_conversions: after.dac_conversions - before.dac_conversions,
        adc_conversions: after.adc_conversions - before.adc_conversions,
        skipped_dac: after.skipped_dac - before.skipped_dac,
        skipped_adc: after.skipped_adc - before.skipped_adc,
        dac_joules: after.dac_joules - before.dac_joules,
        adc_joules: after.adc_joules - before.adc_joules,
        digital_joules: after.digital_joules - before.digital_joules,
    };
    cfg.levels
        .iter()
        .map(|&level| {
            let batch = sparse_batch(cfg, level);
            let d0 = dense.meter;
            let yd = model.forward(&batch, &mut dense);
            let dm = delta(&d0, &dense.meter);
            let s0 = sparse.meter;
            let ys = model.forward(&batch, &mut sparse);
            let sm = delta(&s0, &sparse.meter);
            SparsityRow {
                level,
                dense_dac: dm.dac_conversions,
                dense_adc: dm.adc_conversions,
                sparse_dac: sm.dac_conversions,
                sparse_adc: sm.adc_conversions,
                skipped_dac: sm.skipped_dac,
                skipped_adc: sm.skipped_adc,
                dense_j_per_inf: dm.total_joules() / cfg.batch as f64,
                sparse_j_per_inf: sm.total_joules() / cfg.batch as f64,
                identical: yd.data == ys.data,
            }
        })
        .collect()
}

pub fn run(cfg: &SparsityConfig) -> Report {
    let rows = compute(cfg);
    let mut rep = Report::new(&format!(
        "Energy vs activation sparsity — dense vs sparse capture, synthetic MLP, b = {}, batch = {}",
        cfg.bits, cfg.batch
    ));
    rep.note("sparse capture skips DAC for zero activations and ADC+CRT for structurally-zero output rows");
    rep.note("NoiseModel::None: outputs are bit-identical between capture modes at every level");
    rep.header(&[
        "sparsity",
        "dense dac/adc",
        "sparse dac/adc",
        "skipped dac/adc",
        "dense E/inf",
        "sparse E/inf",
        "saving",
        "identical",
    ]);
    for r in &rows {
        let saving = if r.dense_j_per_inf > 0.0 {
            100.0 * (1.0 - r.sparse_j_per_inf / r.dense_j_per_inf)
        } else {
            0.0
        };
        rep.row(vec![
            f2(r.level),
            format!("{}/{}", r.dense_dac, r.dense_adc),
            format!("{}/{}", r.sparse_dac, r.sparse_adc),
            format!("{}/{}", r.skipped_dac, r.skipped_adc),
            format_si(r.dense_j_per_inf, "J"),
            format_si(r.sparse_j_per_inf, "J"),
            format!("{saving:.1}%"),
            r.identical.to_string(),
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SparsityConfig {
        SparsityConfig { batch: 3, levels: vec![0.0, 0.5, 1.0], ..Default::default() }
    }

    #[test]
    fn sweep_is_bit_identical_and_monotone_in_conversions() {
        let rows = compute(&small());
        for r in &rows {
            assert!(r.identical, "level {}: outputs diverged under NoiseModel::None", r.level);
            assert!(r.sparse_dac <= r.dense_dac, "level {}", r.level);
            assert!(r.sparse_adc <= r.dense_adc, "level {}", r.level);
            // skips + performed conversions must account for the dense work
            assert_eq!(r.sparse_dac + r.skipped_dac, r.dense_dac, "level {}", r.level);
            assert!(r.sparse_j_per_inf <= r.dense_j_per_inf, "level {}", r.level);
        }
    }

    #[test]
    fn endpoints_behave() {
        let rows = compute(&small());
        // even a dense input produces some DAC skips (hidden ReLU zeros),
        // but an all-zero input must skip strictly more of both kinds: the
        // whole first layer's rows become structurally zero
        let dense_input = &rows[0];
        let all_zero = rows.last().unwrap();
        assert!(all_zero.skipped_dac > dense_input.skipped_dac);
        assert!(all_zero.skipped_adc > dense_input.skipped_adc);
        assert!(all_zero.skipped_adc > 0);
        assert!(all_zero.sparse_j_per_inf < all_zero.dense_j_per_inf);
    }
}
