//! Fig. 3 regenerator: distribution of the absolute dot-product error
//! (FP32 ground truth) for the regular fixed-point core vs the RNS core,
//! b = 4..8, h = 128, over randomly generated vector pairs.
//!
//! The paper reports a 9–15x larger error for the fixed-point core at the
//! same input/weight precision; the harness prints both distributions and
//! the measured ratio.

use crate::analog::{FixedPointCore, NoiseModel, RnsCore, RnsCoreConfig};
use crate::exp::report::{sci, Report};
use crate::nn::dataset::random_vector_pair;
use crate::tensor::MatF;
use crate::util::rng::Rng;
use crate::util::stats::{Histogram, Summary};

pub struct Fig3Config {
    pub h: usize,
    pub pairs: usize,
    pub bits: Vec<u32>,
    pub seed: u64,
}

impl Default for Fig3Config {
    fn default() -> Self {
        Fig3Config { h: 128, pairs: 10_000, bits: vec![4, 5, 6, 7, 8], seed: 7 }
    }
}

pub struct Fig3Row {
    pub bits: u32,
    pub fxp_mean: f64,
    pub fxp_p99: f64,
    pub rns_mean: f64,
    pub rns_p99: f64,
    pub ratio: f64,
    pub fxp_hist: Histogram,
    pub rns_hist: Histogram,
}

pub fn compute(cfg: &Fig3Config) -> Vec<Fig3Row> {
    let mut rows = Vec::new();
    for &bits in &cfg.bits {
        let mut rng = Rng::seed_from(cfg.seed ^ bits as u64);
        let mut fxp_core = FixedPointCore::new(bits, cfg.h, NoiseModel::None, 0);
        let mut rns_core = RnsCore::new(RnsCoreConfig::for_bits(bits, cfg.h)).expect("core");
        let mut fxp_sum = Summary::new();
        let mut rns_sum = Summary::new();
        let mut fxp_p = crate::util::stats::Percentiles::new();
        let mut rns_p = crate::util::stats::Percentiles::new();
        // batch the pairs for speed: 64 dot products per GEMM call
        let batch = 64usize;
        let mut fxp_errs = Vec::with_capacity(cfg.pairs);
        let mut rns_errs = Vec::with_capacity(cfg.pairs);
        let mut done = 0;
        while done < cfg.pairs {
            let nb = batch.min(cfg.pairs - done);
            let mut xs = MatF::zeros(nb, cfg.h);
            let mut ws = MatF::zeros(cfg.h, nb);
            for i in 0..nb {
                let (a, b) = random_vector_pair(&mut rng, cfg.h);
                xs.row_mut(i).copy_from_slice(&a);
                for (r, &v) in b.iter().enumerate() {
                    ws.set(r, i, v);
                }
            }
            let want = crate::tensor::gemm::gemm_f32(&xs, &ws);
            let got_f = fxp_core.gemm_quantized(&xs, &ws);
            let got_r = rns_core.gemm_quantized(&xs, &ws);
            for i in 0..nb {
                // diagonal: pair i against its own partner
                let e_f = (got_f.at(i, i) - want.at(i, i)).abs() as f64;
                let e_r = (got_r.at(i, i) - want.at(i, i)).abs() as f64;
                fxp_sum.add(e_f);
                rns_sum.add(e_r);
                fxp_p.add(e_f);
                rns_p.add(e_r);
                fxp_errs.push(e_f);
                rns_errs.push(e_r);
            }
            done += nb;
        }
        let hist_hi = fxp_p.percentile(99.5).max(1e-9);
        let mut fxp_hist = Histogram::new(0.0, hist_hi, 40);
        let mut rns_hist = Histogram::new(0.0, hist_hi, 40);
        for &e in &fxp_errs {
            fxp_hist.add(e);
        }
        for &e in &rns_errs {
            rns_hist.add(e);
        }
        rows.push(Fig3Row {
            bits,
            fxp_mean: fxp_sum.mean(),
            fxp_p99: fxp_p.percentile(99.0),
            rns_mean: rns_sum.mean(),
            rns_p99: rns_p.percentile(99.0),
            ratio: fxp_sum.mean() / rns_sum.mean().max(1e-12),
            fxp_hist,
            rns_hist,
        });
    }
    rows
}

pub fn run(cfg: &Fig3Config) -> Report {
    let rows = compute(cfg);
    let mut rep = Report::new(&format!(
        "Fig. 3 — dot-product |error| vs FP32, {} random pairs, h = {}",
        cfg.pairs, cfg.h
    ));
    rep.note("fixed-point core keeps only the b MSBs of b_out (Table I); RNS core loses nothing beyond quantization");
    rep.note("paper: fixed-point error is 9-15x larger than RNS at the same precision");
    rep.header(&["b", "fxp mean", "fxp p99", "rns mean", "rns p99", "fxp/rns", "fxp |err| dist", "rns |err| dist"]);
    for r in &rows {
        rep.row(vec![
            r.bits.to_string(),
            sci(r.fxp_mean),
            sci(r.fxp_p99),
            sci(r.rns_mean),
            sci(r.rns_p99),
            format!("{:.1}x", r.ratio),
            r.fxp_hist.sparkline(),
            r.rns_hist.sparkline(),
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_point_error_dominates() {
        let cfg = Fig3Config { pairs: 300, bits: vec![4, 6, 8], ..Default::default() };
        let rows = compute(&cfg);
        for r in &rows {
            assert!(
                r.ratio > 3.0,
                "b={}: fxp/rns ratio {:.2} should be >> 1",
                r.bits,
                r.ratio
            );
            assert!(r.rns_mean < r.fxp_mean);
        }
    }

    #[test]
    fn error_shrinks_with_bits() {
        let cfg = Fig3Config { pairs: 200, bits: vec![4, 8], ..Default::default() };
        let rows = compute(&cfg);
        assert!(rows[1].rns_mean < rows[0].rns_mean);
        assert!(rows[1].fxp_mean < rows[0].fxp_mean);
    }
}
