//! Experiment harness: one regenerator per table/figure in the paper's
//! evaluation (see DESIGN.md §6 for the index).  Each returns a `Report`
//! that the CLI prints and saves under `results/`.

pub mod ablation;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod report;
pub mod sparsity;
pub mod table1;

pub use report::Report;
