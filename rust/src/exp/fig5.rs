//! Fig. 5 regenerator: output error probability p_err as a function of the
//! single-residue error probability p, for varying redundancy (n-k) and
//! number of attempts R.
//!
//! Case probabilities come from the Monte-Carlo estimator over the real
//! voting decoder (the paper's own equations are not reprinted there);
//! p_err(R) uses the corrected Eq. (5) geometric series, and the R→∞ limit
//! p_u/(p_u+p_c) matches the limit stated in the paper.

use crate::exp::report::{sci, Report};
use crate::rns::fault_model::{estimate_case_probs, p_correctable_analytic};
use crate::rns::moduli::{extend_moduli, paper_table1};
use crate::rns::rrns::RrnsCode;

pub struct Fig5Config {
    pub bits: u32,
    pub redundancies: Vec<usize>,
    pub attempts: Vec<u32>,
    pub ps: Vec<f64>,
    pub trials: u32,
    pub seed: u64,
}

impl Default for Fig5Config {
    fn default() -> Self {
        Fig5Config {
            bits: 8,
            redundancies: vec![1, 2, 3],
            attempts: vec![1, 2, 3],
            ps: vec![1e-4, 1e-3, 1e-2, 3e-2, 1e-1, 3e-1],
            trials: 40_000,
            seed: 17,
        }
    }
}

pub struct Fig5Row {
    pub redundancy: usize,
    pub p: f64,
    pub p_c: f64,
    pub p_c_analytic: f64,
    /// Simulated fraction of trials with <= t injected faults (shared
    /// `rns::inject` harness) — estimates the same binomial mass as
    /// `p_c_analytic`, validating the injection against the closed form.
    pub p_le_t_sim: f64,
    pub p_err_by_attempts: Vec<(u32, f64)>,
    pub p_err_limit: f64,
}

pub fn compute(cfg: &Fig5Config) -> Vec<Fig5Row> {
    let base = paper_table1(cfg.bits).expect("table1 bits").to_vec();
    let mut rows = Vec::new();
    for &red in &cfg.redundancies {
        let all = extend_moduli(&base, red).expect("extend");
        let code = RrnsCode::new(&all, base.len()).expect("code");
        for &p in &cfg.ps {
            let cp = estimate_case_probs(&code, p, cfg.trials, cfg.seed);
            rows.push(Fig5Row {
                redundancy: red,
                p,
                p_c: cp.p_c,
                p_c_analytic: p_correctable_analytic(code.n(), code.k, p),
                p_le_t_sim: cp.p_le_t,
                p_err_by_attempts: cfg.attempts.iter().map(|&r| (r, cp.p_err(r))).collect(),
                p_err_limit: cp.p_err_limit(),
            });
        }
    }
    rows
}

pub fn run(cfg: &Fig5Config) -> Report {
    let rows = compute(cfg);
    let mut rep = Report::new(&format!(
        "Fig. 5 — output error probability p_err vs residue error probability p (b = {}, {} MC trials)",
        cfg.bits, cfg.trials
    ));
    rep.note("p_err(R) = 1 - p_c * sum_{j=0..R-1} p_d^j (corrected Eq. 5); limit = p_u/(p_u+p_c)");
    rep.note("P(<=t) sim: injected-fault mass from rns::inject — must track the analytic column");
    let mut header = vec![
        "n-k".to_string(),
        "p".to_string(),
        "p_c (MC)".to_string(),
        "p_c (>=, analytic)".to_string(),
        "P(<=t) sim".to_string(),
    ];
    header.extend(cfg.attempts.iter().map(|r| format!("p_err R={r}")));
    header.push("p_err R→∞".to_string());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    rep.header(&header_refs);
    for row in &rows {
        let mut cells = vec![
            row.redundancy.to_string(),
            sci(row.p),
            format!("{:.4}", row.p_c),
            format!("{:.4}", row.p_c_analytic),
            format!("{:.4}", row.p_le_t_sim),
        ];
        cells.extend(row.p_err_by_attempts.iter().map(|(_, pe)| sci(*pe)));
        cells.push(sci(row.p_err_limit));
        rep.row(cells);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> Fig5Config {
        Fig5Config {
            redundancies: vec![1, 3],
            attempts: vec![1, 3],
            ps: vec![1e-2, 1e-1],
            trials: 6_000,
            ..Default::default()
        }
    }

    #[test]
    fn perr_monotone_in_attempts_and_redundancy() {
        let rows = compute(&quick_cfg());
        for r in &rows {
            let pe1 = r.p_err_by_attempts[0].1;
            let pe3 = r.p_err_by_attempts[1].1;
            assert!(pe3 <= pe1 + 1e-9, "n-k={} p={}", r.redundancy, r.p);
        }
        // more redundancy helps at the same p and R
        let r1 = rows.iter().find(|r| r.redundancy == 1 && r.p == 1e-2).unwrap();
        let r3 = rows.iter().find(|r| r.redundancy == 3 && r.p == 1e-2).unwrap();
        assert!(r3.p_err_by_attempts[1].1 <= r1.p_err_by_attempts[1].1);
    }

    #[test]
    fn simulated_injection_tracks_analytic_correctable_mass() {
        // the fig's injected-fault column must agree with the closed-form
        // binomial bound, and the decoder can only do better than it
        let rows = compute(&quick_cfg());
        for r in &rows {
            assert!(
                (r.p_le_t_sim - r.p_c_analytic).abs() < 0.03,
                "n-k={} p={}: sim {} vs analytic {}",
                r.redundancy,
                r.p,
                r.p_le_t_sim,
                r.p_c_analytic
            );
            assert!(r.p_c >= r.p_le_t_sim, "n-k={} p={}", r.redundancy, r.p);
        }
    }

    #[test]
    fn perr_tends_to_one_at_high_p() {
        let cfg = Fig5Config {
            redundancies: vec![2],
            attempts: vec![1],
            ps: vec![0.9],
            trials: 4_000,
            ..Default::default()
        };
        let rows = compute(&cfg);
        assert!(rows[0].p_err_by_attempts[0].1 > 0.9);
    }
}
