//! Fig. 4 regenerator: accuracy of the regular fixed-point core vs the
//! RNS-based core across the benchmark model zoo (the MLPerf stand-ins),
//! normalized to FP32, for b = 4..8.
//!
//! Headline to reproduce: the RNS core reaches >= 99% of FP32 accuracy for
//! every network at b = 6, while the fixed-point core collapses.

use crate::analog::{FixedPointCore, Fp32Backend, NoiseModel, RnsCore, RnsCoreConfig};
use crate::exp::report::{pct, Report};
use crate::nn::dataset::{dataset_for_model, load_eval_set};
use crate::nn::models::{accuracy, load_model, ZOO};

pub struct Fig4Config {
    pub artifacts_dir: String,
    pub models: Vec<String>,
    pub bits: Vec<u32>,
    pub h: usize,
    pub samples: usize,
}

impl Fig4Config {
    pub fn new(artifacts_dir: &str) -> Self {
        Fig4Config {
            artifacts_dir: artifacts_dir.to_string(),
            models: ZOO.iter().map(|s| s.to_string()).collect(),
            bits: vec![4, 5, 6, 7, 8],
            h: 128,
            samples: 256,
        }
    }
}

pub struct Fig4Cell {
    pub model: String,
    pub bits: u32,
    pub fxp_norm: f64,
    pub rns_norm: f64,
    pub fp32_accuracy: f64,
}

pub fn compute(cfg: &Fig4Config) -> Result<Vec<Fig4Cell>, String> {
    let mut out = Vec::new();
    for model_name in &cfg.models {
        let model = load_model(&cfg.artifacts_dir, model_name)?;
        let eval = load_eval_set(&cfg.artifacts_dir, dataset_for_model(model_name))?
            .take(cfg.samples);
        let fp32_acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut Fp32Backend);
        for &bits in &cfg.bits {
            let mut fxp = FixedPointCore::new(bits, cfg.h, NoiseModel::None, 0);
            let mut rns = RnsCore::new(RnsCoreConfig::for_bits(bits, cfg.h))?;
            let fxp_acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut fxp);
            let rns_acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut rns);
            out.push(Fig4Cell {
                model: model_name.clone(),
                bits,
                fxp_norm: fxp_acc / fp32_acc.max(1e-9),
                rns_norm: rns_acc / fp32_acc.max(1e-9),
                fp32_accuracy: fp32_acc,
            });
        }
    }
    Ok(out)
}

pub fn run(cfg: &Fig4Config) -> Result<Report, String> {
    let cells = compute(cfg)?;
    let mut rep = Report::new(&format!(
        "Fig. 4 — accuracy normalized to FP32, fixed-point vs RNS core (h = {}, {} samples)",
        cfg.h, cfg.samples
    ));
    rep.note("MLPerf suite stand-ins per DESIGN.md §5; >= 99% at b=6 with RNS is the paper's headline");
    let mut header = vec!["model".to_string(), "fp32 acc".to_string()];
    for &b in &cfg.bits {
        header.push(format!("fxp b={b}"));
        header.push(format!("rns b={b}"));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    rep.header(&header_refs);
    for model in &cfg.models {
        let mut row = vec![model.clone()];
        let fp32 = cells.iter().find(|c| &c.model == model).map(|c| c.fp32_accuracy).unwrap_or(0.0);
        row.push(pct(fp32));
        for &bits in &cfg.bits {
            let c = cells.iter().find(|c| &c.model == model && c.bits == bits).expect("cell");
            row.push(pct(c.fxp_norm));
            row.push(pct(c.rns_norm));
        }
        rep.row(row);
    }
    Ok(rep)
}

/// The paper's headline claim, extracted from the Fig. 4 data at b = 6.
pub fn headline(cfg: &Fig4Config) -> Result<Report, String> {
    let mut cfg6 = Fig4Config { bits: vec![6], ..Fig4Config::new(&cfg.artifacts_dir) };
    cfg6.models = cfg.models.clone();
    cfg6.samples = cfg.samples;
    cfg6.h = cfg.h;
    let cells = compute(&cfg6)?;
    let mut rep = Report::new("Headline — >= 99% FP32 accuracy with 6-bit RNS (paper abstract)");
    rep.header(&["model", "rns b=6 (norm.)", ">= 99%?", "fxp b=6 (norm.)"]);
    for c in &cells {
        rep.row(vec![
            c.model.clone(),
            pct(c.rns_norm),
            if c.rns_norm >= 0.99 { "yes".into() } else { "NO".into() },
            pct(c.fxp_norm),
        ]);
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn have_artifacts() -> bool {
        std::path::Path::new(&format!("{}/models/mlp.rt", artifacts_dir())).exists()
    }

    #[test]
    fn rns_b6_hits_headline_on_mlp() {
        if !have_artifacts() {
            return;
        }
        let cfg = Fig4Config {
            models: vec!["mlp".into()],
            bits: vec![6],
            samples: 128,
            ..Fig4Config::new(&artifacts_dir())
        };
        let cells = compute(&cfg).unwrap();
        assert!(cells[0].rns_norm >= 0.99, "rns b=6 norm accuracy {}", cells[0].rns_norm);
        assert!(cells[0].rns_norm >= cells[0].fxp_norm);
    }
}
