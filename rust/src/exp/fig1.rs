//! Fig. 1 regenerator: accuracy of (a) the two-layer CNN on the digit task
//! and (b) the deep residual network on the harder image task, evaluated on
//! a regular fixed-point analog core with b_in = b_w = b_ADC = b, sweeping
//! the precision b and the analog array height h.
//!
//! The paper's observation to reproduce: accuracy falls as h grows (larger
//! b_out, more dropped LSBs), it falls earlier for the deeper/harder
//! network, and raising b delays the collapse.

use crate::analog::{FixedPointCore, Fp32Backend, NoiseModel};
use crate::exp::report::{pct, Report};
use crate::nn::dataset::{dataset_for_model, load_eval_set};
use crate::nn::models::{accuracy, load_model};

pub struct Fig1Config {
    pub artifacts_dir: String,
    pub models: Vec<String>,
    pub bits: Vec<u32>,
    pub hs: Vec<usize>,
    pub samples: usize,
}

impl Fig1Config {
    pub fn new(artifacts_dir: &str) -> Self {
        Fig1Config {
            artifacts_dir: artifacts_dir.to_string(),
            models: vec!["cnn".into(), "resnet".into()],
            bits: vec![4, 6, 8],
            hs: vec![16, 64, 128, 256, 512],
            samples: 256,
        }
    }
}

pub struct Fig1Cell {
    pub model: String,
    pub bits: u32,
    pub h: usize,
    pub accuracy: f64,
    pub fp32_accuracy: f64,
}

pub fn compute(cfg: &Fig1Config) -> Result<Vec<Fig1Cell>, String> {
    let mut out = Vec::new();
    for model_name in &cfg.models {
        let model = load_model(&cfg.artifacts_dir, model_name)?;
        let eval = load_eval_set(&cfg.artifacts_dir, dataset_for_model(model_name))?
            .take(cfg.samples);
        let fp32_acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut Fp32Backend);
        for &bits in &cfg.bits {
            for &h in &cfg.hs {
                let mut core = FixedPointCore::new(bits, h, NoiseModel::None, 0);
                let acc = accuracy(model.as_ref(), &eval.input, &eval.labels, &mut core);
                out.push(Fig1Cell {
                    model: model_name.clone(),
                    bits,
                    h,
                    accuracy: acc,
                    fp32_accuracy: fp32_acc,
                });
            }
        }
    }
    Ok(out)
}

pub fn run(cfg: &Fig1Config) -> Result<Report, String> {
    let cells = compute(cfg)?;
    let mut rep = Report::new(&format!(
        "Fig. 1 — fixed-point core accuracy vs precision b and array height h ({} samples)",
        cfg.samples
    ));
    rep.note("easy/shallow task (cnn) tolerates low precision at small h; deeper net (resnet) collapses earlier");
    let mut header: Vec<String> = vec!["model".into(), "b".into(), "fp32".into()];
    header.extend(cfg.hs.iter().map(|h| format!("h={h}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    rep.header(&header_refs);
    for model in &cfg.models {
        for &bits in &cfg.bits {
            let mut row = vec![model.clone(), bits.to_string()];
            let fp32 = cells
                .iter()
                .find(|c| &c.model == model)
                .map(|c| c.fp32_accuracy)
                .unwrap_or(0.0);
            row.push(pct(fp32));
            for &h in &cfg.hs {
                let cell = cells
                    .iter()
                    .find(|c| &c.model == model && c.bits == bits && c.h == h)
                    .expect("cell");
                row.push(pct(cell.accuracy));
            }
            rep.row(row);
        }
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn have_artifacts() -> bool {
        std::path::Path::new(&format!("{}/models/cnn.rt", artifacts_dir())).exists()
    }

    #[test]
    fn accuracy_degrades_with_h_at_low_bits() {
        if !have_artifacts() {
            return;
        }
        let cfg = Fig1Config {
            models: vec!["cnn".into()],
            bits: vec![4],
            hs: vec![16, 512],
            samples: 96,
            ..Fig1Config::new(&artifacts_dir())
        };
        let cells = compute(&cfg).unwrap();
        let small_h = cells.iter().find(|c| c.h == 16).unwrap();
        let large_h = cells.iter().find(|c| c.h == 512).unwrap();
        assert!(
            small_h.accuracy >= large_h.accuracy,
            "h=16 acc {} should be >= h=512 acc {}",
            small_h.accuracy,
            large_h.accuracy
        );
    }

    #[test]
    fn high_bits_recover_accuracy() {
        if !have_artifacts() {
            return;
        }
        let cfg = Fig1Config {
            models: vec!["cnn".into()],
            bits: vec![4, 8],
            hs: vec![128],
            samples: 96,
            ..Fig1Config::new(&artifacts_dir())
        };
        let cells = compute(&cfg).unwrap();
        let b4 = cells.iter().find(|c| c.bits == 4).unwrap();
        let b8 = cells.iter().find(|c| c.bits == 8).unwrap();
        assert!(b8.accuracy >= b4.accuracy);
        // 8-bit @ h=128 keeps meaningful signal (worst-case full-scale ADC
        // model — see DESIGN.md; the paper's Table I "lost bits" column)
        assert!(b8.accuracy > 0.5 * b8.fp32_accuracy);
    }
}
