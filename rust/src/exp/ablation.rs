//! Ablation studies for the design choices DESIGN.md calls out:
//!
//!   A1  moduli selection — max-product (paper Table I) vs greedy-descend:
//!       dynamic range achieved for the same converter budget.
//!   A2  RRNS decoder — CRT-voting (paper §IV) vs base-extension
//!       (paper footnote 5): throughput and decision agreement.
//!   A3  analog modulo realization — ring oscillator vs optical phase
//!       (paper §V): effective residue error rate vs noise level, and the
//!       RRNS redundancy needed to absorb it.
//!   A4  coordinator routing — round-robin vs least-outstanding under a
//!       heavy-tailed (noisy RRNS) backend: serving throughput.

use std::time::Instant;

use crate::analog::modulo_hw::{measure_error_rate, AnalogModulo, OpticalPhaseModulo, RingOscillatorModulo};
use crate::coordinator::{BackendKind, BatcherConfig, Coordinator, CoordinatorConfig, RoutingKind};
use crate::analog::NoiseModel;
use crate::exp::report::{sci, Report};
use crate::nn::models::Batch;
use crate::rns::fault_model::estimate_case_probs;
use crate::rns::mixed_radix::{BexDecoder, BexOutcome};
use crate::rns::moduli::{extend_moduli, gcd, paper_table1, required_output_bits, select_moduli};
use crate::rns::rrns::{Decode, RrnsCode};
use crate::rns::RnsContext;
use crate::tensor::Nhwc;
use crate::util::rng::Rng;

/// A1: greedy-descend moduli selection (the obvious alternative).
pub fn select_moduli_greedy(bits: u32, h: usize) -> Vec<u64> {
    let b_out = required_output_bits(bits, bits, h);
    let target: u128 = 1 << b_out;
    let mut moduli: Vec<u64> = Vec::new();
    let mut prod: u128 = 1;
    let mut cand = (1u64 << bits) - 1;
    while prod < target && cand >= 2 {
        if moduli.iter().all(|&m| gcd(m, cand) == 1) {
            moduli.push(cand);
            prod *= cand as u128;
        }
        cand -= 1;
    }
    moduli
}

pub fn moduli_selection_report() -> Report {
    let mut rep = Report::new("Ablation A1 — moduli selection: max-product (paper) vs greedy");
    rep.note("same converter bit budget; larger M = more headroom for bigger h (Eq. 4)");
    rep.header(&["b", "paper set", "log2(M)", "greedy set", "log2(M)", "paper advantage"]);
    for bits in 4..=8u32 {
        let paper = select_moduli(bits, 128).unwrap();
        let greedy = select_moduli_greedy(bits, 128);
        let lp: f64 = paper.iter().map(|&m| (m as f64).log2()).sum();
        let lg: f64 = greedy.iter().map(|&m| (m as f64).log2()).sum();
        rep.row(vec![
            bits.to_string(),
            format!("{paper:?}"),
            format!("{lp:.2}"),
            format!("{greedy:?}"),
            format!("{lg:.2}"),
            format!("{:+.2} bits (n {} vs {})", lp - lg, paper.len(), greedy.len()),
        ]);
    }
    rep
}

/// A2: decoder comparison over random single-error words.
pub struct DecoderAblation {
    pub voting_ns_per_word: f64,
    pub bex_ns_per_word: f64,
    pub agreement: f64,
    pub words: usize,
}

pub fn decoder_ablation(words: usize, error_rate: f64, seed: u64) -> DecoderAblation {
    let all = extend_moduli(paper_table1(8).unwrap(), 2).unwrap();
    let vote = RrnsCode::new(&all, 3).unwrap();
    let bex = BexDecoder::new(&all, 3).unwrap();
    let ctx = RnsContext::new(&all).unwrap();
    let mut rng = Rng::seed_from(seed);
    let half = (vote.legitimate_range / 2) as i64;
    let cases: Vec<Vec<u64>> = (0..words)
        .map(|_| {
            let v = rng.gen_range_i64(-(half - 1), half);
            let mut res = ctx.forward(v);
            if rng.bernoulli(error_rate) {
                let i = rng.gen_range(all.len() as u64) as usize;
                res[i] = (res[i] + 1 + rng.gen_range(all[i] - 1)) % all[i];
            }
            res
        })
        .collect();

    let t0 = Instant::now();
    let vote_out: Vec<Option<i128>> = cases
        .iter()
        .map(|r| match vote.decode(r) {
            Decode::Ok { value, .. } => Some(value),
            Decode::Detected => None,
        })
        .collect();
    let vote_ns = t0.elapsed().as_nanos() as f64 / words as f64;

    let t0 = Instant::now();
    let bex_out: Vec<Option<i128>> = cases
        .iter()
        .map(|r| match bex.decode(r) {
            BexOutcome::Clean { value } | BexOutcome::Corrected { value, .. } => Some(value),
            BexOutcome::Detected => None,
        })
        .collect();
    let bex_ns = t0.elapsed().as_nanos() as f64 / words as f64;

    let agree = vote_out.iter().zip(&bex_out).filter(|(a, b)| a == b).count() as f64
        / words as f64;
    DecoderAblation {
        voting_ns_per_word: vote_ns,
        bex_ns_per_word: bex_ns,
        agreement: agree,
        words,
    }
}

/// A3: modulo-hardware noise → effective p → required protection.
pub fn modulo_hw_report(trials: u32, seed: u64) -> Report {
    let mut rep = Report::new("Ablation A3 — analog modulo realization vs residue error rate");
    rep.note("effective p measured over dot-product-scale inputs; p_err from RRNS(5,3), R=2");
    rep.header(&["stage", "noise", "effective p", "p_err RRNS(5,3) R=2", "E/op"]);
    let all = extend_moduli(paper_table1(8).unwrap(), 2).unwrap();
    let code = RrnsCode::new(&all, 3).unwrap();
    let mut add = |stage: &dyn AnalogModulo, noise_desc: String| {
        let p = measure_error_rate(stage, 255, trials, seed);
        let cp = estimate_case_probs(&code, p, trials.min(20_000), seed ^ 1);
        rep.row(vec![
            stage.name().to_string(),
            noise_desc,
            sci(p),
            sci(cp.p_err(2)),
            crate::util::format_si(stage.energy_per_op(), "J"),
        ]);
    };
    for jitter in [0.0, 0.25, 1.0] {
        add(&RingOscillatorModulo::new(255, jitter), format!("jitter {jitter} stages"));
    }
    for phase in [0.0, 0.005, 0.02] {
        add(&OpticalPhaseModulo::new(255, phase), format!("phase σ {phase} rad"));
    }
    rep
}

/// A4: routing policy under a noisy (heavy-tailed) RRNS backend.
pub struct RoutingAblation {
    pub rr_throughput: f64,
    pub lo_throughput: f64,
}

pub fn routing_ablation(artifacts_dir: &str, requests: usize) -> Result<RoutingAblation, String> {
    let run = |routing: RoutingKind| -> Result<f64, String> {
        let mut cfg = CoordinatorConfig::new(
            BackendKind::Rns {
                bits: 8,
                redundant: 2,
                attempts: 3,
                noise: NoiseModel::ResidueFlip { p: 0.02 },
            },
            artifacts_dir,
        );
        cfg.workers = 3;
        cfg.routing = routing;
        cfg.batcher = BatcherConfig::default();
        let coord = Coordinator::start(cfg);
        let t0 = Instant::now();
        for _ in 0..requests {
            coord.submit("mlp", Batch::Images(Nhwc::zeros(1, 28, 28, 1)));
        }
        let got = coord.collect(requests);
        let dt = t0.elapsed().as_secs_f64();
        coord.shutdown();
        if got.len() != requests {
            return Err("lost responses".into());
        }
        Ok(requests as f64 / dt)
    };
    Ok(RoutingAblation {
        rr_throughput: run(RoutingKind::RoundRobin)?,
        lo_throughput: run(RoutingKind::LeastOutstanding)?,
    })
}

pub fn run(artifacts_dir: &str) -> Result<Report, String> {
    // composite report: render A1 + A2 + A3 (+A4 when artifacts exist)
    let mut rep = Report::new("Ablations — design-choice studies (A1..A4)");
    rep.header(&["section", "result"]);
    let a1 = moduli_selection_report();
    rep.row(vec!["A1 moduli".into(), "see ablation_a1.txt".into()]);
    a1.save("results", "ablation_a1").ok();

    let d = decoder_ablation(20_000, 0.3, 3);
    rep.row(vec![
        "A2 decoder".into(),
        format!(
            "voting {:.0} ns/word, base-extension {:.0} ns/word ({:.1}x), agreement {:.2}%",
            d.voting_ns_per_word,
            d.bex_ns_per_word,
            d.voting_ns_per_word / d.bex_ns_per_word,
            d.agreement * 100.0
        ),
    ]);

    let a3 = modulo_hw_report(20_000, 11);
    rep.row(vec!["A3 modulo hw".into(), "see ablation_a3.txt".into()]);
    a3.save("results", "ablation_a3").ok();

    if std::path::Path::new(&format!("{artifacts_dir}/models/mlp.rt")).exists() {
        let r = routing_ablation(artifacts_dir, 48)?;
        rep.row(vec![
            "A4 routing".into(),
            format!(
                "round-robin {:.1} req/s vs least-outstanding {:.1} req/s",
                r.rr_throughput, r.lo_throughput
            ),
        ]);
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_selection_never_worse_than_greedy() {
        for bits in 4..=8u32 {
            let paper = select_moduli(bits, 128).unwrap();
            let greedy = select_moduli_greedy(bits, 128);
            let lp: f64 = paper.iter().map(|&m| (m as f64).log2()).sum();
            let lg: f64 = greedy.iter().map(|&m| (m as f64).log2()).sum();
            assert!(
                paper.len() < greedy.len() || lp >= lg - 1e-9,
                "b={bits}: paper {paper:?} vs greedy {greedy:?}"
            );
        }
    }

    #[test]
    fn decoders_agree_and_both_are_fast() {
        // NOTE on the footnote-5 claim: asymptotically base extension does
        // r*k^2 small-word ops vs C(n,k) CRTs for voting, but at n=5 the
        // voting decoder usually short-circuits after ONE in-range CRT on
        // clean words, so there is no guaranteed winner at this size.  We
        // assert agreement plus sane absolute cost and report the measured
        // ratio in the ablation table.
        let d = decoder_ablation(4_000, 0.3, 1);
        assert!(d.agreement > 0.999, "agreement {}", d.agreement);
        assert!(d.bex_ns_per_word < 5_000.0, "bex {:.0}ns", d.bex_ns_per_word);
        assert!(d.voting_ns_per_word < 5_000.0, "voting {:.0}ns", d.voting_ns_per_word);
    }

    #[test]
    fn greedy_is_valid_if_longer() {
        for bits in 4..=8u32 {
            let greedy = select_moduli_greedy(bits, 128);
            let prod: u128 = greedy.iter().map(|&m| m as u128).product();
            assert!(prod >= (1u128 << required_output_bits(bits, bits, 128)));
        }
    }
}
