//! Fig. 7 regenerator: per-output-element data-converter energy of the
//! RNS-based core (n conversions at b bits) vs the regular fixed-point
//! core at the *same precision* (1 conversion at b_out bits), using the
//! paper's Eqs. (6)-(7).
//!
//! Headline shape: ADC energy dominates DAC energy by ~3 orders of
//! magnitude at the same ENOB, and the RNS core's total ADC energy is
//! 168x .. 6.8Mx lower than the same-precision fixed-point core.

use crate::analog::energy::{adc_energy, dac_energy};
use crate::exp::report::Report;
use crate::rns::moduli::{required_output_bits, select_moduli};
use crate::util::format_si;

pub struct Fig7Row {
    pub bits: u32,
    pub n: usize,
    pub b_out: u32,
    pub rns_dac: f64,
    pub rns_adc: f64,
    pub fxp_dac: f64,
    pub fxp_adc: f64,
    pub adc_ratio: f64,
}

pub fn compute(h: usize) -> Vec<Fig7Row> {
    (4..=8)
        .map(|bits| {
            let moduli = select_moduli(bits, h).expect("moduli");
            let n = moduli.len();
            // same precision comparison: fixed-point ADC must capture the
            // full b_out-bit output (paper §V: "b_ADC = b_out ... to achieve
            // the same precision as the RNS approach")
            let b_out = required_output_bits(bits, bits, h);
            let rns_dac = n as f64 * dac_energy(bits);
            let rns_adc = n as f64 * adc_energy(bits);
            let fxp_dac = dac_energy(bits);
            let fxp_adc = adc_energy(b_out);
            Fig7Row { bits, n, b_out, rns_dac, rns_adc, fxp_dac, fxp_adc, adc_ratio: fxp_adc / rns_adc }
        })
        .collect()
}

pub fn run(h: usize) -> Report {
    let rows = compute(h);
    let mut rep = Report::new(&format!(
        "Fig. 7 — data-converter energy per output element, RNS (n conv @ b bits) vs fixed-point (1 conv @ b_out bits), h = {h}"
    ));
    rep.note("E_DAC = ENOB^2 * Cu * VDD^2 (Eq. 6);  E_ADC = k1*ENOB + k2*4^ENOB (Eq. 7)");
    rep.note("paper: RNS ADC energy 168x .. 6.8Mx lower at the same output precision");
    rep.header(&["b", "n", "b_out", "RNS E_DAC", "RNS E_ADC", "FXP E_DAC", "FXP E_ADC", "ADC ratio (fxp/rns)"]);
    for r in &rows {
        rep.row(vec![
            r.bits.to_string(),
            r.n.to_string(),
            r.b_out.to_string(),
            format_si(r.rns_dac, "J"),
            format_si(r.rns_adc, "J"),
            format_si(r.fxp_dac, "J"),
            format_si(r.fxp_adc, "J"),
            format!("{:.3e}x", r.adc_ratio),
        ]);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_span_paper_range() {
        let rows = compute(128);
        // paper: 168x (b=4) up to 6.8Mx (b=8); our Eq-faithful model should
        // land in the same orders of magnitude at the extremes.
        let lo = rows.first().unwrap().adc_ratio;
        let hi = rows.last().unwrap().adc_ratio;
        assert!((50.0..2_000.0).contains(&lo), "b=4 ratio {lo}");
        assert!((1e5..1e8).contains(&hi), "b=8 ratio {hi}");
        // monotone in bits
        for w in rows.windows(2) {
            assert!(w[1].adc_ratio > w[0].adc_ratio);
        }
    }

    #[test]
    fn adc_dominates_dac() {
        // paper §V: "ADCs have approximately three orders of magnitude
        // higher energy than DACs with the same ENOB" — per conversion.
        // (The per-core ratio here divides by n identical DACs, so compare
        // per-conversion values.)
        for r in compute(128) {
            let per_adc = r.rns_adc / r.n as f64;
            let per_dac = r.rns_dac / r.n as f64;
            assert!(per_adc / per_dac > 25.0, "b={}: {per_adc} / {per_dac}", r.bits);
        }
        // at 8 bits the per-conversion gap approaches 3 orders of magnitude
        let r8 = &compute(128)[4];
        assert!(r8.rns_adc / r8.rns_dac > 25.0);
        assert!(adc_energy(8) / dac_energy(8) > 25.0);
        assert!(adc_energy(12) / dac_energy(12) > 100.0);
    }
}
