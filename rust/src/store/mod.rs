//! Shared, read-only plan store: the weight-stationary half of the RNS
//! dataflow, built once per (weight matrix, moduli config) and shared
//! across every core that serves the same model.
//!
//! The paper's datapath loads a layer's residues into the analog arrays
//! once and then streams activations; the expensive reusable artifact on
//! the simulator side is the `RnsPlan` (quantized weights, per-channel
//! residues, `u32` staging).  Before this module each coordinator worker
//! owned a private per-core LRU, so W workers held W copies of every
//! layer's plan.  `PlanStore` de-duplicates them: one `Arc<RnsPlan>` per
//! `PlanKey`, with `Once`-style construction (concurrent `get_or_build`
//! calls for the same key run the builder exactly once; the losers block
//! and receive the same `Arc`), eviction by model unload, and hit/miss/
//! memory counters — per store and per model.
//!
//! Plans are immutable after construction, which is the entire reason
//! sharing is safe: every consumer borrows `&RnsPlan` through its `Arc`,
//! no lock is held during GEMM execution, and a plan evicted mid-use
//! simply lives until the last in-flight `Arc` drops.
//!
//! Keys carry the moduli configuration (`bits`, tile height `h`, the full
//! info+redundant moduli set) alongside the weight identity, so cores
//! with different precisions can share one store without collisions.
//! Plans requested without a model tag (one-shot sweep matrices, fig3
//! style) are LRU-bounded so campaigns of random weights cannot grow the
//! store without limit; model-tagged plans are pinned until
//! `unload_model`.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Arc, Mutex, OnceLock};

use crate::runtime::plan::RnsPlan;
use crate::tensor::MatF;

/// Untagged plans (no model name) are one-shot sweep artifacts; bound
/// them like the old per-core LRU did so fig3-style campaigns degrade to
/// rebuild cost instead of unbounded memory.
pub const DEFAULT_UNTAGGED_CAPACITY: usize = 64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Identity of one plan: weight matrix (pointer + shape + strided FNV
/// fingerprint) × moduli configuration (bits, tile height, channel set).
///
/// The fingerprint samples ~16 elements: cheap against a layer GEMM and
/// enough to tell apart distinct layers that reuse a freed allocation's
/// address.  It is best-effort against in-place mutation — callers that
/// edit weights in place (this crate's models never do) must rebuild the
/// matrix instead.  Cross-worker de-duplication relies on workers sharing
/// one weight allocation (`ModelRegistry` hands every worker the same
/// `Arc<dyn Model>`), which makes `ptr` identical across workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    ptr: usize,
    rows: usize,
    cols: usize,
    fingerprint: u64,
    bits: u32,
    h: usize,
    moduli_fp: u64,
}

impl PlanKey {
    pub fn for_weights(w: &MatF, bits: u32, h: usize, moduli: &[u64]) -> Self {
        let d = &w.data;
        let mut fp = FNV_OFFSET;
        let step = (d.len() / 16).max(1);
        let mut i = 0;
        while i < d.len() {
            fp = (fp ^ d[i].to_bits() as u64).wrapping_mul(FNV_PRIME);
            i += step;
        }
        let mut mfp = FNV_OFFSET ^ moduli.len() as u64;
        for &m in moduli {
            mfp = (mfp ^ m).wrapping_mul(FNV_PRIME);
        }
        PlanKey { ptr: d.as_ptr() as usize, rows: w.rows, cols: w.cols, fingerprint: fp, bits, h, moduli_fp: mfp }
    }
}

/// Whole-store counters (monotonic except the resident gauges).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Plans actually constructed (the deduplicated build count).
    pub builds: u64,
    /// Requests served from an existing slot (including requests that
    /// blocked on an in-flight build and received the shared result).
    pub hits: u64,
    /// Plans dropped by LRU bounding or model unload.
    pub evicted: u64,
    /// Plans currently resident.
    pub resident_plans: usize,
    /// Bytes held by resident plans (residues + staging + quantized
    /// weights; see `RnsPlan::mem_bytes`).
    pub resident_bytes: u64,
}

/// Per-model plan traffic + residency, for the serving shutdown report.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ModelPlanStats {
    pub model: String,
    /// Lookups attributed to this model that found an existing slot.
    pub hits: u64,
    /// Lookups that reserved a new slot (== plans this model caused to
    /// be built, since tagged plans are never LRU-evicted).
    pub misses: u64,
    /// Plans currently resident under this model's tag.
    pub plans: usize,
    pub bytes: u64,
}

struct Slot {
    /// `Once`-style cell: exactly one `get_or_build` caller runs the
    /// builder; everyone else blocks in `get_or_init` and clones the
    /// same `Arc`.
    cell: Arc<OnceLock<Arc<RnsPlan>>>,
    /// Model tag of the reserving caller (None = LRU-bounded).
    model: Option<String>,
    /// Filled in after the build completes (0 while in flight).
    bytes: u64,
}

#[derive(Default)]
struct ModelEntry {
    keys: Vec<PlanKey>,
    hits: u64,
    misses: u64,
}

#[derive(Default)]
struct StoreInner {
    slots: HashMap<PlanKey, Slot>,
    /// Untagged keys, least- to most-recently used.
    lru: VecDeque<PlanKey>,
    models: HashMap<String, ModelEntry>,
    /// Models unloaded and not yet re-activated: tagged lookups under a
    /// draining name fall back to untagged (LRU-bounded) slots, so an
    /// in-flight batch racing `unload_model` cannot re-pin plans of the
    /// dead weight allocation under the unloaded tag (they would be
    /// unreachable once the model reloads at a new address — a leak
    /// until a second unload).  `activate_model` (called by workers when
    /// they warm a fresh instance) restores pinning.
    draining: HashSet<String>,
    builds: u64,
    hits: u64,
    evicted: u64,
    resident_bytes: u64,
}

/// Concurrent, build-once plan store.  All methods take `&self`; the
/// internal mutex guards only the index — plan construction and GEMM
/// execution run outside it.
pub struct PlanStore {
    inner: Mutex<StoreInner>,
    untagged_capacity: usize,
}

impl Default for PlanStore {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_UNTAGGED_CAPACITY)
    }
}

impl PlanStore {
    /// `untagged_capacity` bounds only plans requested without a model
    /// tag; tagged plans live until `unload_model`.
    pub fn with_capacity(untagged_capacity: usize) -> Self {
        PlanStore { inner: Mutex::new(StoreInner::default()), untagged_capacity: untagged_capacity.max(1) }
    }

    /// Fetch the plan for `key`, building it at most once across all
    /// concurrent callers.  `model` attributes the lookup (and, for the
    /// reserving caller, the plan's eviction lifetime) to a model name.
    pub fn get_or_build<F>(&self, key: PlanKey, model: Option<&str>, build: F) -> Arc<RnsPlan>
    where
        F: FnOnce() -> RnsPlan,
    {
        let cell = {
            let mut st = self.inner.lock().unwrap();
            // a draining model's lookups are demoted to untagged: see
            // `StoreInner::draining`
            let model = match model {
                Some(m) if st.draining.contains(m) => None,
                other => other,
            };
            let existing = st.slots.get(&key).map(|s| (Arc::clone(&s.cell), s.model.is_none()));
            match existing {
                Some((cell, untagged)) => {
                    st.hits += 1;
                    if let Some(m) = model {
                        // get_mut first: this is the per-layer-GEMM hot
                        // path, and entry() would allocate a String under
                        // the store mutex on every hit
                        if let Some(e) = st.models.get_mut(m) {
                            e.hits += 1;
                        } else {
                            st.models.entry(m.to_string()).or_default().hits += 1;
                        }
                    }
                    match (untagged, model) {
                        (true, Some(m)) => {
                            // promote: a plan first built untagged (e.g. by
                            // a sweep sharing the store) is now owned by a
                            // served model — pin it out of the LRU and make
                            // it visible to unload_model/model_stats
                            if let Some(pos) = st.lru.iter().position(|k| k == &key) {
                                let _ = st.lru.remove(pos);
                            }
                            if let Some(slot) = st.slots.get_mut(&key) {
                                slot.model = Some(m.to_string());
                            }
                            st.models.entry(m.to_string()).or_default().keys.push(key);
                        }
                        (true, None) => {
                            // touch: move to the most-recently-used end
                            if let Some(pos) = st.lru.iter().position(|k| k == &key) {
                                let _ = st.lru.remove(pos);
                                st.lru.push_back(key);
                            }
                        }
                        // already tagged: first tag wins (two models hitting
                        // one key share the plan; it unloads with the first)
                        (false, _) => {}
                    }
                    cell
                }
                None => {
                    let cell = Arc::new(OnceLock::new());
                    st.slots.insert(
                        key,
                        Slot { cell: Arc::clone(&cell), model: model.map(str::to_string), bytes: 0 },
                    );
                    match model {
                        Some(m) => {
                            let e = st.models.entry(m.to_string()).or_default();
                            e.misses += 1;
                            e.keys.push(key);
                        }
                        None => {
                            st.lru.push_back(key);
                            // bound the scan: with every survivor in-flight
                            // the queue would otherwise rotate forever
                            let mut scanned = 0;
                            while st.lru.len() > self.untagged_capacity && scanned < st.lru.len() {
                                scanned += 1;
                                let Some(old) = st.lru.pop_front() else { break };
                                // never evict a slot whose build is still in
                                // flight: a third caller would miss and run
                                // the builder a second time concurrently,
                                // breaking build-exactly-once (the queue may
                                // transiently exceed capacity instead)
                                if st.slots.get(&old).is_some_and(|s| s.cell.get().is_none()) {
                                    st.lru.push_back(old);
                                    continue;
                                }
                                if let Some(s) = st.slots.remove(&old) {
                                    st.resident_bytes = st.resident_bytes.saturating_sub(s.bytes);
                                    st.evicted += 1;
                                }
                            }
                        }
                    }
                    cell
                }
            }
        };
        // Build outside the index lock: concurrent callers for the same
        // key serialize on the cell, not on the whole store, and exactly
        // one of them runs the builder.
        let mut built = false;
        let plan = Arc::clone(cell.get_or_init(|| {
            built = true;
            Arc::new(build())
        }));
        if built {
            let bytes = plan.mem_bytes();
            let mut st = self.inner.lock().unwrap();
            st.builds += 1;
            // the slot may have been LRU-evicted while building; only
            // still-resident plans count toward the memory gauge
            let resident = match st.slots.get_mut(&key) {
                Some(slot) if Arc::ptr_eq(&slot.cell, &cell) => {
                    slot.bytes = bytes;
                    true
                }
                _ => false,
            };
            if resident {
                st.resident_bytes += bytes;
            }
        }
        plan
    }

    /// Peek at a resident, fully-built plan (no counter updates).
    pub fn get(&self, key: &PlanKey) -> Option<Arc<RnsPlan>> {
        let st = self.inner.lock().unwrap();
        st.slots.get(key).and_then(|s| s.cell.get().cloned())
    }

    /// Drop every plan tagged with `model`; returns how many were
    /// evicted.  In-flight `Arc`s stay valid until their holders drop.
    /// The name starts draining: later tagged lookups fall back to
    /// untagged LRU slots (in-flight batches racing the unload cannot
    /// re-pin dead-allocation plans) until `activate_model` is called —
    /// either by the coordinator once every worker acks the control-
    /// plane unload (nothing can touch the old generation after that),
    /// or by a worker warming a freshly reloaded instance.
    pub fn unload_model(&self, model: &str) -> usize {
        let mut st = self.inner.lock().unwrap();
        st.draining.insert(model.to_string());
        let Some(entry) = st.models.remove(model) else {
            return 0;
        };
        let mut dropped = 0;
        for key in entry.keys {
            // a slot whose build is still in flight is demoted to the
            // untagged LRU instead of removed: removing it would let a
            // racing caller run the builder a second time (breaking
            // build-exactly-once) and would count a never-built plan as
            // evicted; demotion un-pins it while keeping the cell every
            // concurrent caller is blocked on
            if st.slots.get(&key).is_some_and(|s| s.cell.get().is_none()) {
                if let Some(slot) = st.slots.get_mut(&key) {
                    slot.model = None;
                }
                st.lru.push_back(key);
                continue;
            }
            if let Some(slot) = st.slots.remove(&key) {
                st.resident_bytes = st.resident_bytes.saturating_sub(slot.bytes);
                st.evicted += 1;
                dropped += 1;
            }
        }
        dropped
    }

    /// End a model's draining state (no-op if it was not draining):
    /// subsequent tagged lookups pin plans again.  Called from two
    /// places: workers warming a freshly (re)loaded instance (the fresh
    /// generation's plans pin while stale rebuilds from batches that
    /// raced the unload stay LRU-bounded), and the coordinator's
    /// `unload_model` once every worker has acked the control-plane
    /// release — at that point no stale instance survives anywhere, so
    /// draining has nothing left to guard.
    pub fn activate_model(&self, model: &str) {
        self.inner.lock().unwrap().draining.remove(model);
    }

    /// Whether `model` is draining (unloaded, not yet re-activated).
    /// With the control plane, `Coordinator::unload_model` ends draining
    /// itself once every worker acks; exposed for tests and ops.
    pub fn is_draining(&self, model: &str) -> bool {
        self.inner.lock().unwrap().draining.contains(model)
    }

    pub fn stats(&self) -> StoreStats {
        let st = self.inner.lock().unwrap();
        StoreStats {
            builds: st.builds,
            hits: st.hits,
            evicted: st.evicted,
            resident_plans: st.slots.len(),
            resident_bytes: st.resident_bytes,
        }
    }

    /// Per-model counters, sorted by model name (stable report order).
    pub fn model_stats(&self) -> Vec<ModelPlanStats> {
        let st = self.inner.lock().unwrap();
        let mut out: Vec<ModelPlanStats> = st
            .models
            .iter()
            .map(|(name, e)| {
                let (mut plans, mut bytes) = (0usize, 0u64);
                for key in &e.keys {
                    if let Some(slot) = st.slots.get(key) {
                        plans += 1;
                        bytes += slot.bytes;
                    }
                }
                ModelPlanStats { model: name.clone(), hits: e.hits, misses: e.misses, plans, bytes }
            })
            .collect();
        out.sort_by(|a, b| a.model.cmp(&b.model));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rns::paper_table1;
    use crate::util::rng::Rng;

    fn rand_mat(seed: u64, rows: usize, cols: usize) -> MatF {
        let mut rng = Rng::seed_from(seed);
        MatF::from_vec(rows, cols, (0..rows * cols).map(|_| rng.uniform_f32(-1.0, 1.0)).collect())
    }

    fn build_plan(w: &MatF) -> RnsPlan {
        RnsPlan::build(w, 6, 128, paper_table1(6).unwrap())
    }

    fn key_of(w: &MatF) -> PlanKey {
        PlanKey::for_weights(w, 6, 128, paper_table1(6).unwrap())
    }

    #[test]
    fn second_lookup_hits_and_shares_the_arc() {
        let store = PlanStore::default();
        let w = rand_mat(1, 130, 5);
        let a = store.get_or_build(key_of(&w), None, || build_plan(&w));
        let b = store.get_or_build(key_of(&w), None, || panic!("must not rebuild"));
        assert!(Arc::ptr_eq(&a, &b));
        let s = store.stats();
        assert_eq!((s.builds, s.hits, s.resident_plans), (1, 1, 1));
        assert_eq!(s.resident_bytes, a.mem_bytes());
        assert!(s.resident_bytes > 0);
    }

    #[test]
    fn distinct_configs_do_not_collide() {
        let store = PlanStore::default();
        let w = rand_mat(2, 140, 4);
        let k6 = PlanKey::for_weights(&w, 6, 128, paper_table1(6).unwrap());
        let k8 = PlanKey::for_weights(&w, 8, 128, paper_table1(8).unwrap());
        assert_ne!(k6, k8);
        store.get_or_build(k6, None, || RnsPlan::build(&w, 6, 128, paper_table1(6).unwrap()));
        store.get_or_build(k8, None, || RnsPlan::build(&w, 8, 128, paper_table1(8).unwrap()));
        assert_eq!(store.stats().builds, 2);
    }

    #[test]
    fn untagged_plans_are_lru_bounded() {
        let cap = 4;
        let store = PlanStore::with_capacity(cap);
        let mats: Vec<MatF> = (0..cap as u64 + 3).map(|i| rand_mat(10 + i, 32, 2)).collect();
        for w in &mats {
            store.get_or_build(PlanKey::for_weights(w, 4, 32, paper_table1(4).unwrap()), None, || {
                RnsPlan::build(w, 4, 32, paper_table1(4).unwrap())
            });
        }
        let s = store.stats();
        assert_eq!(s.builds, cap as u64 + 3);
        assert_eq!(s.resident_plans, cap);
        assert_eq!(s.evicted, 3);
        // the survivors are the most recently used, and bytes match them
        let survivors: u64 = mats[3..]
            .iter()
            .map(|w| store.get(&PlanKey::for_weights(w, 4, 32, paper_table1(4).unwrap())).unwrap().mem_bytes())
            .sum();
        assert_eq!(s.resident_bytes, survivors);
        assert!(store.get(&PlanKey::for_weights(&mats[0], 4, 32, paper_table1(4).unwrap())).is_none());
    }

    #[test]
    fn lru_touch_on_hit_protects_hot_plans() {
        let store = PlanStore::with_capacity(2);
        let (a, b, c) = (rand_mat(20, 32, 2), rand_mat(21, 32, 2), rand_mat(22, 32, 2));
        let mk = |w: &MatF| PlanKey::for_weights(w, 4, 32, paper_table1(4).unwrap());
        let build = |w: &MatF| RnsPlan::build(w, 4, 32, paper_table1(4).unwrap());
        store.get_or_build(mk(&a), None, || build(&a));
        store.get_or_build(mk(&b), None, || build(&b));
        store.get_or_build(mk(&a), None, || panic!("hit")); // touch a
        store.get_or_build(mk(&c), None, || build(&c)); // evicts b, not a
        assert!(store.get(&mk(&a)).is_some());
        assert!(store.get(&mk(&b)).is_none());
        assert!(store.get(&mk(&c)).is_some());
    }

    #[test]
    fn model_tagged_plans_pinned_until_unload() {
        let store = PlanStore::with_capacity(1);
        let layers: Vec<MatF> = (0..3).map(|i| rand_mat(30 + i, 64, 3)).collect();
        for w in &layers {
            store.get_or_build(key_of(w), Some("mlp"), || build_plan(w));
        }
        // capacity 1 does not evict tagged plans
        assert_eq!(store.stats().resident_plans, 3);
        let ms = store.model_stats();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].model, "mlp");
        assert_eq!((ms[0].hits, ms[0].misses, ms[0].plans), (0, 3, 3));
        assert!(ms[0].bytes > 0);
        // a warm pass from a second worker is all hits
        for w in &layers {
            store.get_or_build(key_of(w), Some("mlp"), || panic!("warm must hit"));
        }
        assert_eq!(store.model_stats()[0].hits, 3);
        assert_eq!(store.unload_model("mlp"), 3);
        let s = store.stats();
        assert_eq!((s.resident_plans, s.resident_bytes, s.evicted), (0, 0, 3));
        assert_eq!(store.unload_model("mlp"), 0);
        assert!(store.model_stats().is_empty());
    }

    #[test]
    fn untagged_plan_promoted_when_a_model_claims_it() {
        let store = PlanStore::with_capacity(1);
        let w = rand_mat(60, 64, 3);
        let a = store.get_or_build(key_of(&w), None, || build_plan(&w)); // untagged build
        // a served model hits the same key: the plan must be promoted —
        // pinned out of the LRU and owned by the model
        let b = store.get_or_build(key_of(&w), Some("mlp"), || panic!("hit"));
        assert!(Arc::ptr_eq(&a, &b));
        let ms = store.model_stats();
        assert_eq!(ms.len(), 1);
        assert_eq!((ms[0].hits, ms[0].misses, ms[0].plans), (1, 0, 1));
        assert_eq!(ms[0].bytes, a.mem_bytes());
        // capacity-1 LRU churn must no longer evict the promoted plan
        for i in 0..3u64 {
            let other = rand_mat(70 + i, 64, 3);
            store.get_or_build(key_of(&other), None, || build_plan(&other));
        }
        assert!(store.get(&key_of(&w)).is_some(), "promoted plan survives LRU pressure");
        // and unload now covers it
        assert_eq!(store.unload_model("mlp"), 1);
        assert!(store.get(&key_of(&w)).is_none());
    }

    #[test]
    fn in_flight_untagged_build_is_not_evicted() {
        use std::sync::mpsc;
        let store = Arc::new(PlanStore::with_capacity(1));
        let w = Arc::new(rand_mat(90, 64, 3));
        let (enter_tx, enter_rx) = mpsc::channel();
        let (go_tx, go_rx) = mpsc::channel();
        let t = {
            let (store, w) = (Arc::clone(&store), Arc::clone(&w));
            std::thread::spawn(move || {
                store.get_or_build(key_of(&w), None, || {
                    enter_tx.send(()).unwrap();
                    go_rx.recv().unwrap();
                    build_plan(&w)
                })
            })
        };
        enter_rx.recv().unwrap(); // builder is inside the build, slot in flight
        // capacity-1 churn while the build runs: the in-flight slot must
        // be skipped (evicting it would let a later caller run the
        // builder a second time, breaking build-exactly-once)
        let other = rand_mat(91, 64, 3);
        store.get_or_build(key_of(&other), None, || build_plan(&other));
        go_tx.send(()).unwrap();
        let built = t.join().unwrap();
        let again = store.get_or_build(key_of(&w), None, || panic!("must not rebuild"));
        assert!(Arc::ptr_eq(&built, &again), "in-flight slot survived the churn");
        assert_eq!(store.stats().builds, 2);
    }

    #[test]
    fn unloaded_model_rebuilds_drain_to_lru_until_reactivated() {
        let store = PlanStore::with_capacity(2);
        let w = rand_mat(80, 64, 3);
        store.get_or_build(key_of(&w), Some("m"), || build_plan(&w));
        assert_eq!(store.unload_model("m"), 1);
        // an in-flight batch racing the unload rebuilds the plan under
        // the unloaded tag: it must land untagged (no pin, no model
        // entry resurrection) so it cannot leak once the model reloads
        // at a new weight address
        store.get_or_build(key_of(&w), Some("m"), || build_plan(&w));
        assert!(store.model_stats().is_empty(), "draining tag must not resurrect the model");
        // LRU pressure evicts the stale rebuild like any untagged plan
        let (a, b) = (rand_mat(81, 64, 3), rand_mat(82, 64, 3));
        store.get_or_build(key_of(&a), None, || build_plan(&a));
        store.get_or_build(key_of(&b), None, || build_plan(&b));
        assert!(store.get(&key_of(&w)).is_none(), "stale rebuild must be evictable");
        // a fresh warm re-activates the name: plans pin again
        store.activate_model("m");
        let w2 = rand_mat(83, 64, 3);
        store.get_or_build(key_of(&w2), Some("m"), || build_plan(&w2));
        let ms = store.model_stats();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].plans, 1);
        assert_eq!(store.unload_model("m"), 1);
    }

    #[test]
    fn concurrent_get_or_build_builds_exactly_once() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let store = Arc::new(PlanStore::default());
        let w = Arc::new(rand_mat(40, 256, 8));
        let builds = Arc::new(AtomicU64::new(0));
        let key = key_of(&w);
        let plans: Vec<Arc<RnsPlan>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let (store, w, builds) = (Arc::clone(&store), Arc::clone(&w), Arc::clone(&builds));
                    s.spawn(move || {
                        store.get_or_build(key, Some("m"), || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            build_plan(&w)
                        })
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(builds.load(Ordering::SeqCst), 1, "builder ran exactly once");
        assert_eq!(store.stats().builds, 1);
        for p in &plans[1..] {
            assert!(Arc::ptr_eq(&plans[0], p), "all callers share one Arc");
        }
    }
}
