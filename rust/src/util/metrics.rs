//! Typed metric registry: the process-wide single source of truth for
//! serving observability.
//!
//! Zero-dep by construction (no prometheus crate in the offline image):
//! three instrument types over plain atomics —
//!
//! - [`Counter`]: monotone u64 (requests, conversions, respawns).
//! - [`Gauge`]: signed level (queue depth, active sessions).
//! - [`Histogram`]: fixed log-scale buckets over integer microseconds
//!   (per-stage pipeline latency).  Buckets are chosen at registration
//!   and never resize, so `observe` is lock-free.
//!
//! Instruments are owned by a [`MetricRegistry`] keyed by family name +
//! one optional label pair.  Handles are `Arc`s: the serving tier holds
//! its handle and bumps atomics on the hot path; the registry walks the
//! same atomics at scrape time to render Prometheus text exposition
//! (`text/plain; version=0.0.4`).  The legacy human-readable report
//! (`ServingMetrics::report`) reads the *same* counters, which is what
//! keeps the exposition and the report-line parsers in exact agreement.
//!
//! Label cardinality is bounded by design: labels are only ever model /
//! worker / stage names, and a family caps its children at
//! [`MAX_SERIES_PER_FAMILY`] — past the cap, new label values collapse
//! into one shared `"_overflow"` series instead of growing without
//! bound (a gateway fed garbage model names must not OOM the scrape).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Hard cap on total series per family, overflow series included: up to
/// `MAX_SERIES_PER_FAMILY - 1` regular label values, then the overflow
/// series absorbs the rest.
pub const MAX_SERIES_PER_FAMILY: usize = 64;

/// Label value that absorbs new values once a family reaches its cap.
pub const OVERFLOW_LABEL: &str = "_overflow";

/// Log-scale (powers of 4) bucket bounds in microseconds: 1 µs … ~16.8 s.
/// Shared by every latency histogram so stage timings are comparable.
pub const LATENCY_BUCKETS_US: [u64; 13] = [
    1,
    4,
    16,
    64,
    256,
    1_024,
    4_096,
    16_384,
    65_536,
    262_144,
    1_048_576,
    4_194_304,
    16_777_216,
];

/// Monotone counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// Increment by one, returning the pre-increment value (a free
    /// 0-based admission/sequence index for callers that want one).
    pub fn inc(&self) -> u64 {
        self.v.fetch_add(1, Ordering::Relaxed)
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    /// Raise the counter to `target` if it is currently below it — the
    /// sync primitive for sources that publish cumulative snapshots
    /// (plan store, fabric) rather than incrementing per event.
    pub fn raise_to(&self, target: u64) {
        self.v.fetch_max(target, Ordering::Relaxed);
    }
}

/// Signed level gauge.
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn set(&self, n: i64) {
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.v.fetch_add(d, Ordering::SeqCst);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::SeqCst)
    }

    /// Atomically increment iff the current value is below `cap`.
    /// Returns whether the increment happened — this is the gateway's
    /// admission-control compare-and-increment, kept on the gauge so
    /// the admission count and the exported `active` series are one
    /// atomic, not two that can disagree.
    pub fn try_inc_below(&self, cap: i64) -> bool {
        self.v
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| (n < cap).then_some(n + 1))
            .is_ok()
    }
}

/// Fixed-bucket histogram over integer values (microseconds by
/// convention).  Bucket counts are per-bucket (not cumulative) in
/// memory; rendering accumulates.
///
/// The serving tier observes one measured value per pipeline stage and
/// feeds the *same* u64 into both this histogram and the request's span
/// trace (`util::trace`), so the aggregate and per-request views are
/// two projections of one measurement, never two clocks.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<u64>,
    buckets: Vec<AtomicU64>, // bounds.len() + 1, last = overflow (+Inf)
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    fn with_bounds(bounds: &[u64]) -> Self {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must be strictly increasing");
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, value: u64) {
        let idx = self.bounds.partition_point(|&b| b < value);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Cumulative counts per upper bound, ending with the +Inf bucket
    /// (`None` bound) — exactly the exposition's `_bucket` series.
    pub fn cumulative(&self) -> Vec<(Option<u64>, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.buckets.len());
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            out.push((self.bounds.get(i).copied(), acc));
        }
        out
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

#[derive(Clone)]
enum Child {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

struct Family {
    help: String,
    kind: Kind,
    /// The one label key this family uses (`None` = unlabeled family).
    /// Bounded-cardinality rule: a family is either unlabeled or keyed
    /// by exactly one of model/worker/stage — never free-form pairs.
    label_key: Option<String>,
    bounds: Vec<u64>, // histograms only
    children: BTreeMap<String, Child>,
}

/// The process-wide registry.  One per coordinator (tests get isolated
/// registries for free); every component registers its families here
/// and keeps the returned `Arc` handle.
#[derive(Default)]
pub struct MetricRegistry {
    families: Mutex<BTreeMap<String, Family>>,
}

impl MetricRegistry {
    pub fn new() -> Self {
        MetricRegistry::default()
    }

    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        match self.child(name, help, Kind::Counter, None, &[]) {
            Child::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    pub fn counter_labeled(&self, name: &str, help: &str, key: &str, value: &str) -> Arc<Counter> {
        match self.child(name, help, Kind::Counter, Some((key, value)), &[]) {
            Child::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        match self.child(name, help, Kind::Gauge, None, &[]) {
            Child::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    pub fn gauge_labeled(&self, name: &str, help: &str, key: &str, value: &str) -> Arc<Gauge> {
        match self.child(name, help, Kind::Gauge, Some((key, value)), &[]) {
            Child::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    pub fn histogram(&self, name: &str, help: &str, bounds: &[u64]) -> Arc<Histogram> {
        match self.child(name, help, Kind::Histogram, None, bounds) {
            Child::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    pub fn histogram_labeled(
        &self,
        name: &str,
        help: &str,
        key: &str,
        value: &str,
        bounds: &[u64],
    ) -> Arc<Histogram> {
        match self.child(name, help, Kind::Histogram, Some((key, value)), bounds) {
            Child::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    /// Get-or-register: same (name, label) always returns the same
    /// handle.  Re-registering a name with a different kind or label
    /// key is a programming error and panics loudly.
    fn child(
        &self,
        name: &str,
        help: &str,
        kind: Kind,
        label: Option<(&str, &str)>,
        bounds: &[u64],
    ) -> Child {
        let mut families = self.families.lock().unwrap();
        let fam = families.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            label_key: label.map(|(k, _)| k.to_string()),
            bounds: bounds.to_vec(),
            children: BTreeMap::new(),
        });
        assert_eq!(fam.kind, kind, "metric family `{name}` re-registered as a different kind");
        assert_eq!(
            fam.label_key.as_deref(),
            label.map(|(k, _)| k),
            "metric family `{name}` re-registered with a different label key"
        );
        let mut value = label.map(|(_, v)| v).unwrap_or("").to_string();
        // bounded cardinality: the overflow series counts toward the cap,
        // so at most MAX_SERIES_PER_FAMILY - 1 regular series + `_overflow`
        if fam.children.len() >= MAX_SERIES_PER_FAMILY - 1 && !fam.children.contains_key(&value) {
            value = OVERFLOW_LABEL.to_string();
        }
        let fam_bounds = fam.bounds.clone();
        fam.children
            .entry(value)
            .or_insert_with(|| match kind {
                Kind::Counter => Child::Counter(Arc::new(Counter::default())),
                Kind::Gauge => Child::Gauge(Arc::new(Gauge::default())),
                Kind::Histogram => Child::Histogram(Arc::new(Histogram::with_bounds(&fam_bounds))),
            })
            .clone()
    }

    /// Render the whole registry as Prometheus text exposition
    /// (`text/plain; version=0.0.4`): `# HELP` / `# TYPE` per family,
    /// cumulative `_bucket{le=...}` + `_sum`/`_count` for histograms,
    /// `le="+Inf"` terminal.
    pub fn render_prometheus(&self) -> String {
        let families = self.families.lock().unwrap();
        let mut out = String::new();
        for (name, fam) in families.iter() {
            let _ = writeln!(out, "# HELP {name} {}", escape_help(&fam.help));
            let _ = writeln!(out, "# TYPE {name} {}", fam.kind.name());
            for (value, child) in &fam.children {
                let label = fam
                    .label_key
                    .as_deref()
                    .map(|k| format!("{k}=\"{}\"", escape_label(value)))
                    .unwrap_or_default();
                match child {
                    Child::Counter(c) => {
                        let _ = writeln!(out, "{name}{} {}", braced(&label), c.get());
                    }
                    Child::Gauge(g) => {
                        let _ = writeln!(out, "{name}{} {}", braced(&label), g.get());
                    }
                    Child::Histogram(h) => {
                        for (bound, cum) in h.cumulative() {
                            let le = match bound {
                                Some(b) => format!("le=\"{b}\""),
                                None => "le=\"+Inf\"".to_string(),
                            };
                            let labels = if label.is_empty() {
                                le
                            } else {
                                format!("{label},{le}")
                            };
                            let _ = writeln!(out, "{name}_bucket{{{labels}}} {cum}");
                        }
                        let _ = writeln!(out, "{name}_sum{} {}", braced(&label), h.sum());
                        let _ = writeln!(out, "{name}_count{} {}", braced(&label), h.count());
                    }
                }
            }
        }
        out
    }
}

fn braced(label: &str) -> String {
    if label.is_empty() {
        String::new()
    } else {
        format!("{{{label}}}")
    }
}

/// Label-value escaping per the exposition format: backslash, double
/// quote, newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            other => out.push(other),
        }
    }
    out
}

/// HELP text escaping: backslash and newline only (quotes are legal).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            other => out.push(other),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let reg = MetricRegistry::new();
        let c = reg.counter("rns_requests_total", "requests");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name returns the same underlying atomic
        let c2 = reg.counter("rns_requests_total", "requests");
        c2.inc();
        assert_eq!(c.get(), 6);
        c.raise_to(10);
        assert_eq!(c.get(), 10);
        c.raise_to(3); // never goes backwards
        assert_eq!(c.get(), 10);

        let g = reg.gauge("rns_queue_depth", "queued requests");
        g.set(7);
        g.add(-2);
        assert_eq!(g.get(), 5);
    }

    #[test]
    fn gauge_admission_compare_and_increment() {
        let g = Gauge::default();
        assert!(g.try_inc_below(2));
        assert!(g.try_inc_below(2));
        assert!(!g.try_inc_below(2), "at cap: refused");
        assert_eq!(g.get(), 2);
        g.add(-1);
        assert!(g.try_inc_below(2), "freed slot re-admits");
    }

    #[test]
    fn histogram_buckets_fill_and_accumulate() {
        let h = Histogram::with_bounds(&[10, 100, 1000]);
        h.observe(5); // <= 10
        h.observe(10); // <= 10 (bounds are inclusive upper edges)
        h.observe(99); // <= 100
        h.observe(5000); // +Inf overflow
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 5 + 10 + 99 + 5000);
        let cum = h.cumulative();
        assert_eq!(cum, vec![(Some(10), 2), (Some(100), 3), (Some(1000), 3), (None, 4)]);
    }

    #[test]
    fn latency_bucket_bounds_are_strictly_increasing() {
        assert!(LATENCY_BUCKETS_US.windows(2).all(|w| w[0] < w[1]));
        let h = Histogram::with_bounds(&LATENCY_BUCKETS_US);
        h.observe(0);
        h.observe(u64::MAX / 2);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn labeled_children_are_distinct_and_bounded() {
        let reg = MetricRegistry::new();
        let a = reg.counter_labeled("rns_model_batches_total", "per-model", "model", "mlp");
        let b = reg.counter_labeled("rns_model_batches_total", "per-model", "model", "bert");
        a.inc();
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
        assert_eq!(b.get(), 1);
        // cardinality cap: values beyond MAX_SERIES_PER_FAMILY share the
        // overflow series
        for i in 0..(MAX_SERIES_PER_FAMILY * 2) {
            reg.counter_labeled("rns_model_batches_total", "per-model", "model", &format!("m{i}"))
                .inc();
        }
        let text = reg.render_prometheus();
        let series = text.lines().filter(|l| l.starts_with("rns_model_batches_total{")).count();
        assert!(series <= MAX_SERIES_PER_FAMILY, "{series} series rendered");
        assert!(text.contains(&format!("model=\"{OVERFLOW_LABEL}\"")), "{text}");
    }

    #[test]
    fn prometheus_rendering_grammar() {
        let reg = MetricRegistry::new();
        reg.counter("rns_adc_conversions_total", "ADC conversions").add(700);
        reg.gauge("rns_queue_depth", "queued requests").set(-3);
        let h = reg.histogram_labeled(
            "rns_stage_latency_us",
            "per-stage latency",
            "stage",
            "decode",
            &[10, 100],
        );
        h.observe(7);
        h.observe(50);
        h.observe(900);
        let text = reg.render_prometheus();
        assert!(text.contains("# HELP rns_adc_conversions_total ADC conversions\n"), "{text}");
        assert!(text.contains("# TYPE rns_adc_conversions_total counter\n"), "{text}");
        assert!(text.contains("\nrns_adc_conversions_total 700\n"), "{text}");
        assert!(text.contains("\nrns_queue_depth -3\n"), "{text}");
        assert!(text.contains("# TYPE rns_stage_latency_us histogram\n"), "{text}");
        assert!(text.contains("rns_stage_latency_us_bucket{stage=\"decode\",le=\"10\"} 1\n"));
        assert!(text.contains("rns_stage_latency_us_bucket{stage=\"decode\",le=\"100\"} 2\n"));
        assert!(text.contains("rns_stage_latency_us_bucket{stage=\"decode\",le=\"+Inf\"} 3\n"));
        assert!(text.contains("rns_stage_latency_us_sum{stage=\"decode\"} 957\n"), "{text}");
        assert!(text.contains("rns_stage_latency_us_count{stage=\"decode\"} 3\n"), "{text}");
    }

    #[test]
    fn label_values_are_escaped() {
        let reg = MetricRegistry::new();
        reg.counter_labeled("rns_model_batches_total", "h", "model", "a\"b\\c\nd").inc();
        let text = reg.render_prometheus();
        assert!(text.contains("model=\"a\\\"b\\\\c\\nd\""), "{text}");
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let reg = MetricRegistry::new();
        reg.counter("rns_thing", "h");
        reg.gauge("rns_thing", "h");
    }
}
