//! Deterministic, seedable PRNG (PCG64-DXSM-ish via xoshiro256**).
//!
//! The image vendors no `rand` crate, so the simulator carries its own
//! generator. Determinism in the seed is a hard requirement: every
//! experiment regenerator and every property test reproduces bit-for-bit
//! from its seed, which is what makes EXPERIMENTS.md numbers replayable.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform signed integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.gen_range(span) as i64)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Standard normal deviate (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gen_range_is_in_bounds() {
        let mut rng = Rng::seed_from(1);
        for bound in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(rng.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_i64_inclusive() {
        let mut rng = Rng::seed_from(2);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..2000 {
            let v = rng.gen_range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Rng::seed_from(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(4);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Rng::seed_from(5);
        let hits = (0..50_000).filter(|_| rng.bernoulli(0.3)).count();
        let rate = hits as f64 / 50_000.0;
        assert!((rate - 0.3).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(6);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::seed_from(7);
        let idx = rng.sample_indices(20, 8);
        assert_eq!(idx.len(), 8);
        let mut sorted = idx.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 8);
        assert!(idx.iter().all(|&i| i < 20));
    }
}
