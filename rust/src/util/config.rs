//! TOML-subset config loader (the image vendors no `serde`/`toml`).
//!
//! Supported grammar — enough for accelerator config files:
//!   * `[section]` headers (nesting via `[a.b]`)
//!   * `key = value` with value ∈ {integer, float, bool, "string", [list]}
//!   * `#` comments, blank lines
//!
//! Values are exposed through typed getters with dotted-path lookup
//! (`core.bits`). The parser is strict: malformed lines are errors, not
//! silently skipped.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    List(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Bool(v) => write!(f, "{v}"),
            Value::Str(v) => write!(f, "\"{v}\""),
            Value::List(vs) => {
                write!(f, "[")?;
                for (i, v) in vs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

fn parse_scalar(s: &str, line_no: usize) -> Result<Value, String> {
    let s = s.trim();
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_scalar(part, line_no)?);
            }
        }
        return Ok(Value::List(items));
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(format!("line {line_no}: cannot parse value `{s}`"))
}

impl Config {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = match raw.find('#') {
                // don't treat '#' inside quotes as comment start
                Some(pos) if !raw[..pos].contains('"') => &raw[..pos],
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(format!("line {line_no}: unterminated section header"));
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {line_no}: empty section name"));
                }
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {line_no}: expected `key = value`, got `{line}`"))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            if key.split('.').any(|p| p.is_empty()) {
                return Err(format!("line {line_no}: bad key `{key}`"));
            }
            cfg.entries.insert(key, parse_scalar(v, line_no)?);
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn set(&mut self, key: &str, value: Value) {
        self.entries.insert(key.to_string(), value);
    }

    pub fn int(&self, key: &str) -> Option<i64> {
        match self.entries.get(key) {
            Some(Value::Int(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.int(key).unwrap_or(default)
    }

    pub fn float(&self, key: &str) -> Option<f64> {
        match self.entries.get(key) {
            Some(Value::Float(v)) => Some(*v),
            Some(Value::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.float(key).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.entries.get(key) {
            Some(Value::Bool(v)) => *v,
            _ => default,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        match self.entries.get(key) {
            Some(Value::Str(v)) => v.clone(),
            _ => default.to_string(),
        }
    }

    pub fn int_list(&self, key: &str) -> Option<Vec<i64>> {
        match self.entries.get(key) {
            Some(Value::List(vs)) => vs
                .iter()
                .map(|v| if let Value::Int(i) = v { Some(*i) } else { None })
                .collect(),
            _ => None,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# accelerator config
name = "rns-demo"
[core]
bits = 6
h = 128
noise_p = 1e-4
rrns = true
moduli = [63, 62, 61, 59]
[serve]
max_batch = 8
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "rns-demo");
        assert_eq!(c.int_or("core.bits", 0), 6);
        assert_eq!(c.int_or("core.h", 0), 128);
        assert!((c.float_or("core.noise_p", 0.0) - 1e-4).abs() < 1e-12);
        assert!(c.bool_or("core.rrns", false));
        assert_eq!(c.int_list("core.moduli").unwrap(), vec![63, 62, 61, 59]);
        assert_eq!(c.int_or("serve.max_batch", 0), 8);
    }

    #[test]
    fn defaults_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int_or("nope", 7), 7);
        assert_eq!(c.str_or("nope", "x"), "x");
    }

    #[test]
    fn int_promotes_to_float() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.float_or("x", 0.0), 3.0);
        // but a float does not masquerade as int
        let c = Config::parse("y = 3.5").unwrap();
        assert_eq!(c.int("y"), None);
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Config::parse("just_a_word").is_err());
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("k = @nonsense").is_err());
        assert!(Config::parse("[]").is_err());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let c = Config::parse("# hi\n\na = 1 # trailing\n").unwrap();
        assert_eq!(c.int_or("a", 0), 1);
    }

    #[test]
    fn empty_list() {
        let c = Config::parse("xs = []").unwrap();
        assert_eq!(c.int_list("xs").unwrap(), Vec::<i64>::new());
    }
}
