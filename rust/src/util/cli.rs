//! Minimal argv parser (the image vendors no `clap`).
//!
//! Grammar: `prog <subcommand> [positional...] [--flag] [--key=value | --key value]`.
//! Unknown flags are an error so typos fail loudly in experiment scripts.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags the command declares; used for unknown-flag detection.
    known: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable without touching env).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self, String> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err("bare `--` is not supported".into());
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn parse_env() -> Result<Self, String> {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Declare a flag as known (for `check_unknown`), returning self for chaining.
    pub fn declare(&mut self, name: &str) -> &mut Self {
        self.known.push(name.to_string());
        self
    }

    /// Error if any present flag was never declared.
    pub fn check_unknown(&self) -> Result<(), String> {
        for k in self.flags.keys() {
            if !self.known.iter().any(|n| n == k) {
                return Err(format!("unknown flag --{k} (known: {})", self.known.join(", ")));
            }
        }
        Ok(())
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.declare(name);
        self.flags.get(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    pub fn get(&mut self, name: &str) -> Option<String> {
        self.declare(name);
        self.flags.get(name).cloned()
    }

    pub fn get_or(&mut self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or_else(|| default.to_string())
    }

    pub fn get_parsed<T: std::str::FromStr>(&mut self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| format!("--{name}={v}: {e}")),
        }
    }

    /// Parse a comma-separated list flag, e.g. `--bits=4,6,8`.
    pub fn get_list<T: std::str::FromStr>(&mut self, name: &str, default: &[T]) -> Result<Vec<T>, String>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<T>().map_err(|e| format!("--{name}: `{s}`: {e}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let mut a = Args::parse_from(toks("exp fig3 --bits=4,6 --seed 7 --verbose")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["fig3"]);
        assert_eq!(a.get_list::<u32>("bits", &[8]).unwrap(), vec![4, 6]);
        assert_eq!(a.get_parsed::<u64>("seed", 0).unwrap(), 7);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse_from(toks("serve")).unwrap();
        assert_eq!(a.get_parsed::<u32>("port", 8080).unwrap(), 8080);
        assert_eq!(a.get_or("model", "mlp"), "mlp");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_flag_detection() {
        let mut a = Args::parse_from(toks("exp --bogus=1")).unwrap();
        a.declare("bits");
        assert!(a.check_unknown().is_err());
        let mut b = Args::parse_from(toks("exp --bits=4")).unwrap();
        b.declare("bits");
        assert!(b.check_unknown().is_ok());
    }

    #[test]
    fn bad_parse_is_error() {
        let mut a = Args::parse_from(toks("x --n=abc")).unwrap();
        assert!(a.get_parsed::<u32>("n", 1).is_err());
    }

    #[test]
    fn boolean_flag_before_positional() {
        // `--flag value`: value is consumed as the flag's value
        let mut a = Args::parse_from(toks("exp --fast fig5")).unwrap();
        assert_eq!(a.get_or("fast", ""), "fig5");
        assert!(a.positional.is_empty());
    }
}
