//! Shared infrastructure substrates: PRNG, statistics, CLI parsing, config
//! files, and property-testing — all hand-rolled because the offline image
//! vendors no `rand`/`clap`/`serde`/`proptest`.

pub mod cli;
pub mod config;
pub mod logging;
pub mod metrics;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod trace;

/// Format a `f64` in engineering notation with an SI-ish suffix
/// (used by the energy reports: fJ/pJ/nJ/µJ).
pub fn format_si(value: f64, unit: &str) -> String {
    let (scaled, prefix) = if value == 0.0 {
        (0.0, "")
    } else {
        let exp = value.abs().log10().floor() as i32;
        match exp {
            i32::MIN..=-16 => (value * 1e18, "a"),
            -15..=-13 => (value * 1e15, "f"),
            -12..=-10 => (value * 1e12, "p"),
            -9..=-7 => (value * 1e9, "n"),
            -6..=-4 => (value * 1e6, "µ"),
            -3..=-1 => (value * 1e3, "m"),
            0..=2 => (value, ""),
            3..=5 => (value * 1e-3, "k"),
            6..=8 => (value * 1e-6, "M"),
            _ => (value * 1e-9, "G"),
        }
    };
    format!("{scaled:.3} {prefix}{unit}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn si_formatting() {
        assert_eq!(format_si(1.5e-15, "J"), "1.500 fJ");
        assert_eq!(format_si(2.0e-12, "J"), "2.000 pJ");
        assert_eq!(format_si(0.0, "J"), "0.000 J");
        assert_eq!(format_si(4.2e6, "Op/s"), "4.200 MOp/s");
    }
}
