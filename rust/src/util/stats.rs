//! Small statistics helpers used by the experiment harness and the bench
//! harness: summaries, histograms, and percentile estimation — plus the
//! bounded `Reservoir` the serving stack uses for all-time percentiles.

use crate::util::rng::Rng;

/// Streaming summary of a sample (count / mean / min / max / variance via
/// Welford's algorithm).  NaN inputs are skipped and counted rather than
/// folded in: a NaN would poison mean/m2 forever while min/max silently
/// dropped it (f64::min/max ignore NaN), leaving the summary self-
/// inconsistent.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    nans: u64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, nans: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x.is_nan() {
            self.nans += 1;
            return;
        }
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// NaN samples rejected by `add` (not part of `count`).
    pub fn nan_count(&self) -> u64 {
        self.nans
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Exact percentile over a stored sample (fine at experiment scales).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Linear-interpolated percentile, `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = pos - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }
}

/// Bounded percentile estimator: Vitter's Algorithm R over a fixed-size
/// reservoir, seeded for reproducibility.  Long-lived servers feed every
/// latency sample through this instead of an unbounded `Percentiles`
/// vector — memory stays O(capacity) forever while each of the first
/// `seen` samples still had an equal chance of being retained.
#[derive(Clone, Debug)]
pub struct Reservoir {
    samples: Vec<f64>,
    capacity: usize,
    seen: u64,
    rng: Rng,
}

impl Reservoir {
    pub fn new(capacity: usize, seed: u64) -> Self {
        assert!(capacity > 0, "reservoir capacity must be positive");
        Reservoir { samples: Vec::new(), capacity, seen: 0, rng: Rng::seed_from(seed) }
    }

    pub fn add(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.capacity {
            self.samples.push(x);
        } else {
            let j = self.rng.gen_range(self.seen) as usize;
            if j < self.capacity {
                self.samples[j] = x;
            }
        }
    }

    /// Total samples offered (not just the retained subset).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Linear-interpolated percentile over the retained sample, `q` in
    /// [0, 100]; exact until `capacity` samples, an unbiased estimate after.
    pub fn percentile(&self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q / 100.0 * (xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            xs[lo]
        } else {
            let frac = pos - lo as f64;
            xs[lo] * (1.0 - frac) + xs[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Fixed-bin histogram over `[lo, hi)`; out-of-range values clamp to the
/// edge bins (used for the Fig. 3 error-distribution plots).  NaN samples
/// are skipped and counted — the float-to-int cast used to misfile them
/// into bin 0 (`NaN as i64 == 0`), silently inflating the first bin.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    /// NaN samples rejected by `add` (not in any bin nor `total`).
    pub nans: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], nans: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x.is_nan() {
            self.nans += 1;
            return;
        }
        let nb = self.bins.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * nb as f64).floor() as i64).clamp(0, nb as i64 - 1) as usize;
        self.bins[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }

    /// Render as a fixed-width ASCII sparkline (for report output).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&b| {
                if b == 0 {
                    ' '
                } else {
                    GLYPHS[((b as f64 / max as f64) * 7.0).round() as usize]
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for x in [10.0, 20.0, 30.0, 40.0, 50.0] {
            p.add(x);
        }
        assert_eq!(p.median(), 30.0);
        assert_eq!(p.percentile(0.0), 10.0);
        assert_eq!(p.percentile(100.0), 50.0);
        assert!((p.percentile(25.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.5);
        h.add(-100.0); // clamps to first bin
        h.add(100.0); // clamps to last bin
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn summary_skips_and_counts_nan() {
        let mut s = Summary::new();
        s.add(1.0);
        s.add(f64::NAN);
        s.add(3.0);
        assert_eq!(s.count(), 2);
        assert_eq!(s.nan_count(), 1);
        assert!((s.mean() - 2.0).abs() < 1e-12, "mean must not be poisoned");
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert!(s.variance().is_finite());
    }

    #[test]
    fn histogram_skips_and_counts_nan() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(f64::NAN);
        h.add(0.5);
        assert_eq!(h.bins[0], 1, "NaN must not be misfiled into bin 0");
        assert_eq!(h.total(), 1);
        assert_eq!(h.nans, 1);
    }

    #[test]
    fn empty_structures_are_safe() {
        let s = Summary::new();
        assert_eq!(s.min(), 0.0);
        let mut p = Percentiles::new();
        assert_eq!(p.median(), 0.0);
        let r = Reservoir::new(8, 0);
        assert_eq!(r.median(), 0.0);
        assert!(r.is_empty());
    }

    #[test]
    fn reservoir_is_exact_below_capacity() {
        let mut r = Reservoir::new(100, 1);
        for x in [10.0, 20.0, 30.0, 40.0, 50.0] {
            r.add(x);
        }
        assert_eq!(r.seen(), 5);
        assert_eq!(r.median(), 30.0);
        assert_eq!(r.percentile(0.0), 10.0);
        assert_eq!(r.percentile(100.0), 50.0);
    }

    #[test]
    fn reservoir_bounds_memory_and_estimates_percentiles() {
        let mut r = Reservoir::new(256, 2);
        for i in 0..100_000u64 {
            r.add(i as f64);
        }
        assert_eq!(r.seen(), 100_000);
        // the retained sample stays at capacity; the median of a uniform
        // 0..100k stream should land near 50k
        let med = r.median();
        assert!((30_000.0..70_000.0).contains(&med), "median {med}");
    }

    #[test]
    fn reservoir_is_deterministic_in_seed() {
        let mut a = Reservoir::new(64, 7);
        let mut b = Reservoir::new(64, 7);
        for i in 0..10_000u64 {
            a.add((i % 977) as f64);
            b.add((i % 977) as f64);
        }
        assert_eq!(a.percentile(95.0), b.percentile(95.0));
    }
}
