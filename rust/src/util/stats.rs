//! Small statistics helpers used by the experiment harness and the bench
//! harness: summaries, histograms, and percentile estimation.

/// Streaming summary of a sample (count / mean / min / max / variance via
/// Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Exact percentile over a stored sample (fine at experiment scales).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Linear-interpolated percentile, `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = pos - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }
}

/// Fixed-bin histogram over `[lo, hi)`; out-of-range values clamp to the
/// edge bins (used for the Fig. 3 error-distribution plots).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins] }
    }

    pub fn add(&mut self, x: f64) {
        let nb = self.bins.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * nb as f64).floor() as i64).clamp(0, nb as i64 - 1) as usize;
        self.bins[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }

    /// Render as a fixed-width ASCII sparkline (for report output).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&b| {
                if b == 0 {
                    ' '
                } else {
                    GLYPHS[((b as f64 / max as f64) * 7.0).round() as usize]
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for x in [10.0, 20.0, 30.0, 40.0, 50.0] {
            p.add(x);
        }
        assert_eq!(p.median(), 30.0);
        assert_eq!(p.percentile(0.0), 10.0);
        assert_eq!(p.percentile(100.0), 50.0);
        assert!((p.percentile(25.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.5);
        h.add(-100.0); // clamps to first bin
        h.add(100.0); // clamps to last bin
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn empty_structures_are_safe() {
        let s = Summary::new();
        assert_eq!(s.min(), 0.0);
        let mut p = Percentiles::new();
        assert_eq!(p.median(), 0.0);
    }
}
