//! Minimal leveled logger (no `log`/`env_logger` facade wiring needed for
//! a single binary; the vendored `log` crate is unused by our deps' public
//! APIs).  Level comes from `RNS_LOG` (error|warn|info|debug|trace),
//! default `info`.  Output goes to stderr with a monotonic timestamp so
//! serving logs interleave meaningfully across threads.
//!
//! `RNS_LOG_FORMAT=json` switches every line to one self-contained JSON
//! object (`{"ts":…,"level":…,"target":…,"msg":…}`) so fleet log
//! ingestion doesn't re-parse the human format; the default human format
//! is unchanged.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, PartialOrd)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Output format: human-readable bracketed lines (default) or one JSON
/// object per line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Format {
    Human = 0,
    Json = 1,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static FORMAT: AtomicU8 = AtomicU8::new(0); // Human
static EPOCH: OnceLock<Instant> = OnceLock::new();
static INIT: OnceLock<()> = OnceLock::new();

/// Initialize from `RNS_LOG` / `RNS_LOG_FORMAT` (idempotent; called
/// lazily by `enabled`).
pub fn init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("RNS_LOG") {
            if let Some(l) = Level::parse(&v) {
                MAX_LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
        if let Ok(v) = std::env::var("RNS_LOG_FORMAT") {
            if v.eq_ignore_ascii_case("json") {
                FORMAT.store(Format::Json as u8, Ordering::Relaxed);
            }
        }
        EPOCH.get_or_init(Instant::now);
    });
}

/// Override the level programmatically (tests, CLI flags).
pub fn set_level(level: Level) {
    init();
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Override the output format programmatically (tests, CLI flags).
pub fn set_format(format: Format) {
    init();
    FORMAT.store(format as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    init();
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Core emit function used by the macros.
pub fn emit(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = EPOCH.get_or_init(Instant::now).elapsed();
    if FORMAT.load(Ordering::Relaxed) == Format::Json as u8 {
        eprintln!(
            "{{\"ts\":{:.3},\"level\":\"{}\",\"target\":\"{}\",\"msg\":\"{}\"}}",
            t.as_secs_f64(),
            level.name(),
            json_escape(target),
            json_escape(&msg.to_string()),
        );
    } else {
        eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), level.tag(), target, msg);
    }
}

/// A typed value for [`emit_fields`]: numbers stay unquoted in JSON
/// output so ingestion pipelines can aggregate without re-parsing.
pub enum FieldValue {
    Num(u64),
    Text(String),
}

/// Structured emit: the message plus typed key/value fields.  In JSON
/// mode the fields land as native object members next to `msg`; in
/// human mode they render as trailing `key=value` tokens.  This is the
/// gateway access-log path (`path`/`status`/`bytes`/`micros` per HTTP
/// request).
pub fn emit_fields(level: Level, target: &str, msg: &str, fields: &[(&str, FieldValue)]) {
    if !enabled(level) {
        return;
    }
    let t = EPOCH.get_or_init(Instant::now).elapsed();
    if FORMAT.load(Ordering::Relaxed) == Format::Json as u8 {
        let mut line = format!(
            "{{\"ts\":{:.3},\"level\":\"{}\",\"target\":\"{}\",\"msg\":\"{}\"",
            t.as_secs_f64(),
            level.name(),
            json_escape(target),
            json_escape(msg),
        );
        for (k, v) in fields {
            match v {
                FieldValue::Num(n) => line.push_str(&format!(",\"{}\":{n}", json_escape(k))),
                FieldValue::Text(s) => {
                    line.push_str(&format!(",\"{}\":\"{}\"", json_escape(k), json_escape(s)))
                }
            }
        }
        line.push('}');
        eprintln!("{line}");
    } else {
        let mut tail = String::new();
        for (k, v) in fields {
            match v {
                FieldValue::Num(n) => tail.push_str(&format!(" {k}={n}")),
                FieldValue::Text(s) => tail.push_str(&format!(" {k}={s}")),
            }
        }
        eprintln!("[{:>9.3}s {} {}] {}{}", t.as_secs_f64(), level.tag(), target, msg, tail);
    }
}

/// Minimal JSON string escaping (hand-rolled; no serde in the image):
/// backslash, quote, and control characters.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

#[macro_export]
macro_rules! log_error { ($tgt:expr, $($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, $tgt, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($tgt:expr, $($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, $tgt, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($tgt:expr, $($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, $tgt, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($tgt:expr, $($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, $tgt, format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        // restore default for other tests
        set_level(Level::Info);
    }

    #[test]
    fn emit_does_not_panic() {
        set_level(Level::Info);
        emit(Level::Info, "test", format_args!("hello {}", 42));
        emit(Level::Trace, "test", format_args!("filtered"));
    }

    #[test]
    fn json_escaping_covers_specials_and_controls() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("line\nfeed\ttab\rret"), "line\\nfeed\\ttab\\rret");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn json_emit_does_not_panic_and_restores_format() {
        set_level(Level::Info);
        set_format(Format::Json);
        emit(Level::Info, "gate\"way", format_args!("msg with \"quotes\" and \\slashes\\"));
        set_format(Format::Human);
    }

    #[test]
    fn emit_fields_renders_in_both_formats() {
        set_level(Level::Info);
        let fields = [
            ("path", FieldValue::Text("/metrics".into())),
            ("status", FieldValue::Num(200)),
            ("bytes", FieldValue::Num(1234)),
            ("micros", FieldValue::Num(87)),
        ];
        emit_fields(Level::Info, "gateway", "http", &fields);
        set_format(Format::Json);
        emit_fields(Level::Info, "gateway", "http", &fields);
        set_format(Format::Human);
        // gated out entirely below the level threshold
        emit_fields(Level::Trace, "gateway", "filtered", &fields);
    }
}
