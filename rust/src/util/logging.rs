//! Minimal leveled logger (no `log`/`env_logger` facade wiring needed for
//! a single binary; the vendored `log` crate is unused by our deps' public
//! APIs).  Level comes from `RNS_LOG` (error|warn|info|debug|trace),
//! default `info`.  Output goes to stderr with a monotonic timestamp so
//! serving logs interleave meaningfully across threads.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, PartialOrd)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static EPOCH: OnceLock<Instant> = OnceLock::new();
static INIT: OnceLock<()> = OnceLock::new();

/// Initialize from `RNS_LOG` (idempotent; called lazily by `enabled`).
pub fn init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("RNS_LOG") {
            if let Some(l) = Level::parse(&v) {
                MAX_LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
        EPOCH.get_or_init(Instant::now);
    });
}

/// Override the level programmatically (tests, CLI flags).
pub fn set_level(level: Level) {
    init();
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    init();
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Core emit function used by the macros.
pub fn emit(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = EPOCH.get_or_init(Instant::now).elapsed();
    eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), level.tag(), target, msg);
}

#[macro_export]
macro_rules! log_error { ($tgt:expr, $($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, $tgt, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($tgt:expr, $($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, $tgt, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($tgt:expr, $($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, $tgt, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($tgt:expr, $($arg:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, $tgt, format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        // restore default for other tests
        set_level(Level::Info);
    }

    #[test]
    fn emit_does_not_panic() {
        set_level(Level::Info);
        emit(Level::Info, "test", format_args!("hello {}", 42));
        emit(Level::Trace, "test", format_args!("filtered"));
    }
}
