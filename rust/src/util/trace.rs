//! End-to-end request tracing: sampled, bounded span trees that follow a
//! single request across every serving tier.
//!
//! A sampled request carries a nonzero **trace id** on the wire (additive
//! v2 `Infer`/`InferOk` field): either the client requested sampling by
//! sending one, or the gateway drew one from the seeded [`TraceCollector`]
//! sampler at admission. Each tier then records typed [`Span`]s — epoch-
//! relative monotonic timestamps in microseconds — against that id:
//!
//! | tier         | spans                                          | track |
//! |--------------|------------------------------------------------|-------|
//! | gateway loop | `assemble`, `admission`, `write_flush`, `session` (root) | 0 |
//! | batcher      | `queue`, `batch_form`                          | 1     |
//! | worker *w*   | `batch`, `dac_forward`, `analog_gemm`, `adc_capture`, `decode`, `delivery` | 10+*w* |
//!
//! The stage spans are recorded from the **same** computed values the
//! `rns_stage_latency_us` histograms observe (see `serve_batch`), so the
//! histogram and span views can never disagree about a request.
//!
//! Memory is bounded everywhere: at most [`TraceCollector::MAX_PENDING`]
//! in-flight traces (drop-oldest), [`TraceCollector::MAX_SPANS`] spans per
//! trace, and `slots` completed trees kept slowest-first — the same
//! keep-the-slowest-N policy as the `TraceRing` line summaries, which the
//! span trees complement rather than replace (the ring summarizes every
//! slow request in one line; the collector keeps full trees for sampled
//! ones). Requests that fail with `DeadlineExceeded`/`Poisoned` are
//! force-completed into trees even when unsampled.
//!
//! See DESIGN.md §6f for the ownership diagram and the sampling /
//! bounded-memory invariants.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Span names. The pipeline-stage names are byte-identical to the
/// `rns_stage_latency_us{stage=...}` labels so dashboards and traces
/// speak one vocabulary.
pub const SPAN_SESSION: &str = "session";
pub const SPAN_ASSEMBLE: &str = "assemble";
pub const SPAN_ADMISSION: &str = "admission";
pub const SPAN_QUEUE: &str = "queue";
pub const SPAN_BATCH_FORM: &str = "batch_form";
pub const SPAN_BATCH: &str = "batch";
pub const SPAN_DAC_FORWARD: &str = "dac_forward";
pub const SPAN_ANALOG_GEMM: &str = "analog_gemm";
pub const SPAN_ADC_CAPTURE: &str = "adc_capture";
pub const SPAN_DECODE: &str = "decode";
pub const SPAN_DELIVERY: &str = "delivery";
pub const SPAN_WRITE_FLUSH: &str = "write_flush";

/// Chrome-trace thread tracks: the gateway readiness loops share track 0,
/// the batcher/dispatcher is track 1, worker `w` is `WORKER_TID_BASE + w`.
pub const GATEWAY_TID: u32 = 0;
pub const BATCHER_TID: u32 = 1;
pub const WORKER_TID_BASE: u32 = 10;

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process-wide monotonic epoch every span timestamp is relative to.
/// Anchored eagerly by [`TraceCollector::new`] (i.e. at coordinator
/// startup) so request instants are always at or after it.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds elapsed since the trace epoch.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Convert a captured `Instant` to epoch-relative microseconds
/// (saturating to 0 for instants predating the epoch).
pub fn us_since_epoch(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_micros() as u64
}

/// One timed unit of work attributed to a trace.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Span {
    pub name: &'static str,
    /// Chrome-trace thread track (which serving tier ran this span).
    pub tid: u32,
    /// Epoch-relative start, microseconds.
    pub start_us: u64,
    pub dur_us: u64,
    /// Extra numeric annotations (e.g. batch size / member index).
    pub args: Vec<(&'static str, u64)>,
}

impl Span {
    pub fn new(name: &'static str, tid: u32, start_us: u64, dur_us: u64) -> Self {
        Span { name, tid, start_us, dur_us, args: Vec::new() }
    }

    pub fn with_args(mut self, args: &[(&'static str, u64)]) -> Self {
        self.args = args.to_vec();
        self
    }

    /// Exclusive end of the span on the shared epoch clock.
    pub fn end_us(&self) -> u64 {
        self.start_us.saturating_add(self.dur_us)
    }
}

/// A completed, assembled span tree for one request.
#[derive(Clone, Debug)]
pub struct TraceTree {
    pub id: u64,
    pub model: String,
    pub start_us: u64,
    pub total_us: u64,
    /// True when completion was forced (deadline exceeded / poisoned)
    /// rather than observed at reply flush.
    pub forced: bool,
    /// All recorded spans; the first is the synthesized `session` root,
    /// which contains every other span by construction.
    pub spans: Vec<Span>,
}

impl TraceTree {
    /// The non-container span with the largest duration — where this
    /// request actually spent its time. Container spans (`session`,
    /// `batch`) are excluded.
    pub fn dominant(&self) -> Option<&Span> {
        self.spans
            .iter()
            .filter(|s| s.name != SPAN_SESSION && s.name != SPAN_BATCH)
            .max_by_key(|s| s.dur_us)
    }
}

/// Counters describing collector activity (exported as `rns_trace_*`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStats {
    pub sampled: u64,
    pub forced: u64,
    pub dropped: u64,
    pub kept: usize,
    pub pending: usize,
}

struct PendingTrace {
    model: String,
    start_us: u64,
    spans: Vec<Span>,
}

struct Inner {
    pending: HashMap<u64, PendingTrace>,
    /// Insertion order of pending ids, for drop-oldest eviction.
    order: VecDeque<u64>,
    /// Completed trees, unordered; keep-slowest-N by `total_us`.
    done: Vec<TraceTree>,
}

/// Process-wide trace assembly: seeded sampling, bounded pending state,
/// keep-slowest-N completed trees, Chrome-trace / text rendering.
pub struct TraceCollector {
    slots: usize,
    sample_rate: f64,
    seed: u64,
    /// Sampling threshold on a 64-bit hash; 0 = never, `u64::MAX` = always.
    threshold: u64,
    draws: AtomicU64,
    forced_ids: AtomicU64,
    sampled: AtomicU64,
    forced: AtomicU64,
    dropped: AtomicU64,
    inner: Mutex<Inner>,
}

impl TraceCollector {
    /// In-flight (begun, not completed) traces retained; oldest dropped.
    pub const MAX_PENDING: usize = 128;
    /// Spans retained per trace; extras are dropped, not reallocated.
    pub const MAX_SPANS: usize = 64;

    /// `slots` completed trees kept (0 disables the collector entirely),
    /// `sample` in `[0, 1]` is the fraction of requests drawn by
    /// [`sample`](Self::sample), decided by a seeded hash so runs are
    /// reproducible.
    pub fn new(slots: usize, sample: f64, seed: u64) -> Self {
        epoch(); // anchor before any request timestamps exist
        let rate = sample.clamp(0.0, 1.0);
        let threshold = if rate >= 1.0 {
            u64::MAX
        } else if rate <= 0.0 {
            0
        } else {
            (rate * (u64::MAX as f64)) as u64
        };
        TraceCollector {
            slots,
            sample_rate: rate,
            seed,
            threshold,
            draws: AtomicU64::new(0),
            forced_ids: AtomicU64::new(0),
            sampled: AtomicU64::new(0),
            forced: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                pending: HashMap::new(),
                order: VecDeque::new(),
                done: Vec::new(),
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots
    }

    pub fn sample_rate(&self) -> f64 {
        self.sample_rate
    }

    /// False when `slots == 0`: every operation is a no-op and
    /// [`sample`](Self::sample) always returns 0.
    pub fn enabled(&self) -> bool {
        self.slots > 0
    }

    /// Draw the sampling decision for one request: a fresh nonzero trace
    /// id when sampled, 0 otherwise. Deterministic in (seed, draw index);
    /// the unsampled fast path (`sample = 0`) touches no shared state.
    pub fn sample(&self) -> u64 {
        if self.threshold == 0 || !self.enabled() {
            return 0;
        }
        let n = self.draws.fetch_add(1, Ordering::Relaxed);
        let h = splitmix64(self.seed ^ n.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1));
        if self.threshold == u64::MAX || h < self.threshold {
            self.sampled.fetch_add(1, Ordering::Relaxed);
            h | 1
        } else {
            0
        }
    }

    /// A synthesized id for force-completed traces of unsampled requests
    /// (high bit set so they are visually distinct from sampled hashes).
    pub fn forced_id(&self) -> u64 {
        (1u64 << 63) | self.forced_ids.fetch_add(1, Ordering::Relaxed).wrapping_add(1)
    }

    /// Open a pending trace. Idempotent for an already-open id; evicts
    /// the oldest pending trace at [`MAX_PENDING`](Self::MAX_PENDING).
    pub fn begin(&self, id: u64, model: &str, start_us: u64) {
        if id == 0 || !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.pending.contains_key(&id) {
            return;
        }
        while inner.pending.len() >= Self::MAX_PENDING {
            match inner.order.pop_front() {
                Some(old) => {
                    if inner.pending.remove(&old).is_some() {
                        self.dropped.fetch_add(1, Ordering::Relaxed);
                    }
                }
                None => break,
            }
        }
        inner.order.push_back(id);
        inner.pending.insert(
            id,
            PendingTrace { model: model.to_string(), start_us, spans: Vec::new() },
        );
    }

    /// Append one span to a pending trace (no-op if the id is unknown —
    /// e.g. evicted, or never sampled).
    pub fn record(&self, id: u64, span: Span) {
        self.record_batch(std::iter::once((id, span)));
    }

    /// Append several spans to one pending trace under a single lock.
    pub fn record_all(&self, id: u64, spans: &[Span]) {
        self.record_batch(spans.iter().map(|s| (id, s.clone())));
    }

    /// Append (id, span) pairs — possibly for different ids — under a
    /// single lock. This is what [`SpanBuffer::flush`] calls.
    pub fn record_batch<I: IntoIterator<Item = (u64, Span)>>(&self, entries: I) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        for (id, span) in entries {
            if let Some(p) = inner.pending.get_mut(&id) {
                if p.spans.len() < Self::MAX_SPANS {
                    p.spans.push(span);
                } else {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Close a pending trace at `end_us`: synthesize the `session` root
    /// span covering every recorded span and move the tree into the
    /// keep-slowest-N set. Returns false if the id was not pending.
    pub fn complete(&self, id: u64, end_us: u64) -> bool {
        if !self.enabled() {
            return false;
        }
        let mut inner = self.inner.lock().unwrap();
        let Some(p) = inner.pending.remove(&id) else {
            return false;
        };
        let tree = assemble(id, p.model, p.start_us, end_us, p.spans, false);
        self.keep_slowest(&mut inner, tree);
        true
    }

    /// Force-complete a trace that failed (deadline exceeded, poisoned):
    /// merges with any pending state for `id`, accepts `id == 0` for
    /// unsampled requests (a [`forced_id`](Self::forced_id) is drawn),
    /// and returns the id actually used (0 when disabled).
    pub fn force(
        &self,
        id: u64,
        model: &str,
        start_us: u64,
        end_us: u64,
        spans: Vec<Span>,
    ) -> u64 {
        if !self.enabled() {
            return 0;
        }
        let id = if id == 0 { self.forced_id() } else { id };
        self.forced.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let (model, start_us, all) = match inner.pending.remove(&id) {
            Some(mut p) => {
                p.spans.extend(spans);
                p.spans.truncate(Self::MAX_SPANS);
                (p.model, p.start_us.min(start_us), p.spans)
            }
            None => (model.to_string(), start_us, spans),
        };
        let tree = assemble(id, model, start_us, end_us, all, true);
        self.keep_slowest(&mut inner, tree);
        id
    }

    fn keep_slowest(&self, inner: &mut Inner, tree: TraceTree) {
        if self.slots == 0 {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if inner.done.len() < self.slots {
            inner.done.push(tree);
            return;
        }
        // full: replace the current fastest only if this one is slower
        let (idx, fastest) = inner
            .done
            .iter()
            .enumerate()
            .min_by_key(|(_, t)| t.total_us)
            .map(|(i, t)| (i, t.total_us))
            .expect("done is non-empty when full");
        if tree.total_us > fastest {
            inner.done[idx] = tree;
        }
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Completed trees, slowest first.
    pub fn trees(&self) -> Vec<TraceTree> {
        let inner = self.inner.lock().unwrap();
        let mut out = inner.done.clone();
        out.sort_by(|a, b| b.total_us.cmp(&a.total_us));
        out
    }

    pub fn stats(&self) -> TraceStats {
        let inner = self.inner.lock().unwrap();
        TraceStats {
            sampled: self.sampled.load(Ordering::Relaxed),
            forced: self.forced.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            kept: inner.done.len(),
            pending: inner.pending.len(),
        }
    }

    /// Greppable key=value text: one header line plus one `span-trace:`
    /// line per kept tree, slowest first. Parse lines back with
    /// [`parse_summary_line`].
    pub fn summary(&self) -> String {
        let stats = self.stats();
        let trees = self.trees();
        let mut out = format!(
            "trace spans: kept={} cap={} sample={:.4} sampled={} forced={} dropped={}\n",
            stats.kept, self.slots, self.sample_rate, stats.sampled, stats.forced, stats.dropped,
        );
        for t in &trees {
            out.push_str(&format!(
                "span-trace: id={:#018x} model={} forced={} total={}µs",
                t.id,
                t.model,
                u8::from(t.forced),
                t.total_us
            ));
            for s in &t.spans {
                if s.name == SPAN_SESSION {
                    continue;
                }
                out.push_str(&format!(" {}={}µs", s.name, s.dur_us));
            }
            if let Some(d) = t.dominant() {
                out.push_str(&format!(" dominant={}", d.name));
            }
            out.push('\n');
        }
        out
    }

    /// Chrome trace-event JSON (load in Perfetto / `chrome://tracing`):
    /// a flat array of `"ph":"X"` complete events (µs timestamps) plus
    /// `"ph":"M"` thread-name metadata, one pid, tids per serving tier.
    pub fn chrome_json(&self) -> String {
        let trees = self.trees();
        let mut tids: Vec<u32> = Vec::new();
        for t in &trees {
            for s in &t.spans {
                if !tids.contains(&s.tid) {
                    tids.push(s.tid);
                }
            }
        }
        tids.sort_unstable();
        let mut events: Vec<String> = Vec::new();
        for tid in &tids {
            let name = match *tid {
                GATEWAY_TID => "gateway-loop".to_string(),
                BATCHER_TID => "batcher".to_string(),
                w => format!("worker-{}", w - WORKER_TID_BASE),
            };
            events.push(format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
                 \"args\":{{\"name\":\"{}\"}}}}",
                tid,
                json_escape(&name)
            ));
        }
        for t in &trees {
            for s in &t.spans {
                let mut args = format!(
                    "\"trace\":\"{:#018x}\",\"model\":\"{}\",\"forced\":{}",
                    t.id,
                    json_escape(&t.model),
                    u8::from(t.forced)
                );
                for (k, v) in &s.args {
                    args.push_str(&format!(",\"{k}\":{v}"));
                }
                events.push(format!(
                    "{{\"name\":\"{}\",\"cat\":\"rns\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                     \"pid\":1,\"tid\":{},\"args\":{{{}}}}}",
                    s.name, s.start_us, s.dur_us, s.tid, args
                ));
            }
        }
        format!("[{}]", events.join(",\n"))
    }
}

/// Build the completed tree: the synthesized `session` root is widened to
/// contain every recorded span, so nesting holds by construction even
/// when a tier's clock reading straddled the nominal end.
fn assemble(
    id: u64,
    model: String,
    start_us: u64,
    end_us: u64,
    spans: Vec<Span>,
    forced: bool,
) -> TraceTree {
    let lo = spans.iter().map(|s| s.start_us).min().unwrap_or(start_us).min(start_us);
    let hi = spans.iter().map(|s| s.end_us()).max().unwrap_or(end_us).max(end_us).max(lo);
    let mut all = Vec::with_capacity(spans.len() + 1);
    all.push(Span::new(SPAN_SESSION, GATEWAY_TID, lo, hi - lo));
    all.extend(spans);
    TraceTree { id, model, start_us: lo, total_us: hi - lo, forced, spans: all }
}

/// A per-thread bounded staging buffer: tiers push spans locally and
/// flush them to the collector in one lock acquisition at hand-off
/// boundaries (end of a readiness-loop sweep, end of a batch).
pub struct SpanBuffer {
    entries: Vec<(u64, Span)>,
}

impl SpanBuffer {
    /// Spans staged before overflow drops the excess.
    pub const CAP: usize = 256;

    pub fn new() -> Self {
        SpanBuffer { entries: Vec::new() }
    }

    pub fn push(&mut self, id: u64, span: Span) {
        if id != 0 && self.entries.len() < Self::CAP {
            self.entries.push((id, span));
        }
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn flush(&mut self, collector: &TraceCollector) {
        if !self.entries.is_empty() {
            collector.record_batch(self.entries.drain(..));
        }
    }
}

impl Default for SpanBuffer {
    fn default() -> Self {
        Self::new()
    }
}

/// One `span-trace:` summary line, parsed back (the loadgen report joins
/// client-observed latency with these).
#[derive(Clone, Debug, PartialEq)]
pub struct SummaryEntry {
    pub id: u64,
    pub total_us: u64,
    pub forced: bool,
    pub dominant: Option<String>,
}

/// Parse one line of [`TraceCollector::summary`] output; returns `None`
/// for the header and anything else that is not a `span-trace:` line.
pub fn parse_summary_line(line: &str) -> Option<SummaryEntry> {
    let rest = line.trim().strip_prefix("span-trace: ")?;
    let mut id = None;
    let mut total_us = None;
    let mut forced = false;
    let mut dominant = None;
    for tok in rest.split_whitespace() {
        if let Some(v) = tok.strip_prefix("id=0x") {
            id = u64::from_str_radix(v, 16).ok();
        } else if let Some(v) = tok.strip_prefix("total=") {
            total_us = v.strip_suffix("µs").and_then(|n| n.parse::<u64>().ok());
        } else if let Some(v) = tok.strip_prefix("forced=") {
            forced = v == "1";
        } else if let Some(v) = tok.strip_prefix("dominant=") {
            dominant = Some(v.to_string());
        }
    }
    Some(SummaryEntry { id: id?, total_us: total_us?, forced, dominant })
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &'static str, tid: u32, start: u64, dur: u64) -> Span {
        Span::new(name, tid, start, dur)
    }

    #[test]
    fn sampling_is_seeded_and_deterministic() {
        let a = TraceCollector::new(8, 0.5, 42);
        let b = TraceCollector::new(8, 0.5, 42);
        let da: Vec<u64> = (0..64).map(|_| a.sample()).collect();
        let db: Vec<u64> = (0..64).map(|_| b.sample()).collect();
        assert_eq!(da, db, "same seed, same draws");
        let hits = da.iter().filter(|&&id| id != 0).count();
        assert!(hits > 8 && hits < 56, "p=0.5 over 64 draws, got {hits}");
        let c = TraceCollector::new(8, 0.5, 43);
        let dc: Vec<u64> = (0..64).map(|_| c.sample()).collect();
        assert_ne!(da, dc, "different seed, different draws");
    }

    #[test]
    fn sample_rate_edges() {
        let off = TraceCollector::new(8, 0.0, 1);
        assert!((0..100).all(|_| off.sample() == 0), "rate 0 never samples");
        let on = TraceCollector::new(8, 1.0, 1);
        assert!((0..100).all(|_| on.sample() != 0), "rate 1 always samples");
        let disabled = TraceCollector::new(0, 1.0, 1);
        assert_eq!(disabled.sample(), 0, "slots=0 disables sampling too");
        assert!(!disabled.enabled());
    }

    #[test]
    fn complete_synthesizes_a_containing_session_root() {
        let c = TraceCollector::new(4, 0.0, 7);
        c.begin(9, "mlp", 100);
        c.record(9, span(SPAN_ADMISSION, GATEWAY_TID, 110, 5));
        c.record(9, span(SPAN_QUEUE, BATCHER_TID, 120, 40));
        assert!(c.complete(9, 150));
        assert!(!c.complete(9, 150), "already completed");
        let trees = c.trees();
        assert_eq!(trees.len(), 1);
        let t = &trees[0];
        assert_eq!(t.id, 9);
        assert_eq!(t.model, "mlp");
        assert!(!t.forced);
        assert_eq!(t.spans[0].name, SPAN_SESSION);
        // queue ends at 160 > nominal end 150: root widens to contain it
        assert_eq!(t.spans[0].start_us, 100);
        assert_eq!(t.spans[0].dur_us, 60);
        assert_eq!(t.total_us, 60);
        for s in &t.spans {
            assert!(s.start_us >= t.spans[0].start_us);
            assert!(s.end_us() <= t.spans[0].end_us());
        }
        assert_eq!(t.dominant().unwrap().name, SPAN_QUEUE);
    }

    #[test]
    fn keep_slowest_n_under_interleaved_completion() {
        let c = TraceCollector::new(3, 0.0, 7);
        for (id, dur) in [(1u64, 50u64), (2, 500), (3, 10), (4, 300), (5, 80), (6, 400)] {
            c.begin(id, "m", 0);
            assert!(c.complete(id, dur));
        }
        let totals: Vec<u64> = c.trees().iter().map(|t| t.total_us).collect();
        assert_eq!(totals, vec![500, 400, 300], "slowest three, slowest first");
        assert_eq!(c.stats().dropped, 3);
    }

    #[test]
    fn slots_zero_disables_cleanly() {
        let c = TraceCollector::new(0, 1.0, 7);
        c.begin(1, "m", 0);
        c.record(1, span(SPAN_QUEUE, BATCHER_TID, 0, 5));
        assert!(!c.complete(1, 10));
        assert_eq!(c.force(0, "m", 0, 10, vec![]), 0);
        assert!(c.trees().is_empty());
        assert_eq!(c.stats().pending, 0);
    }

    #[test]
    fn pending_is_bounded_drop_oldest() {
        let c = TraceCollector::new(4, 0.0, 7);
        for id in 1..=(TraceCollector::MAX_PENDING as u64 + 8) {
            c.begin(id, "m", id);
        }
        assert_eq!(c.stats().pending, TraceCollector::MAX_PENDING);
        // the oldest 8 were evicted; completing them is a no-op
        assert!(!c.complete(1, 100));
        assert!(c.complete(9, 100));
    }

    #[test]
    fn spans_per_trace_are_bounded() {
        let c = TraceCollector::new(4, 0.0, 7);
        c.begin(1, "m", 0);
        for i in 0..(TraceCollector::MAX_SPANS as u64 + 10) {
            c.record(1, span(SPAN_QUEUE, BATCHER_TID, i, 1));
        }
        assert!(c.complete(1, 1000));
        // +1 for the synthesized session root
        assert_eq!(c.trees()[0].spans.len(), TraceCollector::MAX_SPANS + 1);
    }

    #[test]
    fn force_merges_pending_and_marks_forced() {
        let c = TraceCollector::new(4, 0.0, 7);
        c.begin(5, "mlp", 10);
        c.record(5, span(SPAN_ADMISSION, GATEWAY_TID, 11, 2));
        let used = c.force(5, "ignored", 20, 90, vec![span(SPAN_QUEUE, BATCHER_TID, 20, 70)]);
        assert_eq!(used, 5);
        let t = &c.trees()[0];
        assert!(t.forced);
        assert_eq!(t.model, "mlp", "pending metadata wins");
        assert_eq!(t.spans.len(), 3);
        assert_eq!(t.start_us, 10);
        // unsampled request: an id is synthesized, high bit set
        let synth = c.force(0, "mlp", 0, 5, vec![]);
        assert!(synth & (1 << 63) != 0);
        assert_eq!(c.stats().forced, 2);
    }

    #[test]
    fn summary_round_trips_through_the_parser() {
        let c = TraceCollector::new(4, 0.25, 7);
        c.begin(0xabc, "synthetic-mlp", 0);
        c.record(0xabc, span(SPAN_QUEUE, BATCHER_TID, 5, 40));
        c.record(0xabc, span(SPAN_DECODE, WORKER_TID_BASE, 50, 9));
        c.complete(0xabc, 60);
        let text = c.summary();
        assert!(text.starts_with("trace spans: kept=1 cap=4 sample=0.2500"), "{text}");
        let entry = text.lines().find_map(parse_summary_line).expect("one span-trace line");
        assert_eq!(
            entry,
            SummaryEntry {
                id: 0xabc,
                total_us: 60,
                forced: false,
                dominant: Some("queue".to_string()),
            }
        );
        assert!(parse_summary_line("trace spans: kept=1 cap=4").is_none());
    }

    #[test]
    fn chrome_json_is_an_event_array_with_nested_spans() {
        let c = TraceCollector::new(4, 0.0, 7);
        c.begin(3, "mlp\"quoted", 0);
        c.record(
            3,
            span(SPAN_BATCH, WORKER_TID_BASE + 1, 10, 50).with_args(&[("batch", 4), ("member", 0)]),
        );
        c.complete(3, 70);
        let json = c.chrome_json();
        assert!(json.starts_with('[') && json.ends_with(']'), "{json}");
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"M\""));
        assert!(json.contains("\"tid\":11"));
        assert!(json.contains("\"name\":\"worker-1\""));
        assert!(json.contains("\"batch\":4"));
        assert!(json.contains("\\\"quoted"));
        assert!(json.contains("\"trace\":\"0x0000000000000003\""));
        // no trailing comma before the closing bracket
        assert!(!json.contains(",]"));
    }

    #[test]
    fn span_buffer_stages_and_flushes_in_one_batch() {
        let c = TraceCollector::new(4, 0.0, 7);
        c.begin(2, "m", 0);
        let mut buf = SpanBuffer::new();
        buf.push(0, span(SPAN_QUEUE, BATCHER_TID, 0, 1)); // id 0 ignored
        buf.push(2, span(SPAN_QUEUE, BATCHER_TID, 0, 7));
        assert!(!buf.is_empty());
        buf.flush(&c);
        assert!(buf.is_empty());
        c.complete(2, 10);
        let t = &c.trees()[0];
        assert_eq!(t.spans.iter().filter(|s| s.name == SPAN_QUEUE).count(), 1);
    }
}
