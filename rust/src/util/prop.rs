//! Minimal property-testing harness (the image vendors no `proptest`).
//!
//! `run_prop` drives a seeded generator through N cases; on failure it
//! reports the failing seed so the case replays deterministically:
//!
//! ```ignore
//! run_prop("crt roundtrip", 500, |rng| {
//!     let a = rng.gen_range_i64(-1000, 1000);
//!     prop_assert(ctx.crt_signed(&ctx.forward(a)) == a, &format!("a={a}"))
//! });
//! ```

use super::rng::Rng;

/// Result of a single property case.
pub type PropResult = Result<(), String>;

/// Assert helper producing a `PropResult`.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert two values are equal, formatting both on failure.
pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, ctx: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

/// Run `cases` property cases with per-case derived seeds.  Panics with the
/// failing case's seed + message on the first failure.
pub fn run_prop<F: FnMut(&mut Rng) -> PropResult>(name: &str, cases: u64, mut f: F) {
    run_prop_seeded(name, cases, 0xC0FFEE, &mut f)
}

/// Like `run_prop` but with an explicit base seed (for replaying failures).
pub fn run_prop_seeded<F: FnMut(&mut Rng) -> PropResult>(
    name: &str,
    cases: u64,
    base_seed: u64,
    f: &mut F,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::seed_from(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property `{name}` failed at case {case} (replay: run_prop_seeded(\"{name}\", 1, {seed:#x}, ..)):\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("trivial", 25, |rng| {
            count += 1;
            prop_assert(rng.gen_range(10) < 10, "in range")
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        run_prop("fails", 10, |rng| {
            let v = rng.gen_range(100);
            prop_assert(v < 1, &format!("v={v}"))
        });
    }

    #[test]
    fn prop_assert_eq_formats() {
        assert!(prop_assert_eq(1, 1, "x").is_ok());
        let err = prop_assert_eq(1, 2, "x").unwrap_err();
        assert!(err.contains("1 != 2"));
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        run_prop("collect", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        run_prop("collect", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
