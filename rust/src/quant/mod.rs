//! Quantization substrate (paper §III-B / Fig. 2 dataflow).
//!
//! Scaling convention (mirrors python/compile/quantize.py):
//!   * activations: one scale per input vector, `s_in = max(|x_row|)`;
//!   * weights: one scale per *output column* of the (K, N) matrix — the
//!     paper's "per row of the h×h weight matrix" in its (N, K) layout;
//!   * symmetric signed integers in `[-(2^(b-1)-1), 2^(b-1)-1]`;
//!   * dequantize: `Y[k] = Y_SI[k] * s_in * s_w[k] / qmax^2`.

use crate::tensor::{MatF, MatI};

/// Largest symmetric quantized magnitude for `bits`: `2^(b-1) - 1`.
pub fn qmax(bits: u32) -> i64 {
    (1 << (bits - 1)) - 1
}

/// Quantized activations: integer matrix + per-row scales.
#[derive(Clone, Debug)]
pub struct QuantActs {
    pub q: MatI,
    pub scales: Vec<f32>, // length = rows
    pub bits: u32,
}

/// Quantized weights: integer matrix + per-column scales.
#[derive(Clone, Debug)]
pub struct QuantWeights {
    pub q: MatI,
    pub scales: Vec<f32>, // length = cols
    pub bits: u32,
}

/// Per-input-vector symmetric quantization of (B, K) activations.
pub fn quantize_activations(x: &MatF, bits: u32) -> QuantActs {
    let qm = qmax(bits) as f32;
    let mut q = MatI::zeros(x.rows, x.cols);
    let mut scales = Vec::with_capacity(x.rows);
    for r in 0..x.rows {
        let row = x.row(r);
        let mut s = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        if s == 0.0 {
            s = 1.0;
        }
        scales.push(s);
        let qrow = q.row_mut(r);
        for (dst, &v) in qrow.iter_mut().zip(row) {
            *dst = (v / s * qm).round() as i64;
        }
    }
    QuantActs { q, scales, bits }
}

/// Per-output-column symmetric quantization of (K, N) weights.
pub fn quantize_weights(w: &MatF, bits: u32) -> QuantWeights {
    let qm = qmax(bits) as f32;
    let mut scales = vec![0.0f32; w.cols];
    for r in 0..w.rows {
        for (c, &v) in w.row(r).iter().enumerate() {
            scales[c] = scales[c].max(v.abs());
        }
    }
    for s in scales.iter_mut() {
        if *s == 0.0 {
            *s = 1.0;
        }
    }
    let mut q = MatI::zeros(w.rows, w.cols);
    for r in 0..w.rows {
        let qrow = q.row_mut(r);
        for (c, &v) in w.row(r).iter().enumerate() {
            qrow[c] = (v / scales[c] * qm).round() as i64;
        }
    }
    QuantWeights { q, scales, bits }
}

/// Undo both scalings on an integer GEMM output (B, N).
pub fn dequantize(y_si: &MatI, acts: &QuantActs, weights: &QuantWeights) -> MatF {
    assert_eq!(acts.bits, weights.bits, "mixed-precision dequantize");
    assert_eq!(y_si.rows, acts.scales.len());
    assert_eq!(y_si.cols, weights.scales.len());
    let qm2 = (qmax(acts.bits) * qmax(acts.bits)) as f32;
    let mut out = MatF::zeros(y_si.rows, y_si.cols);
    for r in 0..y_si.rows {
        let s_in = acts.scales[r];
        let orow = out.row_mut(r);
        for (c, &v) in y_si.row(r).iter().enumerate() {
            orow[c] = v as f32 * s_in * weights.scales[c] / qm2;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gemm::{gemm_f32, gemm_i64};
    use crate::util::prop::{prop_assert, run_prop};
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> MatF {
        MatF::from_vec(rows, cols, (0..rows * cols).map(|_| rng.uniform_f32(-scale, scale)).collect())
    }

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(4), 7);
        assert_eq!(qmax(6), 31);
        assert_eq!(qmax(8), 127);
    }

    #[test]
    fn activation_bounds_and_integrality() {
        run_prop("act quantize bounds", 50, |rng| {
            let bits = [4u32, 6, 8][rng.gen_range(3) as usize];
            let x = rand_mat(rng, 3, 17, 5.0);
            let qa = quantize_activations(&x, bits);
            let qm = qmax(bits);
            prop_assert(qa.q.data.iter().all(|&v| v.abs() <= qm), "bounds")?;
            prop_assert(qa.scales.iter().all(|&s| s > 0.0), "positive scales")
        });
    }

    #[test]
    fn weight_scales_per_column() {
        let w = MatF::from_vec(3, 2, vec![1.0, 10.0, 2.0, -20.0, 0.5, 5.0]);
        let qw = quantize_weights(&w, 8);
        assert_eq!(qw.scales, vec![2.0, 20.0]);
        // max-magnitude entries map to exactly +-qmax
        assert_eq!(qw.q.at(1, 0), 127);
        assert_eq!(qw.q.at(1, 1), -127);
    }

    #[test]
    fn zero_input_guard() {
        let qa = quantize_activations(&MatF::zeros(2, 4), 6);
        assert!(qa.scales.iter().all(|&s| s == 1.0));
        assert!(qa.q.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn quantized_gemm_tracks_fp32() {
        // dequant(qx @ qw) approx x @ w with error bounded by quantization
        let mut rng = Rng::seed_from(5);
        let x = rand_mat(&mut rng, 4, 64, 1.0);
        let w = rand_mat(&mut rng, 64, 8, 0.5);
        let want = gemm_f32(&x, &w);
        let qa = quantize_activations(&x, 8);
        let qw = quantize_weights(&w, 8);
        let y = gemm_i64(&qa.q, &qw.q);
        let got = dequantize(&y, &qa, &qw);
        // bound: K * (s_in/2qm * wmax + s_w/2qm * xmax + tiny) per element
        for (g, f) in got.data.iter().zip(&want.data) {
            assert!((g - f).abs() < 0.05, "{g} vs {f}");
        }
    }

    #[test]
    fn dequantize_formula() {
        let y = MatI::from_vec(1, 2, vec![100, -200]);
        let acts = QuantActs { q: MatI::zeros(1, 2), scales: vec![2.0], bits: 8 };
        let weights = QuantWeights { q: MatI::zeros(2, 2), scales: vec![3.0, 4.0], bits: 8 };
        let out = dequantize(&y, &acts, &weights);
        let qm2 = 127.0f32 * 127.0;
        assert!((out.at(0, 0) - 100.0 * 6.0 / qm2).abs() < 1e-6);
        assert!((out.at(0, 1) + 200.0 * 8.0 / qm2).abs() < 1e-6);
    }
}
