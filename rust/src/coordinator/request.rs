//! Request/response types for the serving coordinator, including the
//! typed serving error the supervision layer and the wire protocol share.

use std::fmt;
use std::time::{Duration, Instant};

use crate::nn::models::Batch;
use crate::tensor::MatF;

/// Monotonically increasing request id.
pub type RequestId = u64;

/// Why a request failed, in terms a client can act on (see the README
/// failure-modes table): `Model`/`Poisoned`/`DeadlineExceeded` are
/// permanent for the same request, `Internal` is retryable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeErrorKind {
    /// Unknown model, shape mismatch, or load failure — fix the request.
    Model,
    /// Worker-side failure (backend construction, crash during an
    /// unrelated batch) — safe to retry, inference is pure.
    Internal,
    /// The request's deadline passed before a result was produced.
    DeadlineExceeded,
    /// The batch crashed workers `poison_threshold` times and was
    /// quarantined instead of being redispatched again.
    Poisoned,
}

/// A typed serving failure: the kind drives client retry policy and the
/// wire error code; the message carries the human-readable detail.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeError {
    pub kind: ServeErrorKind,
    pub message: String,
}

impl ServeError {
    pub fn new(kind: ServeErrorKind, message: impl Into<String>) -> Self {
        ServeError { kind, message: message.into() }
    }

    pub fn model(message: impl Into<String>) -> Self {
        Self::new(ServeErrorKind::Model, message)
    }

    pub fn internal(message: impl Into<String>) -> Self {
        Self::new(ServeErrorKind::Internal, message)
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let kind = match self.kind {
            ServeErrorKind::Model => "model",
            ServeErrorKind::Internal => "internal",
            ServeErrorKind::DeadlineExceeded => "deadline-exceeded",
            ServeErrorKind::Poisoned => "poisoned",
        };
        write!(f, "{kind}: {}", self.message)
    }
}

impl std::error::Error for ServeError {}

/// One inference request: a (possibly multi-sample) input for a zoo model.
#[derive(Debug)]
pub struct InferenceRequest {
    pub id: RequestId,
    pub model: String,
    pub input: Batch,
    pub submitted_at: Instant,
    /// Absolute completion deadline; `None` means no limit.  Resolved at
    /// submit time (per-request wire field, else the server default) so
    /// queue time counts against it.
    pub deadline: Option<Instant>,
    /// Trace id when this request is sampled for span tracing; 0 (the
    /// overwhelmingly common case) means unsampled.  Carried through the
    /// batcher so workers can attribute per-stage spans.
    pub trace: u64,
}

impl InferenceRequest {
    pub fn new(id: RequestId, model: &str, input: Batch) -> Self {
        InferenceRequest {
            id,
            model: model.to_string(),
            input,
            submitted_at: Instant::now(),
            deadline: None,
            trace: 0,
        }
    }

    pub fn with_deadline(mut self, deadline: Option<Instant>) -> Self {
        self.deadline = deadline;
        self
    }

    pub fn with_trace(mut self, trace: u64) -> Self {
        self.trace = trace;
        self
    }

    pub fn num_samples(&self) -> usize {
        self.input.len()
    }

    /// True once the request can no longer be answered in time.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// The completed response.
#[derive(Debug)]
pub struct InferenceResponse {
    pub id: RequestId,
    /// Logits (num_samples, num_classes), or the typed failure.
    pub result: Result<MatF, ServeError>,
    /// Time spent queued before a worker picked the batch up.
    pub queue_time: Duration,
    /// End-to-end latency (submit -> response).
    pub latency: Duration,
    /// Worker that executed the batch.
    pub worker: usize,
    /// RRNS decode detections triggered while serving this request's batch.
    pub faults_detected: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Nhwc;

    #[test]
    fn request_sample_count() {
        let r = InferenceRequest::new(1, "mlp", Batch::Images(Nhwc::zeros(3, 28, 28, 1)));
        assert_eq!(r.num_samples(), 3);
        assert_eq!(r.model, "mlp");
        assert_eq!(r.deadline, None);
        assert!(!r.expired(Instant::now()));
    }

    #[test]
    fn deadline_expiry() {
        let now = Instant::now();
        let r = InferenceRequest::new(2, "mlp", Batch::Images(Nhwc::zeros(1, 28, 28, 1)))
            .with_deadline(Some(now + Duration::from_millis(5)));
        assert!(!r.expired(now));
        assert!(r.expired(now + Duration::from_millis(5)));
        assert!(r.expired(now + Duration::from_secs(1)));
    }

    #[test]
    fn serve_error_display_includes_kind() {
        let e = ServeError::new(ServeErrorKind::DeadlineExceeded, "late by 3ms");
        assert_eq!(e.to_string(), "deadline-exceeded: late by 3ms");
        assert_eq!(ServeError::model("no such model").to_string(), "model: no such model");
    }
}
