//! Request/response types for the serving coordinator.

use std::time::{Duration, Instant};

use crate::nn::models::Batch;
use crate::tensor::MatF;

/// Monotonically increasing request id.
pub type RequestId = u64;

/// One inference request: a (possibly multi-sample) input for a zoo model.
#[derive(Debug)]
pub struct InferenceRequest {
    pub id: RequestId,
    pub model: String,
    pub input: Batch,
    pub submitted_at: Instant,
}

impl InferenceRequest {
    pub fn new(id: RequestId, model: &str, input: Batch) -> Self {
        InferenceRequest { id, model: model.to_string(), input, submitted_at: Instant::now() }
    }

    pub fn num_samples(&self) -> usize {
        self.input.len()
    }
}

/// The completed response.
#[derive(Debug)]
pub struct InferenceResponse {
    pub id: RequestId,
    /// Logits (num_samples, num_classes), or the failure message.
    pub result: Result<MatF, String>,
    /// Time spent queued before a worker picked the batch up.
    pub queue_time: Duration,
    /// End-to-end latency (submit -> response).
    pub latency: Duration,
    /// Worker that executed the batch.
    pub worker: usize,
    /// RRNS decode detections triggered while serving this request's batch.
    pub faults_detected: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Nhwc;

    #[test]
    fn request_sample_count() {
        let r = InferenceRequest::new(1, "mlp", Batch::Images(Nhwc::zeros(3, 28, 28, 1)));
        assert_eq!(r.num_samples(), 3);
        assert_eq!(r.model, "mlp");
    }
}
